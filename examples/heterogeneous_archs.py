"""Model heterogeneity (paper Fig. 5b): each client keeps a DIFFERENT
private architecture — MLP, LeNet5, CNN1, CNN2 — while agreeing only on the
small shared proxy. Canonical FL (FedAvg et al.) cannot do this at all.

    PYTHONPATH=src python examples/heterogeneous_archs.py
"""
import os
import tempfile

import jax
import numpy as np

from repro.configs.base import DPConfig, ProxyFLConfig
from repro.core.baselines import run_federated
from repro.core.protocol import ModelSpec
from repro.data.partition import partition_major
from repro.data.synthetic import make_classification_data
from repro.nn.vision import get_vision_model

N_CLASSES, IMG = 10, (14, 14, 1)
ARCHS = ("mlp", "lenet5", "cnn1", "cnn2")
K = len(ARCHS)

key = jax.random.PRNGKey(0)
x, y = make_classification_data(key, 4000, IMG, N_CLASSES, sep=2.0)
xt, yt = make_classification_data(jax.random.fold_in(key, 1), 1000, IMG,
                                  N_CLASSES, sep=2.0)
parts = partition_major(np.random.default_rng(0), np.asarray(y), K, 500,
                        0.8, N_CLASSES)
client_data = [(x[i], y[i]) for i in parts]

specs = []
for name in ARCHS:
    vm = get_vision_model(name)
    specs.append(ModelSpec(name, lambda k, vm=vm: vm.init(k, IMG, N_CLASSES),
                           vm.apply))
proxy_vm = get_vision_model("mlp")
proxy = ModelSpec("proxy-mlp", lambda k: proxy_vm.init(k, IMG, N_CLASSES),
                  proxy_vm.apply)

cfg = ProxyFLConfig(n_clients=K, rounds=5, batch_size=100,
                    dp=DPConfig(enabled=True))

# Heterogeneous cohorts force the per-client `loop` backend — checkpoints
# are stored per client, so even four DIFFERENT architectures snapshot and
# resume bit-exactly. The directory is stable across invocations: kill the
# script mid-run and rerun it to watch the federation pick up where it
# stopped (a finished run's snapshots just re-evaluate instantly).
ckpt_dir = os.path.join(tempfile.gettempdir(), "proxyfl_hetero_ckpts")
fed = run_federated("proxyfl", specs, proxy, client_data, (xt, yt), cfg,
                    eval_every=cfg.rounds, checkpoint_dir=ckpt_dir,
                    checkpoint_every=2, resume=True)
solo = {}
for k, name in enumerate(ARCHS):
    r = run_federated("regular", [specs[k]] * K, specs[k], client_data,
                      (xt, yt), cfg, eval_every=cfg.rounds)
    solo[name] = float(np.mean(r["history"][-1]["acc"]))

print(f"{'client arch':12s} {'regular':>8s} {'proxyfl':>8s}")
row = fed["history"][-1]
for k, name in enumerate(ARCHS):
    print(f"{name:12s} {solo[name]:8.3f} {row['private_acc'][k]:8.3f}")
print("\nEvery architecture improves by collaborating through the shared "
      "proxy — weaker models gain the most (paper Fig. 5b).")
