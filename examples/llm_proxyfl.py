"""End-to-end driver: ProxyFL over ~100M-parameter language models.

Each client's private model is the ``repro-100m`` dense decoder (12L/768d,
~100M params); the shared proxy is a 4L/256d decoder. Clients hold
synthetic bigram-domain corpora (non-IID by construction); per round each
runs local DML steps (private Adam + proxy DP-SGD), then the proxies
travel the PushSum exponential graph.

Defaults are sized for a CPU demonstration run. For the full-scale
"few hundred steps" run used in EXPERIMENTS.md:

    PYTHONPATH=src python examples/llm_proxyfl.py -- \
        --rounds 20 --steps-per-round 10 --batch 8 --seq 256

(Anything after ``--`` is forwarded to repro.launch.train.)
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    args = sys.argv[1:]
    if "--" in args:
        args = args[args.index("--") + 1:]
    if not args:
        args = ["--preset", "100m", "--clients", "2", "--rounds", "2",
                "--steps-per-round", "3", "--batch", "4", "--seq", "128"]
    raise SystemExit(main(["--preset", "100m"] + args
                          if "--preset" not in args and "--arch" not in args
                          else args))
