"""Quickstart: ProxyFL in ~40 lines.

Four hospitals (clients), each with a skewed private dataset, jointly train
without sharing data or private models. Each client trains its private
model + a DP-SGD proxy (deep mutual learning), then exchanges ONLY the
proxy over the decentralized PushSum graph.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import tempfile

import jax
import numpy as np

from repro.configs.base import DPConfig, ProxyFLConfig
from repro.core.baselines import final_mean_acc, run_federated
from repro.core.protocol import ModelSpec
from repro.data.partition import partition_major
from repro.data.synthetic import make_classification_data
from repro.nn.vision import get_vision_model

N_CLIENTS, N_CLASSES, IMG = 4, 10, (14, 14, 1)

# --- synthetic non-IID federation -----------------------------------------
key = jax.random.PRNGKey(0)
x, y = make_classification_data(key, 4000, IMG, N_CLASSES, sep=2.0)
xt, yt = make_classification_data(jax.random.fold_in(key, 1), 1000, IMG,
                                  N_CLASSES, sep=2.0)
parts = partition_major(np.random.default_rng(0), np.asarray(y), N_CLIENTS,
                        per_client=500, p_major=0.8, n_classes=N_CLASSES)
client_data = [(x[i], y[i]) for i in parts]

# --- models: any private architecture; a common (small) proxy -------------
mlp = get_vision_model("mlp")
spec = ModelSpec("mlp", lambda k: mlp.init(k, IMG, N_CLASSES), mlp.apply)

cfg = ProxyFLConfig(
    n_clients=N_CLIENTS, rounds=5, batch_size=100, alpha=0.5, beta=0.5,
    dp=DPConfig(enabled=True, clip_norm=1.0, noise_multiplier=1.0),
    topology="exponential",
)

print("method      test-acc   epsilon")
for method in ("proxyfl", "regular", "joint"):
    res = run_federated(method, [spec] * N_CLIENTS, spec, client_data,
                        (xt, yt), cfg, eval_every=cfg.rounds)
    eps = res["epsilon"][0]
    print(f"{method:11s} {final_mean_acc(res):8.3f}   "
          f"{eps if eps is None else round(eps, 2)}")
print("\nProxyFL's private models should clearly beat isolated Regular "
      "training, approaching the pooled-data Joint upper bound — with a "
      "quantified (eps, delta) guarantee on everything that left a client.")

# --- preemption tolerance: checkpoint every round, resume after a kill ----
# Long multi-institution federations survive restarts: checkpoint_dir
# snapshots complete federation state each round, and resume=True picks up
# where a killed run stopped — the continuation is BIT-IDENTICAL to an
# uninterrupted run (CI verifies this via scripts/ci.sh --smoke).
ckpt_dir = tempfile.mkdtemp(prefix="proxyfl_quickstart_")
interrupted = dataclasses.replace(cfg, rounds=3)  # "killed" after round 3
run_federated("proxyfl", [spec] * N_CLIENTS, spec, client_data, (xt, yt),
              interrupted, eval_every=interrupted.rounds,
              checkpoint_dir=ckpt_dir, checkpoint_every=1)
res = run_federated("proxyfl", [spec] * N_CLIENTS, spec, client_data,
                    (xt, yt), cfg, eval_every=cfg.rounds,
                    checkpoint_dir=ckpt_dir, checkpoint_every=1, resume=True)
print(f"\nresumed from round 3/{cfg.rounds} checkpoint -> final acc "
      f"{final_mean_acc(res):.3f} (same params as an uninterrupted run)")

# --- compressed exchange: same protocol, ~6x fewer bytes on the wire ------
# compress="topk" (or "int8") delta-codes each transmitted proxy against a
# public copy receivers already hold (repro.core.compress): ~6.4x fewer
# bytes at ratio 0.25, with error feedback re-sending truncated mass in
# later rounds so accuracy tracks full precision (benchmarks/fig_compress
# measures the accuracy-vs-bytes Pareto; scripts/check_comm_claim.py gates
# it in CI). compress="none" is bitwise-identical to the plain exchange.
compressed = dataclasses.replace(cfg, compress="topk", compress_ratio=0.25)
res = run_federated("proxyfl", [spec] * N_CLIENTS, spec, client_data,
                    (xt, yt), compressed, eval_every=compressed.rounds)
print(f"top-k compressed exchange (~6.4x fewer bytes) -> final acc "
      f"{final_mean_acc(res):.3f}")
