"""Batched serving of a client's private model after federation — prefill a
batch of prompts, then step the decode loop (greedy) through the KV cache.
Uses the reduced gemma3-4b family variant (5:1 sliding-window) on CPU.

    PYTHONPATH=src python examples/serve_demo.py
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    args = sys.argv[1:] or ["--arch", "gemma3-4b", "--smoke", "--batch", "4",
                            "--prompt-len", "32", "--gen", "8",
                            "--temperature", "0.8"]
    raise SystemExit(main(args))
