"""Fused round-blocks: rounds/sec vs block size per backend (beyond-paper).

The paper's O(1)-communication claim (Fig. 4) is about gossip VOLUME; on a
simulator the wall-clock is instead dominated by per-round host
synchronization — rebuilding P^(t) in numpy, folding the round key,
re-dispatching the compiled round program and pulling metrics, every
round. ``FederationEngine.run_rounds`` fuses B consecutive rounds into one
compiled program (outer ``lax.scan`` over rounds, ``mix_schedule``
precomputing the stacked [B, K, K] exchange matrices), so the host is
re-entered once per block. This figure quantifies how much of the round
time that overhead was: rounds/sec vs B per backend at K ∈ {4, 8, 16}, in
the gossip-bound regime (``local_steps=1`` — one local step, one exchange;
the regime the paper's communication claim lives in). The loop backend has
per-round semantics by definition and appears as the B=1 baseline only.

Results are also written as JSON (``REPRO_BENCH_BLOCKS_JSON``, default
``fig_blocks.json`` in the CWD) including ``speedup_vs_b1`` — the measured
rounds/sec speedup of each B>1 vmap configuration over B=1 on the same
cohort (the acceptance metric: host overhead recovered by fusing the round
boundary).
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs.base import DPConfig, ProxyFLConfig
from repro.core.engine import dml_engine

from .common import FULL, federation_data, spec_of


def _time_blocks(engine, data, key, rounds: int, block: int,
                 trials: int = 3) -> float:
    """Steady-state seconds per ROUND when driving ``rounds`` rounds in
    blocks of ``block`` (compile excluded: one warm-up block; BEST of
    ``trials`` — the standard throughput measure, robust to CPU
    contention, which medians are not on shared small machines)."""
    state = engine.init_states(key)
    state, _ = engine.run_rounds(state, data, 0, min(block, rounds), key)
    jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
    ts = []
    for _ in range(trials):
        t0 = time.time()
        t = 0
        while t < rounds:
            n = min(block, rounds - t)
            state, _ = engine.run_rounds(state, data, t, n, key)
            t += n
        jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
        ts.append((time.time() - t0) / rounds)
    return float(np.min(ts))


def run(full: bool = FULL):
    cohorts = (4, 8, 16) if full else (4, 8)
    rounds = 16 if full else 8
    blocks = (1, 2, 4, 8)
    dataset = "mnist"
    key = jax.random.PRNGKey(0)

    rows = []
    for n_clients in cohorts:
        client_data, _, d = federation_data(
            dataset, n_clients, seed=0, n_train_factor=1.0 if full else 0.2)
        spec = spec_of("mlp", d["shape"], d["n_classes"])
        # gossip-bound regime: one local step then one exchange — the end
        # of Algorithm 1 where per-round host overhead dominates
        cfg = ProxyFLConfig(n_clients=n_clients, rounds=rounds, local_steps=1,
                            batch_size=16, seed=0, dp=DPConfig(enabled=False))
        base = {}
        for backend in ("loop", "vmap"):
            engine = dml_engine((spec,) * n_clients, spec, cfg,
                                backend=backend)
            for block in blocks if backend == "vmap" else (1,):
                sec = _time_blocks(engine, client_data, key, rounds, block)
                if block == 1:  # B=1 is each backend's own baseline
                    base[backend] = sec
                rows.append({
                    "dataset": dataset, "clients": n_clients,
                    "backend": backend, "rounds_per_block": block,
                    "local_steps": 1,
                    "sec_per_round": round(sec, 5),
                    "rounds_per_sec": round(1.0 / sec, 2),
                    "speedup_vs_b1": round(base[backend] / sec, 2),
                })
    path = os.environ.get("REPRO_BENCH_BLOCKS_JSON", "fig_blocks.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return rows
