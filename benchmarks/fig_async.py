"""Async stale gossip: rounds/sec and final proxy accuracy vs staleness τ.

The synchronous PushSum exchange blocks every client on its in-neighbor's
CURRENT proxy, so one straggler stalls the cohort. The engine's ``async``
backend delivers proxy mass put in flight τ rounds earlier instead
(Assran et al. 2019's overlap trick; see ``repro.core.engine``), letting
communication hide behind the next τ local scans. Staleness is a
semantics knob, not a free lunch: the mix consumes τ-round-old
information, so consensus — and with it proxy accuracy — can lag. This
figure quantifies the trade on the paper-style synthetic task: for
τ ∈ {0, 1, 2, 4}, final MEAN proxy and private accuracy of a ProxyFL
federation against the synchronous (vmap) reference, plus simulator
rounds/sec (the buffer machinery's overhead; the wall-clock WIN of
asynchrony — not stalling on stragglers — is a property of a real
deployment, which a single-host simulator cannot exhibit).

τ=0 must reproduce the sync reference EXACTLY (bit-identity is enforced
by tests/test_conformance.py; here it shows up as acc_delta_vs_sync == 0).
Small τ (≤ 2) tracking the reference within seed noise is the evidence
behind the ROADMAP's "when is τ accuracy-safe" guidance.

Results are also written as JSON (``REPRO_BENCH_ASYNC_JSON``, default
``fig_async.json`` in the CWD) including ``acc_delta_vs_sync`` per τ.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs.base import DPConfig, ProxyFLConfig
from repro.core.baselines import run_federated
from repro.core.engine import dml_engine

from .common import FULL, federation_data, spec_of

STALENESS = (0, 1, 2, 4)


def _time_rounds(engine, data, key, rounds: int, trials: int = 3) -> float:
    """Steady-state seconds per round driving ``rounds`` rounds as one
    engine block (compile excluded via a warm-up block; best of
    ``trials``, the contention-robust throughput measure)."""
    state = engine.init_states(key)
    state, _ = engine.run_rounds(state, data, 0, rounds, key)
    jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
    ts = []
    for _ in range(trials):
        state = engine.init_states(key)
        t0 = time.time()
        state, _ = engine.run_rounds(state, data, 0, rounds, key)
        jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
        ts.append((time.time() - t0) / rounds)
    return float(np.min(ts))


def run(full: bool = FULL):
    n_clients = 8 if full else 4
    rounds = 30 if full else 12
    seeds = (0, 1, 2) if full else (0,)
    dataset = "mnist"
    key = jax.random.PRNGKey(0)

    rows = []
    sync_proxy = None
    for tau in STALENESS:
        accs, paccs = [], []
        for seed in seeds:
            client_data, test, d = federation_data(
                dataset, n_clients, seed,
                n_train_factor=1.0 if full else 0.2)
            spec = spec_of("mlp", d["shape"], d["n_classes"])
            cfg = ProxyFLConfig(
                n_clients=n_clients, rounds=rounds, local_steps=2,
                batch_size=64, seed=seed, staleness=tau,
                dp=DPConfig(enabled=False))
            backend = "vmap" if tau == 0 else "async"
            res = run_federated(
                "proxyfl", [spec] * n_clients, spec, client_data, test,
                cfg, seed=seed, eval_every=rounds, backend=backend,
                rounds_per_block=rounds)
            row = res["history"][-1]
            accs.extend(row["private_acc"])
            paccs.extend(row["proxy_acc"])
            if seed == seeds[0]:
                # throughput on the same cohort: whole horizon as ONE block
                eng = dml_engine((spec,) * n_clients, spec, cfg,
                                 backend=backend)
                sec = _time_rounds(eng, client_data, key, rounds)
        proxy_mean = float(np.mean(paccs))
        if tau == 0:
            sync_proxy = proxy_mean
        rows.append({
            "dataset": dataset, "clients": n_clients, "rounds": rounds,
            "staleness": tau, "backend": "vmap (sync ref)" if tau == 0
            else "async",
            "proxy_acc_mean": round(proxy_mean, 4),
            "proxy_acc_std": round(float(np.std(paccs)), 4),
            "private_acc_mean": round(float(np.mean(accs)), 4),
            "acc_delta_vs_sync": round(proxy_mean - sync_proxy, 4),
            "sec_per_round": round(sec, 5),
            "rounds_per_sec": round(1.0 / sec, 2),
        })
    path = os.environ.get("REPRO_BENCH_ASYNC_JSON", "fig_async.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return rows
