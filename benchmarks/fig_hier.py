"""Two-level hier gossip: rounds/sec vs flat backends at large K (beyond-paper).

The engine's ``backend="hier"`` targets thousand-client cohorts: the flat
PushSum matrix P^(t) is factored into a block-diagonal intra-shard part
(mixed on device as one batched [S, L, L] matmul over the stacked clients)
plus at most one sparse cross-shard edge per client per round (the
ppermute-shaped permutation that becomes inter-node traffic in
production). This figure measures what the factoring buys on a forced
8-device host mesh (``XLA_FLAGS=--xla_force_host_platform_device_count=8``,
set in a SUBPROCESS worker because jax locks the device count at first
initialization):

* rounds/sec of hier (n_shards=8, blocked) vs flat vmap (blocked) vs flat
  loop (per-round dispatch — the B=1 baseline) at K ∈ {8, 64, 256}
  (full budget adds 1024);
* flat shard_map for reference at K=8 ONLY — its one-client-per-device
  layout cannot exceed the 8-device host mesh, which is exactly the
  scaling wall the two-level layout removes (logged in the row);
* the analytic per-client CROSS-SHARD wire bytes per round, which stay
  O(D) — flat in K — while the intra-shard mass movement never leaves the
  device;
* hier at τ=2 (cross-shard staleness). HONESTY CAVEAT, carried in the
  rows: on this CPU simulator τ>0 overlaps no real network latency — it
  removes the cross-shard data dependency from the compiled schedule, but
  the wall-clock win only materializes with genuine inter-node latency
  (the τ=0/τ=2 ratio here bounds the scheduling overhead, nothing more).

Results are written as JSON to ``results/fig_hier.json`` (override with
``REPRO_BENCH_HIER_JSON``); the acceptance metric is
``speedup_vs_loop`` of the hier τ=0 row at K=256.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

FULL = bool(int(os.environ.get("REPRO_BENCH_FULL", "0")))

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_MARK = "FIG_HIER_JSON "
_DEVICES = 8

#: tiny synthetic classification task — the timing target is the ROUND
#: machinery (mix factoring, host dispatch), not the model math
_SHAPE, _N_CLASSES, _PER_CLIENT = (8, 8, 1), 4, 32


def _worker(full: bool) -> list:
    """Runs inside the subprocess with the forced 8-device host mesh."""
    import time

    import jax
    import numpy as np

    from benchmarks.common import spec_of
    from benchmarks.fig_blocks import _time_blocks
    from repro.configs.base import DPConfig, ProxyFLConfig
    from repro.core.engine import FederationEngine, dml_engine
    from repro.core.gossip import hier_mix_schedule
    from repro.data.synthetic import make_classification_data
    from repro.nn.modules import tree_flatten_vector

    n_dev = jax.device_count()
    spec = spec_of("mlp", _SHAPE, _N_CLASSES)
    D = int(tree_flatten_vector(spec.init(jax.random.PRNGKey(0))).shape[0])
    key = jax.random.PRNGKey(0)

    def data_of(K):
        x, y = make_classification_data(
            jax.random.PRNGKey(1), _PER_CLIENT * K, _SHAPE, _N_CLASSES,
            sep=2.0, task_seed=7)
        return [(x[k * _PER_CLIENT:(k + 1) * _PER_CLIENT],
                 y[k * _PER_CLIENT:(k + 1) * _PER_CLIENT])
                for k in range(K)]

    def cfg_of(K, rounds, *, n_shards=1, staleness=0):
        # gossip-bound regime (local_steps=1), as in fig_blocks: the claim
        # under test is round machinery, not step math
        return ProxyFLConfig(n_clients=K, rounds=rounds, local_steps=1,
                             batch_size=8, seed=0, n_shards=n_shards,
                             staleness=staleness, dp=DPConfig(enabled=False))

    def cross_bytes_per_client(K, S, rounds):
        """Mean analytic cross-shard f32 wire bytes per client per round:
        (#cross edges / K) · 2 · 4·D (value vector out + the mirrored w
        scalar is noise; ×2 for the send being received) — bounded by O(D)
        independent of K."""
        _, _, scale = hier_mix_schedule("pushsum", 0, rounds, K, S)
        frac_cross = float((np.asarray(scale) > 0).mean())
        return frac_cross * 4 * D

    Ks = (8, 64, 256, 1024) if full else (8, 64, 256)
    rounds, block = 8, 8
    shards = _DEVICES
    rows = []
    for K in Ks:
        data = data_of(K)
        base_loop = None
        # loop = the flat per-round-dispatch baseline (B=1 by definition)
        loop_rounds = 4 if K >= 256 else rounds
        eng = dml_engine((spec,) * K, spec, cfg_of(K, loop_rounds),
                         backend="loop")
        sec = _time_blocks(eng, data, key, loop_rounds, 1,
                           trials=2 if K >= 256 else 3)
        base_loop = sec
        rows.append(dict(figure="fig_hier", K=K, backend="loop",
                         n_shards=1, staleness=0, rounds_per_block=1,
                         devices=n_dev, sec_per_round=round(sec, 5),
                         rounds_per_sec=round(1.0 / sec, 2),
                         speedup_vs_loop=1.0,
                         bytes_cross_per_client=None, note=""))

        grid = [("vmap", 1, 0), ("hier", shards, 0), ("hier", shards, 2)]
        for backend, S, tau in grid:
            eng = dml_engine((spec,) * K, spec,
                             cfg_of(K, rounds, n_shards=S, staleness=tau),
                             backend=backend)
            sec = _time_blocks(eng, data, key, rounds, block)
            note = ""
            if tau:
                note = ("CPU simulator: tau>0 overlaps no real network "
                        "latency; wall-clock win needs genuine inter-node "
                        "latency")
            rows.append(dict(
                figure="fig_hier", K=K, backend=backend, n_shards=S,
                staleness=tau, rounds_per_block=block, devices=n_dev,
                sec_per_round=round(sec, 5),
                rounds_per_sec=round(1.0 / sec, 2),
                speedup_vs_loop=round(base_loop / sec, 2),
                bytes_cross_per_client=(
                    round(cross_bytes_per_client(K, S, rounds), 1)
                    if backend == "hier" else None),
                note=note))

        if K == n_dev:
            # flat shard_map: one client per device — CANNOT scale past
            # the 8-device host mesh; measured at K=8 for reference only
            vmap_eng = dml_engine((spec,) * K, spec, cfg_of(K, rounds),
                                  backend="vmap")
            mesh = jax.make_mesh((K,), ("clients",))
            eng = FederationEngine(
                cfg_of(K, rounds), n_clients=K,
                step_fns=vmap_eng.step_fns[0], init_fns=vmap_eng.init_fns[0],
                sample_fn=vmap_eng.sample_fn, backend="shard_map",
                mix="pushsum", mesh=mesh, axis="clients")
            sec = _time_blocks(eng, data, key, rounds, block)
            rows.append(dict(
                figure="fig_hier", K=K, backend="shard_map", n_shards=K,
                staleness=0, rounds_per_block=block, devices=n_dev,
                sec_per_round=round(sec, 5),
                rounds_per_sec=round(1.0 / sec, 2),
                speedup_vs_loop=round(base_loop / sec, 2),
                bytes_cross_per_client=round(4.0 * D, 1),
                note="one client per device: bounded by the 8-device host "
                     "mesh — the flat layout cannot reach K=64+"))
    return rows


def run(full: bool = FULL):
    """Spawn the worker with the forced host-device mesh (jax locks the
    device count at first init, and this parent process has already
    initialized jax via the other figure modules)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_DEVICES}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["REPRO_BENCH_FULL"] = "1" if full else "0"
    pp = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_REPO, "src"), _REPO] + ([pp] if pp else []))
    cmd = [sys.executable, "-m", "benchmarks.fig_hier"]
    r = subprocess.run(cmd, cwd=_REPO, env=env, capture_output=True,
                       text=True)
    marked = [l for l in r.stdout.splitlines() if l.startswith(_MARK)]
    if r.returncode != 0 or not marked:
        raise RuntimeError(
            f"fig_hier worker failed (rc={r.returncode}):\n"
            f"{r.stdout[-2000:]}\n{r.stderr[-4000:]}")
    rows = json.loads(marked[-1][len(_MARK):])
    path = os.environ.get("REPRO_BENCH_HIER_JSON",
                          os.path.join(_REPO, "results", "fig_hier.json"))
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def main(argv=None) -> int:
    # worker entry: force the host-device mesh BEFORE jax initializes
    # (harmless if the parent already set it in our env)
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={_DEVICES}")
    rows = _worker(FULL)
    print(_MARK + json.dumps(rows))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
