"""Dropout/join scenario sweep: final accuracy vs dropout rate (§3.4).

The paper claims the time-varying PushSum graph "can adapt to clients
joining or dropping out" — the exchange re-knits over the active subset
each round, mass conservation holds, and learning should degrade
gracefully (not collapse) as the per-round dropout probability grows.
This sweep runs ProxyFL through ``bench_methods(dropout_rate=...)`` over a
grid of rates and reports the final private AND proxy accuracy per rate;
rate 0.0 is the everyone-participates reference the other rows are read
against. The §3.4 schedule is deterministic per (seed, round), so rows are
reproducible, and every backend replays the identical membership
trajectory.
"""
from __future__ import annotations

from .common import FULL, bench_methods


def run(full: bool = FULL):
    n_clients = 8 if full else 4
    rounds = 30 if full else 6
    seeds = (0, 1, 2) if full else (0,)
    rates = (0.0, 0.2, 0.4, 0.6) if full else (0.0, 0.3, 0.6)

    rows = []
    for rate in rates:
        for r in bench_methods("mnist", ("proxyfl",), n_clients=n_clients,
                               rounds=rounds, seeds=seeds, dp=False,
                               n_train_factor=1.0 if full else 0.25,
                               dropout_rate=rate):
            rows.append({
                "dropout_rate": rate,
                "which": ("proxy" if r["method"].endswith("-proxy")
                          else "private"),
                **{k: r[k] for k in ("dataset", "method", "acc_mean",
                                     "acc_std", "rounds", "clients")},
            })
    return rows
