"""Paper Fig. 4 / Fig. 13: communication time per round as the federation
grows. Centralized schemes (FedAvg, FML) serialize at the server → O(K);
decentralized PushSum sends exactly one model per client → O(1). We report
the analytic link model (bytes / 50 GB/s ICI-class links) over the REAL
serialized sizes of the models used in the paper reproduction, plus the
LLM-scale proxies used in the multi-pod path."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.registry import proxy_of
from repro.core.gossip import comm_cost_per_round
from repro.core.protocol import ModelSpec
from repro.nn.modules import tree_bytes
from repro.nn.vision import get_vision_model

from .common import FULL

METHODS = ("proxyfl", "fml", "avgpush", "fedavg", "cwt")


def run(full: bool = FULL):
    rows = []
    # paper-scale: LeNet5 private / MLP proxy on MNIST geometry
    vm_priv = get_vision_model("lenet5")
    vm_prox = get_vision_model("mlp")
    pb = tree_bytes(vm_priv.init(jax.random.PRNGKey(0), (28, 28, 1), 10))
    xb = tree_bytes(vm_prox.init(jax.random.PRNGKey(0), (28, 28, 1), 10))
    for K in (4, 8, 16, 32, 64, 128) if full else (4, 8, 32, 128):
        for m in METHODS:
            rows.append({
                "scale": "paper(lenet5/mlp)", "clients": K, "method": m,
                "model_bytes": pb, "proxy_bytes": xb,
                "comm_s_per_round": comm_cost_per_round(m, K, pb, xb),
            })
    # LLM-scale: the common proxy of the assigned archs (what actually
    # crosses the wire in the multi-pod ProxyFL deployment)
    cfg = get_config("qwen2-7b")
    proxy = proxy_of(cfg)
    priv_b = cfg.param_counts()["total"] * 2        # bf16
    prox_b = proxy.param_counts()["total"] * 2
    for K in (8, 64, 512):
        for m in METHODS:
            rows.append({
                "scale": "llm(qwen2-7b/proxy)", "clients": K, "method": m,
                "model_bytes": priv_b, "proxy_bytes": prox_b,
                "comm_s_per_round": comm_cost_per_round(m, K, priv_b, prox_b),
            })
    return rows
