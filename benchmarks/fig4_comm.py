"""Paper Fig. 4 / Fig. 13: communication per round — method x compression.
Centralized schemes (FedAvg, FML) serialize at the server → O(K);
decentralized PushSum sends exactly one model per client → O(1). We report
the analytic link model (bytes / 50 GB/s ICI-class links) over the REAL
serialized sizes of the models used in the paper reproduction, plus the
LLM-scale proxies used in the multi-pod path — now crossed with the
compressed-exchange wire formats of ``repro.core.compress``: every row
carries the MEASURED bytes-on-wire of one transmission (the top-k payload
is the observed nonzero count of a real encode on the actual flat
parameter vector, not just the analytic formula) so the O(1)-per-client
claim is checked on what actually ships. Rows are also written as JSON
(``REPRO_BENCH_COMM_JSON``, default ``fig4_comm.json`` in the CWD) for
``scripts/check_comm_claim.py``, the CI gate that fails if ProxyFL's
per-client bytes/round ever grows with K."""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.registry import proxy_of
from repro.core.compress import (CompressionSpec, encode_decode, topk_k,
                                 wire_bytes)
from repro.core.gossip import comm_cost_per_round
from repro.nn.modules import tree_bytes, tree_flatten_vector
from repro.nn.vision import get_vision_model

from .common import FULL

METHODS = ("proxyfl", "fml", "avgpush", "fedavg", "cwt")
COMPRESS = ("none", "topk", "int8")
RATIO = 0.25  # top-k kept fraction — fig_compress.py sweeps accuracy at it


def _measured_wire_bytes(flat: np.ndarray, mode: str,
                         ratio: float = RATIO) -> int:
    """Bytes ONE client puts on the wire for one message, measured by
    running the codec on a real flat parameter vector: top-k's payload is
    the observed nonzero count of the decoded transmission (position
    bitmap + 2 bytes per bf16 value — entries that round to bf16 zero cost
    their bitmap bit but ship no value); int8 and none are structural
    (payload size is fixed by construction, independent of the values)."""
    D = int(flat.shape[0])
    if mode == "topk":
        spec = CompressionSpec(mode="topk", ratio=ratio)
        c = encode_decode(jnp.asarray(flat, jnp.float32)[None, :],
                          jax.random.PRNGKey(0), spec)
        nnz = int(np.count_nonzero(np.asarray(c)))
        assert nnz <= topk_k(D, ratio), (nnz, topk_k(D, ratio))
        return (D + 7) // 8 + 2 * nnz
    return wire_bytes(mode, D, ratio)


def _rows_for(scale: str, clients, model_wire, proxy_wire, pb, xb,
              dtype_bytes: int):
    """One row per (K, method, compression mode): compression applies to
    whatever the method gossips — bytes_per_round is the serialized
    traffic at the bottleneck node (server for FedAvg/FML, any single
    client for the decentralized schemes)."""
    rows = []
    for K in clients:
        for m in METHODS:
            for cm in COMPRESS:
                mbw, xbw = model_wire[cm], proxy_wire[cm]
                rows.append({
                    "scale": scale, "clients": K, "method": m,
                    "compress": cm, "dtype_bytes": dtype_bytes,
                    "model_bytes": pb, "proxy_bytes": xb,
                    "wire_model_bytes": mbw, "wire_proxy_bytes": xbw,
                    "bytes_per_round": int(comm_cost_per_round(
                        m, K, mbw, xbw, link_bandwidth=1.0)),
                    "comm_s_per_round": comm_cost_per_round(m, K, mbw, xbw),
                })
    return rows


def run(full: bool = FULL):
    # paper-scale: LeNet5 private / MLP proxy on MNIST geometry — wire
    # bytes MEASURED on the real initialized flats
    vm_priv = get_vision_model("lenet5")
    vm_prox = get_vision_model("mlp")
    priv_p = vm_priv.init(jax.random.PRNGKey(0), (28, 28, 1), 10)
    prox_p = vm_prox.init(jax.random.PRNGKey(1), (28, 28, 1), 10)
    priv_flat = np.asarray(tree_flatten_vector(priv_p))
    prox_flat = np.asarray(tree_flatten_vector(prox_p))
    rows = _rows_for(
        "paper(lenet5/mlp)",
        (4, 8, 16, 32, 64, 128) if full else (4, 8, 32, 128),
        {cm: _measured_wire_bytes(priv_flat, cm) for cm in COMPRESS},
        {cm: _measured_wire_bytes(prox_flat, cm) for cm in COMPRESS},
        tree_bytes(priv_p), tree_bytes(prox_p), dtype_bytes=4)
    # LLM-scale: the common proxy of the assigned archs (what actually
    # crosses the wire in the multi-pod ProxyFL deployment) — analytic
    # param counts, bf16 full-precision baseline
    cfg = get_config("qwen2-7b")
    proxy = proxy_of(cfg)
    Dp = cfg.param_counts()["total"]
    Dx = proxy.param_counts()["total"]
    rows += _rows_for(
        "llm(qwen2-7b/proxy)", (8, 64, 512),
        {cm: wire_bytes(cm, Dp, RATIO, dtype_bytes=2) for cm in COMPRESS},
        {cm: wire_bytes(cm, Dx, RATIO, dtype_bytes=2) for cm in COMPRESS},
        Dp * 2, Dx * 2, dtype_bytes=2)
    path = os.environ.get("REPRO_BENCH_COMM_JSON", "fig4_comm.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return rows
