"""Paper Fig. 8 / Table 2: Camelyon-17 histopathology — 4 institutions,
binary (healthy vs tumor), sigma=1.4, C=0.7, delta=1e-5, batch 32,
alpha=beta=0.3. Synthetic binary stand-in with the paper's client sizes.

Two things are validated: (i) the accuracy ordering
(ProxyFL > FML ≥ FedAvg/AvgPush/CWT > Regular, Joint on top), and (ii) the
PRIVACY GUARANTEES — our RDP accountant must reproduce the paper's
per-client epsilons (Table 2 right: 2.36 / 2.17 / 2.08 / 2.12, Joint 1.00)
from the real training-set sizes, since those are pure mathematics."""
from __future__ import annotations

from repro.core.accountant import epsilon_for

from .common import FULL, bench_methods

TRAIN_SIZES = {"C1": 2338, "C2": 2726, "C3": 2937, "C4": 2841}
PAPER_EPS = {"C1": 2.36, "C2": 2.17, "C3": 2.08, "C4": 2.12, "Joint": 1.00}


def run(full: bool = FULL):
    rows = []
    # (ii) privacy guarantees — exact reproduction of Table 2 (right)
    for c, n in TRAIN_SIZES.items():
        eps = epsilon_for(noise_multiplier=1.4, sample_rate=32 / n,
                          steps=30 * (n // 32), delta=1e-5)
        rows.append({"table": "privacy", "client": c, "epsilon": round(eps, 3),
                     "paper_epsilon": PAPER_EPS[c],
                     "rel_err": round(abs(eps - PAPER_EPS[c]) / PAPER_EPS[c], 3)})
    n_joint = sum(TRAIN_SIZES.values())
    eps_j = epsilon_for(noise_multiplier=1.4, sample_rate=32 / n_joint,
                        steps=30 * (n_joint // 32), delta=1e-5)
    rows.append({"table": "privacy", "client": "Joint",
                 "epsilon": round(eps_j, 3), "paper_epsilon": PAPER_EPS["Joint"],
                 "rel_err": round(abs(eps_j - 1.0), 3)})

    # (i) accuracy ordering on the synthetic stand-in
    rows += [dict(r, table="accuracy") for r in bench_methods(
        "camelyon",
        ("proxyfl", "fml", "avgpush", "fedavg", "cwt", "regular", "joint"),
        n_clients=4,
        rounds=30 if full else 3,
        seeds=range(15) if full else (0,),
        batch_size=32,
        sigma=1.4, clip=0.7, alpha=0.3,
        private_arch="cnn1" if full else "mlp",
        proxy_arch="cnn1" if full else "mlp",
        n_train_factor=1.0 if full else 0.5,
    )]
    return rows
