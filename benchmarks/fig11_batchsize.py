"""Paper Fig. 11: effect of batch size on the privacy guarantee — smaller
batches (lower sampling rate q) give dramatically stronger (eps, delta)
at equal epochs. Pure accountant math."""
from __future__ import annotations

from repro.core.accountant import epsilon_for

from .common import FULL


def run(full: bool = FULL):
    n = 1000  # per-client training set size (paper MNIST setting)
    epochs = 30
    rows = []
    for b in (10, 25, 50, 125, 250):
        steps = epochs * max(1, n // b)
        rows.append({
            "batch_size": b, "sample_rate": b / n, "steps": steps,
            "epsilon": round(epsilon_for(noise_multiplier=1.0,
                                         sample_rate=b / n, steps=steps,
                                         delta=1e-5), 3),
        })
    return rows
