"""Paper Fig. 3 (+ Fig. 9 macro-accuracy): test accuracy of all methods on
the three benchmark datasets under DP training, 8 clients, non-IID skew.
Synthetic class-conditional stand-ins replace MNIST/FaMNIST/CIFAR-10
offline; the claim validated is the ORDERING:
ProxyFL-private ≥ FML-private > decentralized singles ≥ centralized
singles ≥ Regular, with Joint as the upper bound."""
from __future__ import annotations

from .common import FULL, bench_methods

METHODS = ("proxyfl", "fml", "avgpush", "fedavg", "cwt", "regular", "joint")


def run(full: bool = FULL):
    rows = []
    datasets = ("mnist", "famnist", "cifar10") if full else ("mnist", "cifar10")
    for ds in datasets:
        rows += bench_methods(
            ds, METHODS,
            n_clients=8 if full else 4,
            rounds=30 if full else 3,
            seeds=range(5) if full else (0,),
            n_train_factor=1.0 if full else 0.4,
        )
    return rows
