"""Benchmark harness: one module per paper table/figure. Prints CSV rows.

    PYTHONPATH=src python -m benchmarks.run            # CPU-budget settings
    REPRO_BENCH_FULL=1 python -m benchmarks.run        # paper-scale settings
    PYTHONPATH=src python -m benchmarks.run --only fig4_comm,fig11_batchsize
    PYTHONPATH=src python -m benchmarks.run --list     # registry + one-liners
"""
from __future__ import annotations

import argparse
import csv
import io
import sys
import time

from . import (fig3_accuracy, fig4_comm, fig5_ablations, fig6_kvasir,
               fig11_batchsize, fig_async, fig_blocks, fig_compress,
               fig_dropout, fig_hier, fig_kernels, fig_ragged, mia_privacy,
               roofline, table2_histo)

# name -> (module, paper anchor, runtime tier). The one-line description
# shown by ``--list`` is each module's own docstring first line, so
# registry and docs cannot drift apart; tests assert every fig_* file on
# disk is here. The TIER is the CI contract: "fast" figures finish in CPU
# minutes at default settings and are run by the non-gating baseline step
# (scripts/bench_baseline.py selects them FROM THIS FIELD — CI never
# hard-codes module names); "full" figures are accuracy sweeps that only
# make sense at paper scale.
MODULES = {
    "fig3_accuracy": (fig3_accuracy, "Fig. 3 / Fig. 9", "full"),
    "fig4_comm": (fig4_comm, "Fig. 4 / Fig. 13", "full"),
    "fig5_ablations": (fig5_ablations, "Fig. 5 a-c / Fig. 12", "full"),
    "fig6_kvasir": (fig6_kvasir, "Fig. 6", "full"),
    "table2_histo": (table2_histo, "Fig. 8 / Table 2", "full"),
    "fig11_batchsize": (fig11_batchsize, "Fig. 11", "full"),
    "fig_ragged": (fig_ragged, "beyond-paper", "full"),
    "fig_blocks": (fig_blocks, "beyond-paper", "fast"),
    "fig_kernels": (fig_kernels, "beyond-paper", "fast"),
    "fig_hier": (fig_hier, "beyond-paper", "fast"),
    "fig_compress": (fig_compress, "beyond-paper", "full"),
    "fig_async": (fig_async, "beyond-paper", "full"),
    "fig_dropout": (fig_dropout, "paper §3.4", "full"),
    "mia_privacy": (mia_privacy, "beyond-paper", "full"),
    "roofline": (roofline, "§Roofline", "full"),
}

TIERS = ("fast", "full")


def names_for_tier(tier: str) -> list:
    """Registry names whose runtime tier is ``tier`` — the programmatic
    hook CI slices use instead of hard-coding module names."""
    if tier not in TIERS:
        raise ValueError(f"unknown tier {tier!r}; expected one of {TIERS}")
    return [n for n, (_, _, t) in MODULES.items() if t == tier]


def _describe(name: str) -> str:
    mod, anchor, tier = MODULES[name]
    first = (mod.__doc__ or "").strip().splitlines()
    return (f"{name}: [{anchor}] ({tier}) "
            f"{first[0] if first else '(no docstring)'}")


def list_benchmarks() -> list:
    """Registry listing, one line per benchmark (also the --list output)."""
    return [_describe(name) for name in MODULES]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default="",
                    help="comma-separated subset of benchmark names")
    ap.add_argument("--list", action="store_true",
                    help="print every registered benchmark with its "
                         "one-line description and runtime tier, and exit")
    ap.add_argument("--tier", choices=TIERS, default="",
                    help="run only benchmarks of this runtime tier (CI's "
                         "non-gating baseline step uses --tier fast)")
    ap.add_argument("--full", action="store_true", help="paper-scale settings")
    args = ap.parse_args(argv)
    if args.list:
        for line in list_benchmarks():
            print(line)
        return 0
    names = [n.strip() for n in args.only.split(",") if n.strip()] or list(MODULES)
    if args.tier:
        allowed = set(names_for_tier(args.tier))
        names = [n for n in names if n in allowed]

    failures = 0
    for name in names:
        mod = MODULES[name][0]
        t0 = time.time()
        print(f"\n===== {name} =====", flush=True)
        try:
            rows = mod.run(args.full) if args.full else mod.run()
        except Exception as e:
            print(f"BENCH FAILED {name}: {type(e).__name__}: {e}",
                  file=sys.stderr)
            failures += 1
            continue
        if not rows:
            print("(no rows)")
            continue
        keys = sorted({k for r in rows for k in r})
        buf = io.StringIO()
        w = csv.DictWriter(buf, fieldnames=keys)
        w.writeheader()
        for r in rows:
            w.writerow(r)
        print(buf.getvalue().rstrip())
        print(f"[{name}: {len(rows)} rows in {time.time()-t0:.1f}s]")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
