"""Roofline report: reads the dry-run JSON artifacts written by
``repro.launch.dryrun`` and renders the §Roofline table (three terms per
arch × shape × mesh, dominant bottleneck, MODEL_FLOPS/HLO ratio)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS_DIR = os.environ.get("REPRO_DRYRUN_DIR", "results/dryrun")


def load_rows(results_dir: str = RESULTS_DIR) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def run(full: bool = False) -> List[Dict]:
    out = []
    for r in load_rows():
        if r.get("status") == "skipped":
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "mesh": r["mesh"], "tag": r.get("tag", ""),
                        "status": "skipped", "reason": r.get("reason", "")})
            continue
        if r.get("status") != "ok":
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "mesh": r["mesh"], "tag": r.get("tag", ""),
                        "status": "FAILED", "reason": r.get("error", "")[:80]})
            continue
        rl = r["roofline"]
        ma = r.get("memory_analysis", {})
        hbm = (ma.get("argument_size_in_bytes", 0)
               + ma.get("temp_size_in_bytes", 0)
               - ma.get("alias_size_in_bytes", 0))  # donated args update in place
        out.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "tag": r.get("tag", ""), "status": "ok",
            "program": r["program"],
            "compute_ms": round(rl["compute_s"] * 1e3, 2),
            "memory_ms": round(rl["memory_s"] * 1e3, 2),
            "collective_ms": round(rl["collective_s"] * 1e3, 2),
            "dominant": rl["dominant"],
            "hbm_gib_per_dev": round(hbm / 2**30, 2),
            "fits_16g": hbm < 16 * 2**30,
            "model_flops": f"{r['model_flops']:.3e}",
            "useful_ratio": round(r["useful_flops_ratio"] or 0, 3),
            "modes": str(r.get("sharding_modes")),
        })
    return out


def markdown_table(rows: List[Dict]) -> str:
    ok = [r for r in rows if r.get("status") == "ok"]
    hdr = ("| arch | shape | mesh | program | tag | compute ms | memory ms | "
           "collective ms | dominant | HBM GiB/dev | fits | useful ratio |")
    sep = "|" + "---|" * 12
    lines = [hdr, sep]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"],
                                       r.get("program", ""), r["tag"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r.get('program', '')} | {r['tag']} "
            f"| {r['compute_ms']} | {r['memory_ms']} | {r['collective_ms']} "
            f"| {r['dominant']} | {r['hbm_gib_per_dev']} "
            f"| {'yes' if r['fits_16g'] else 'NO'} | {r['useful_ratio']} |")
    skipped = [r for r in rows if r.get("status") == "skipped"]
    if skipped:
        lines.append("")
        lines.append("Skipped (per DESIGN.md long-context rules): "
                     + ", ".join(f"{r['arch']}×{r['shape']}×{r['mesh']}"
                                 for r in skipped))
    failed = [r for r in rows if r.get("status") == "FAILED"]
    if failed:
        lines.append("")
        lines.append("FAILED: " + ", ".join(
            f"{r['arch']}×{r['shape']}×{r['mesh']}: {r['reason']}" for r in failed))
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table(run()))
