"""Ragged-cohort round time: padded vmap vs Python loop (beyond-paper).

The paper's Dirichlet partitions (§4.3/4.4) give size-skewed clients; this
figure measures what the compiled stacked path buys on exactly that
workload. One engine per backend runs the SAME Dirichlet(0.5) ragged
cohort; rows report steady-state seconds per round (compile excluded —
the first round is warm-up; median of 3 trials against timer noise on
small CPUs) and the vmap speedup, in two regimes:

* ``gossip`` — ``local_steps=1``: one local step then one exchange, the
  communication-bound end of Algorithm 1 (the paper's O(1)-communication
  claim lives here). Step counts are uniform, so raggedness costs only
  the padded device copy and the masked index draw; the loop backend
  pays a host-side ``tree_flatten_vector`` -> matmul -> unflatten round
  trip EVERY round, while the stacked path keeps the PushSum exchange on
  device — vmap beats the loop at K >= 8 (the acceptance bar).
* ``epoch`` — ``local_steps=0``: every client runs its own ``n_k // B``
  steps. The scan still runs the cohort-max step count with exhausted
  clients masked, so at high size skew the stacked path performs wasted
  (masked) work proportional to the pad fraction — the honest tradeoff,
  reported rather than hidden. The loop backend does exactly
  ``sum(n_k // B)`` steps and can win here on skewed CPUs.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.base import DPConfig, ProxyFLConfig
from repro.core.engine import dml_engine

from .common import FULL, federation_data, spec_of


def _time_rounds(engine, data, key, rounds: int, trials: int = 3) -> float:
    state = engine.init_states(key)
    # warm-up round compiles the program (vmap) / per-client steps (loop)
    state, _ = engine.run_round(state, data, 0, jax.random.fold_in(key, 10_000))
    jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
    ts = []
    for _ in range(trials):
        t0 = time.time()
        for t in range(1, rounds + 1):
            state, _ = engine.run_round(state, data, t,
                                        jax.random.fold_in(key, 10_000 + t))
        jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
        ts.append((time.time() - t0) / rounds)
    return float(np.median(ts))


def run(full: bool = FULL):
    n_clients = 16 if full else 8
    rounds = 6 if full else 4
    dataset = "kvasir"  # Dirichlet(0.5) — ragged by construction
    client_data, _, d = federation_data(
        dataset, n_clients, seed=0, n_train_factor=1.0 if full else 0.4)
    sizes = np.asarray([dk[0].shape[0] for dk in client_data])
    spec = spec_of("mlp", d["shape"], d["n_classes"])
    key = jax.random.PRNGKey(0)
    pad_frac = float(1.0 - sizes.sum() / (sizes.max() * n_clients))

    rows = []
    for regime, local_steps in (("gossip", 1), ("epoch", 0)):
        # fixed batch: sampling is with-replacement and the masked sampler
        # bounds indices by n_valid, so batch > n_k is fine for tiny
        # clients — clamping to sizes.min() would explode epoch-mode step
        # counts for the large clients and benchmark a degenerate config
        cfg = ProxyFLConfig(
            n_clients=n_clients, rounds=rounds, local_steps=local_steps,
            batch_size=16, seed=0, dp=DPConfig(enabled=False))
        secs = {}
        for backend in ("loop", "vmap"):
            engine = dml_engine((spec,) * n_clients, spec, cfg,
                                backend=backend)
            secs[backend] = _time_rounds(engine, client_data, key, rounds)
        rows += [{
            "dataset": dataset, "clients": n_clients, "regime": regime,
            "backend": backend,
            "min_client": int(sizes.min()), "max_client": int(sizes.max()),
            "pad_fraction": round(pad_frac, 3),
            "sec_per_round": round(secs[backend], 4),
            "speedup_vs_loop": round(secs["loop"] / secs[backend], 2),
        } for backend in ("loop", "vmap")]
    return rows
