"""Accuracy-vs-bytes Pareto of the compressed proxy exchange (beyond-paper).

The paper's Fig. 4 claim is bytes-per-round; this figure adds the other
axis — what those bytes buy. ProxyFL runs at K ∈ {4, 8, 16} on the
synthetic-MNIST cohort under each wire format of ``repro.core.compress``
("none" | "topk" @ ratio 0.25 | "int8", error feedback on), plus an
uncompressed FedAvg baseline, and every row pairs the final private and
proxy accuracies with the MEASURED bytes of its exchange: per-client
bytes/round (one proxy out + one in for the decentralized schemes),
bottleneck-node bytes/round (the server for FedAvg), and the cumulative
per-client traffic of the whole run. The acceptance numbers this guards:
top-k at ratio 0.25 moves ≥4x fewer bytes than full precision with proxy
accuracy within 2 points at 20 rounds at the claim cohorts (K ≤ 8; the
paper's experiments run 8 clients). K=16 is the scaling stress row: the
6.4x-compressed exchange pays a measured ~4-round consensus delay at the
slowest-mixing cohort (its gap closes fully by 24 rounds) — reported in
the Pareto, gated only for bytes. The copies warm-start at the initial
proxies (one uncompressed setup broadcast, amortized across the run and
excluded from the per-round steady-state bytes the claim is about).

Results are also written as JSON (``REPRO_BENCH_COMPRESS_JSON``, default
``fig_compress.json`` in the CWD) for ``scripts/check_comm_claim.py``.
``REPRO_BENCH_COMPRESS_TINY=1`` shrinks the grid to a single minutes-scale
CI slice (K=4, 2 rounds, 5% data) that exercises every codec end-to-end
without asserting the accuracy gap (2 rounds of a tiny cohort is noise).
"""
from __future__ import annotations

import json
import os
import time

import jax

from repro.core.compress import wire_bytes
from repro.core.gossip import comm_cost_per_round
from repro.nn.modules import tree_flatten_vector

from .common import DATASETS, FULL, _env_flag, bench_methods, spec_of

# (method, compress mode) grid — FedAvg is the uncompressed centralized
# baseline point; compressing it is a different paper's experiment
GRID = (("proxyfl", "none"), ("proxyfl", "topk"), ("proxyfl", "int8"),
        ("fedavg", "none"))
RATIO = 0.25


def run(full: bool = FULL):
    tiny = _env_flag("REPRO_BENCH_COMPRESS_TINY")
    dataset = "mnist"
    cohorts = (4,) if tiny else (4, 8, 16)
    rounds = 2 if tiny else 20
    seeds = (0, 1, 2) if full else (0,)
    # the accuracy claim is about the full synthetic-MNIST cohort — a
    # data-starved slice (ntf << 1) measures small-sample noise, not the
    # codec (the tiny CI slice never asserts accuracy, so it can shrink)
    ntf = 0.05 if tiny else 1.0
    d = DATASETS[dataset]
    # bench_methods gossips the "mlp" arch for both the FedAvg private
    # model and the ProxyFL proxy, so one flat length covers the grid
    D = int(tree_flatten_vector(
        spec_of("mlp", d["shape"], d["n_classes"]).init(
            jax.random.PRNGKey(0))).shape[0])
    rows = []
    for K in cohorts:
        base_client_bytes = None
        for method, mode in GRID:
            t0 = time.time()
            # dp=False: with σ=1.0 on a CPU-budget cohort the proxy's
            # signal is mostly DP noise, and delaying noise through the
            # error-feedback residual measures the DP×compression
            # interaction, not compression — this figure isolates what
            # the codec costs (fig3/fig5 own the DP accuracy story)
            bench = bench_methods(
                dataset, [method], n_clients=K, rounds=rounds, seeds=seeds,
                n_train_factor=ntf, dp=False, compress=mode,
                compress_ratio=RATIO)
            by_method = {r["method"]: r for r in bench}
            wb = wire_bytes(mode, D, RATIO)
            client_bytes = 2 * wb  # one message out + one in per round
            if method == "proxyfl" and mode == "none":
                base_client_bytes = client_bytes
            rows.append({
                "dataset": dataset, "clients": K, "method": method,
                "compress": mode, "ratio": RATIO, "rounds": rounds,
                "acc_mean": by_method[method]["acc_mean"],
                "acc_std": by_method[method]["acc_std"],
                "proxy_acc_mean": by_method.get(
                    method + "-proxy", {}).get("acc_mean"),
                "wire_bytes_per_msg": wb,
                "client_bytes_per_round": client_bytes,
                "bottleneck_bytes_per_round": int(comm_cost_per_round(
                    method, K, wb, wb, link_bandwidth=1.0)),
                "client_bytes_total": client_bytes * rounds,
                "reduction_vs_none": (
                    round(base_client_bytes / client_bytes, 2)
                    if base_client_bytes and method == "proxyfl" else None),
                "seconds": round(time.time() - t0, 1),
            })
    path = os.environ.get("REPRO_BENCH_COMPRESS_JSON", "fig_compress.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return rows
