"""Shared benchmark machinery.

Every per-figure module exposes ``run(full: bool) -> list[dict]``; rows are
printed as CSV by ``benchmarks.run``. ``full=False`` (default) runs a
CPU-budget configuration that preserves the qualitative ordering the paper
reports; ``REPRO_BENCH_FULL=1`` switches to paper-scale settings (8 clients,
5 seeds, full round counts)."""
from __future__ import annotations

import os
import time
from typing import Dict, List, Sequence

import jax
import numpy as np

from repro.configs.base import DPConfig, ProxyFLConfig
from repro.core.baselines import run_federated
from repro.core.protocol import ModelSpec
from repro.data.partition import partition_dirichlet, partition_major
from repro.data.synthetic import make_classification_data
from repro.nn.vision import get_vision_model

FULL = bool(int(os.environ.get("REPRO_BENCH_FULL", "0")))


def _env_int(name: str) -> int:
    raw = os.environ.get(name, "").strip()
    try:
        return int(raw) if raw else 0
    except ValueError:
        raise SystemExit(f"{name} must be an integer, got {raw!r}")


def _env_flag(name: str) -> bool:
    return os.environ.get(name, "").strip().lower() in ("1", "true", "yes",
                                                        "on")

# synthetic stand-ins for the paper's datasets (offline container): same
# image geometry, class count and non-IID partition structure
DATASETS = {
    "mnist": dict(shape=(28, 28, 1), n_classes=10, per_client=1000,
                  p_major=0.8, sep=2.5),
    "famnist": dict(shape=(28, 28, 1), n_classes=10, per_client=1000,
                    p_major=0.8, sep=1.8),
    "cifar10": dict(shape=(32, 32, 3), n_classes=10, per_client=3000,
                    p_major=0.3, sep=0.7),
    "kvasir": dict(shape=(25, 20, 3), n_classes=8, per_client=750,
                   p_major=None, dirichlet=0.5, sep=1.0),
    "camelyon": dict(shape=(32, 32, 3), n_classes=2, per_client=700,
                     p_major=None, dirichlet=1.0, sep=0.4),
}


def spec_of(name: str, shape, n_classes) -> ModelSpec:
    vm = get_vision_model(name)
    return ModelSpec(name, lambda k: vm.init(k, shape, n_classes), vm.apply)


def federation_data(dataset: str, n_clients: int, seed: int, *,
                    n_train_factor: float = 1.0, p_major=None):
    d = DATASETS[dataset]
    key = jax.random.PRNGKey(seed)
    per_client = int(d["per_client"] * n_train_factor)
    n_total = per_client * n_clients * 2
    x, y = make_classification_data(key, n_total, d["shape"], d["n_classes"],
                                    sep=d["sep"], task_seed=hash(dataset) % 997)
    xt, yt = make_classification_data(jax.random.fold_in(key, 1),
                                      1000, d["shape"], d["n_classes"],
                                      sep=d["sep"], task_seed=hash(dataset) % 997)
    rng = np.random.default_rng(seed)
    pm = p_major if p_major is not None else d.get("p_major")
    if pm is not None:
        idxs = partition_major(rng, np.asarray(y), n_clients, per_client, pm,
                               d["n_classes"])
    else:
        idxs = partition_dirichlet(rng, np.asarray(y), n_clients,
                                   d.get("dirichlet", 0.5))
        idxs = [i[:per_client] for i in idxs]
    return [(x[i], y[i]) for i in idxs], (xt, yt), d


def bench_methods(dataset: str, methods: Sequence[str], *, n_clients: int,
                  rounds: int, seeds: Sequence[int], batch_size: int = 250,
                  dp: bool = True, p_major=None, private_arch: str = "mlp",
                  proxy_arch: str = "mlp", alpha: float = 0.5,
                  sigma: float = 1.0, clip: float = 1.0,
                  n_train_factor: float = 1.0,
                  backend: str = None, dropout_rate: float = 0.0,
                  checkpoint_dir: str = None, checkpoint_every: int = 0,
                  resume: bool = None
                  ) -> List[Dict]:
    """``backend`` selects the FederationEngine execution path for every
    figure run ("auto" -> one compiled vmap round program on these
    homogeneous cohorts; override via REPRO_BENCH_BACKEND). ``dropout_rate``
    turns on the §3.4 per-round dropout/join scenario.

    ``checkpoint_dir`` makes every (method, seed) run snapshot its complete
    federation state every ``checkpoint_every`` rounds under
    ``<dir>/<dataset>/<method>_s<seed>``; with ``resume`` a preempted
    benchmark restarts mid-run and finishes bit-identically to an
    uninterrupted one. Env overrides (for figure drivers run as scripts):
    ``REPRO_BENCH_CKPT_DIR``, ``REPRO_BENCH_CKPT_EVERY``,
    ``REPRO_BENCH_RESUME``."""
    backend = backend or os.environ.get("REPRO_BENCH_BACKEND", "auto")
    checkpoint_dir = checkpoint_dir or os.environ.get("REPRO_BENCH_CKPT_DIR")
    checkpoint_every = checkpoint_every or _env_int("REPRO_BENCH_CKPT_EVERY")
    if resume is None:
        resume = _env_flag("REPRO_BENCH_RESUME")
    rows = []
    for method in methods:
        accs, eps_out = [], None
        t0 = time.time()
        for seed in seeds:
            client_data, test, d = federation_data(
                dataset, n_clients, seed, p_major=p_major,
                n_train_factor=n_train_factor)
            priv = spec_of(private_arch, d["shape"], d["n_classes"])
            prox = spec_of(proxy_arch, d["shape"], d["n_classes"])
            cfg = ProxyFLConfig(
                alpha=alpha, beta=alpha, n_clients=n_clients, rounds=rounds,
                batch_size=min(batch_size, client_data[0][0].shape[0]),
                seed=seed, dropout_rate=dropout_rate,
                dp=DPConfig(enabled=dp, noise_multiplier=sigma, clip_norm=clip))
            res = run_federated(
                method, [priv] * n_clients, prox, client_data, test, cfg,
                seed=seed, eval_every=rounds, backend=backend,
                checkpoint_dir=(os.path.join(checkpoint_dir, dataset)
                                if checkpoint_dir else None),
                checkpoint_every=checkpoint_every, resume=resume)
            row = res["history"][-1]
            which = "private_acc" if "private_acc" in row else "acc"
            accs.extend(row[which])
            if method in ("proxyfl", "fml"):
                rows_proxy = row.get("proxy_acc")
            eps_out = res["epsilon"][0]
        rows.append({
            "dataset": dataset, "method": method,
            "acc_mean": float(np.mean(accs)), "acc_std": float(np.std(accs)),
            "epsilon": eps_out, "rounds": rounds, "clients": n_clients,
            "dp": dp, "seconds": round(time.time() - t0, 1),
        })
        if method in ("proxyfl", "fml") and rows_proxy is not None:
            rows.append({
                "dataset": dataset, "method": method + "-proxy",
                "acc_mean": float(np.mean(rows_proxy)),
                "acc_std": float(np.std(rows_proxy)),
                "epsilon": eps_out, "rounds": rounds, "clients": n_clients,
                "dp": dp, "seconds": 0.0,
            })
    return rows
