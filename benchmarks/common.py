"""Shared benchmark machinery.

Every per-figure module exposes ``run(full: bool) -> list[dict]``; rows are
printed as CSV by ``benchmarks.run``. ``full=False`` (default) runs a
CPU-budget configuration that preserves the qualitative ordering the paper
reports; ``REPRO_BENCH_FULL=1`` switches to paper-scale settings (8 clients,
5 seeds, full round counts)."""
from __future__ import annotations

import os
import time
import zlib
from typing import Dict, List, Sequence

import jax
import numpy as np

from repro.configs.base import DPConfig, ProxyFLConfig
from repro.core.baselines import run_federated
from repro.core.protocol import ModelSpec
from repro.data.partition import partition_dirichlet, partition_major
from repro.data.synthetic import make_classification_data
from repro.nn.vision import get_vision_model

FULL = bool(int(os.environ.get("REPRO_BENCH_FULL", "0")))


def _env_int(name: str) -> int:
    raw = os.environ.get(name, "").strip()
    try:
        return int(raw) if raw else 0
    except ValueError:
        raise SystemExit(f"{name} must be an integer, got {raw!r}")


def _env_flag(name: str) -> bool:
    return os.environ.get(name, "").strip().lower() in ("1", "true", "yes",
                                                        "on")

# synthetic stand-ins for the paper's datasets (offline container): same
# image geometry, class count and non-IID partition structure
DATASETS = {
    "mnist": dict(shape=(28, 28, 1), n_classes=10, per_client=1000,
                  p_major=0.8, sep=2.5),
    "famnist": dict(shape=(28, 28, 1), n_classes=10, per_client=1000,
                    p_major=0.8, sep=1.8),
    "cifar10": dict(shape=(32, 32, 3), n_classes=10, per_client=3000,
                    p_major=0.3, sep=0.7),
    "kvasir": dict(shape=(25, 20, 3), n_classes=8, per_client=750,
                   p_major=None, dirichlet=0.5, sep=1.0),
    "camelyon": dict(shape=(32, 32, 3), n_classes=2, per_client=700,
                     p_major=None, dirichlet=1.0, sep=0.4),
}


def spec_of(name: str, shape, n_classes) -> ModelSpec:
    vm = get_vision_model(name)
    return ModelSpec(name, lambda k: vm.init(k, shape, n_classes), vm.apply)


def task_seed_of(dataset: str) -> int:
    """Process-independent task seed for a named dataset. ``hash()`` on
    strings is salted per interpreter (PYTHONHASHSEED), so it would give
    every benchmark process a DIFFERENT synthetic task; crc32 is a stable
    digest of the name."""
    return zlib.crc32(dataset.encode()) % 997


def federation_data(dataset: str, n_clients: int, seed: int, *,
                    n_train_factor: float = 1.0, p_major=None):
    """Per-client train sets + shared test set. Dirichlet datasets
    (kvasir/camelyon) return a RAGGED cohort — true size-skewed client
    sets, exactly as partitioned (the engine's stacked path pads and
    mask-samples them) — instead of truncating every client to
    ``per_client``."""
    d = DATASETS[dataset]
    key = jax.random.PRNGKey(seed)
    per_client = int(d["per_client"] * n_train_factor)
    pm = p_major if p_major is not None else d.get("p_major")
    # the p_major partitioner draws each client's quota from a 2x pool;
    # Dirichlet assigns every sample, so E[client size] == per_client
    # without over-generating
    n_total = per_client * n_clients * (2 if pm is not None else 1)
    task_seed = task_seed_of(dataset)
    x, y = make_classification_data(key, n_total, d["shape"], d["n_classes"],
                                    sep=d["sep"], task_seed=task_seed)
    xt, yt = make_classification_data(jax.random.fold_in(key, 1),
                                      1000, d["shape"], d["n_classes"],
                                      sep=d["sep"], task_seed=task_seed)
    rng = np.random.default_rng(seed)
    if pm is not None:
        idxs = partition_major(rng, np.asarray(y), n_clients, per_client, pm,
                               d["n_classes"])
    else:
        # full Dirichlet size skew preserved — a RAGGED cohort, no
        # truncation; the engine's stacked path pads and mask-samples it
        idxs = partition_dirichlet(rng, np.asarray(y), n_clients,
                                   d.get("dirichlet", 0.5))
        idxs = _ensure_nonempty(rng, idxs)
    return [(x[i], y[i]) for i in idxs], (xt, yt), d


def _ensure_nonempty(rng, idxs):
    """A Dirichlet draw can leave a client with zero samples, which no
    backend can sample from — move one index over from the largest client
    (repeatedly: a single donor pass could itself empty a client)."""
    idxs = [np.asarray(i) for i in idxs]
    if sum(len(i) for i in idxs) < len(idxs):
        raise ValueError("fewer samples than clients — cannot give every "
                         "client at least one example")
    while True:
        empty = [k for k, i in enumerate(idxs) if len(i) == 0]
        if not empty:
            return idxs
        donor = int(np.argmax([len(j) for j in idxs]))
        take = rng.integers(len(idxs[donor]))
        idxs[empty[0]] = idxs[donor][take:take + 1]
        idxs[donor] = np.delete(idxs[donor], take)


def bench_methods(dataset: str, methods: Sequence[str], *, n_clients: int,
                  rounds: int, seeds: Sequence[int], batch_size: int = 250,
                  dp: bool = True, p_major=None, private_arch: str = "mlp",
                  proxy_arch: str = "mlp", alpha: float = 0.5,
                  sigma: float = 1.0, clip: float = 1.0,
                  n_train_factor: float = 1.0,
                  backend: str = None, dropout_rate: float = 0.0,
                  rounds_per_block: int = 0, staleness: int = 0,
                  n_shards: int = 0,
                  checkpoint_dir: str = None, checkpoint_every: int = 0,
                  resume: bool = None, use_pallas: bool = None,
                  compress: str = None, compress_ratio: float = None,
                  verify_commitments: bool = None,
                  local_steps: int = None, lr: float = None,
                  weight_decay: float = None, topology: str = None,
                  min_active: int = None
                  ) -> List[Dict]:
    """``backend`` selects the FederationEngine execution path for every
    figure run ("auto" -> one compiled vmap round program on these
    homogeneous cohorts; override via REPRO_BENCH_BACKEND). ``dropout_rate``
    turns on the §3.4 per-round dropout/join scenario. ``rounds_per_block``
    (env ``REPRO_BENCH_BLOCK``) fuses that many rounds into one compiled
    engine round-block — bit-identical results, fewer host round-trips; 0/1
    keep the historical per-round execution. ``staleness`` (env
    ``REPRO_BENCH_STALENESS``) sets the gossip delay τ of the async and
    hier backends (τ=0 reproduces the vmap backend bit-identically; with
    hier only the cross-shard edges are delayed). ``n_shards`` (env
    ``REPRO_BENCH_SHARDS``) sets the two-level cohort layout of the hier
    backend — n_shards shards mixing block-diagonally on device plus at
    most one sparse cross-shard edge per client per round.

    ``checkpoint_dir`` makes every (method, seed) run snapshot its complete
    federation state every ``checkpoint_every`` rounds under
    ``<dir>/<dataset>/<method>_s<seed>``; with ``resume`` a preempted
    benchmark restarts mid-run and finishes bit-identically to an
    uninterrupted one. Env overrides (for figure drivers run as scripts):
    ``REPRO_BENCH_CKPT_DIR``, ``REPRO_BENCH_CKPT_EVERY``,
    ``REPRO_BENCH_RESUME``. ``use_pallas`` (env ``REPRO_BENCH_PALLAS``)
    runs every figure on the Pallas-fused round hot path — fused gossip
    mix + DP clip→noise→step; allclose to the plain-XLA reference.
    ``compress`` / ``compress_ratio`` (envs ``REPRO_BENCH_COMPRESS``,
    ``REPRO_BENCH_COMPRESS_RATIO``) run every exchange through the
    compressed gossip protocol with error feedback ("none" | "topk" |
    "int8"; see repro.core.compress) — accuracy-vs-bytes tradeoffs are
    measured by ``benchmarks/fig_compress.py``. ``verify_commitments``
    (env ``REPRO_BENCH_VERIFY``) runs every figure with verifiable
    federation on: received proxies are checked against their senders'
    declared commitments before mixing (loop backend) and checkpoint
    restores run in strict commitment mode (repro.core.commit) — the
    verified trajectory is bit-identical to the unverified one."""
    backend = backend or os.environ.get("REPRO_BENCH_BACKEND", "auto")
    rounds_per_block = rounds_per_block or _env_int("REPRO_BENCH_BLOCK") or 1
    staleness = staleness or _env_int("REPRO_BENCH_STALENESS")
    n_shards = n_shards or _env_int("REPRO_BENCH_SHARDS")
    if staleness and backend not in ("async", "hier"):
        # same guard as train.py: a silently-ignored τ would let a sweep
        # report synchronous results as stale-gossip measurements
        raise SystemExit(
            f"staleness={staleness} requires backend='async' or 'hier' "
            f"(got {backend!r}; the synchronous backends deliver every "
            "round) — set REPRO_BENCH_BACKEND=async")
    if n_shards > 1 and backend != "hier":
        raise SystemExit(
            f"n_shards={n_shards} requires backend='hier' "
            f"(got {backend!r}; the flat backends have no shard level) "
            "— set REPRO_BENCH_BACKEND=hier")
    checkpoint_dir = checkpoint_dir or os.environ.get("REPRO_BENCH_CKPT_DIR")
    checkpoint_every = checkpoint_every or _env_int("REPRO_BENCH_CKPT_EVERY")
    if resume is None:
        resume = _env_flag("REPRO_BENCH_RESUME")
    if use_pallas is None:
        use_pallas = _env_flag("REPRO_BENCH_PALLAS")
    if verify_commitments is None:
        verify_commitments = _env_flag("REPRO_BENCH_VERIFY")
    compress = compress or os.environ.get("REPRO_BENCH_COMPRESS", "").strip() \
        or None
    if compress_ratio is None:
        raw = os.environ.get("REPRO_BENCH_COMPRESS_RATIO", "").strip()
        if raw:
            try:
                compress_ratio = float(raw)
            except ValueError:
                raise SystemExit("REPRO_BENCH_COMPRESS_RATIO must be a "
                                 f"float, got {raw!r}")
    # optimizer/topology/participation knobs ride through to ProxyFLConfig
    # verbatim; None keeps the dataclass default (fedlint FED004 requires
    # every config field to be settable from this entry point)
    cfg_extra = {k: v for k, v in dict(
        local_steps=local_steps, lr=lr, weight_decay=weight_decay,
        topology=topology, min_active=min_active).items() if v is not None}
    rows = []
    for method in methods:
        # proxy accuracies accumulate across seeds exactly like ``accs``
        # (and reset per method — no stale binding leaks between methods)
        accs, proxy_accs, eps_out = [], [], None
        t0 = time.time()
        for seed in seeds:
            client_data, test, d = federation_data(
                dataset, n_clients, seed, p_major=p_major,
                n_train_factor=n_train_factor)
            priv = spec_of(private_arch, d["shape"], d["n_classes"])
            prox = spec_of(proxy_arch, d["shape"], d["n_classes"])
            # clamp to the MEAN client size (== per_client in expectation):
            # sampling is with-replacement so batch > n_k is fine for small
            # clients, while clamping to the smallest client would distort
            # every client's batch and explode epoch-mode step counts
            mean_n = int(np.mean([dk[0].shape[0] for dk in client_data]))
            cfg = ProxyFLConfig(
                alpha=alpha, beta=alpha, n_clients=n_clients, rounds=rounds,
                batch_size=max(1, min(batch_size, mean_n)),
                seed=seed, dropout_rate=dropout_rate, staleness=staleness,
                n_shards=n_shards or 1,
                use_pallas=bool(use_pallas),
                verify_commitments=bool(verify_commitments),
                dp=DPConfig(enabled=dp, noise_multiplier=sigma, clip_norm=clip),
                **cfg_extra)
            res = run_federated(
                method, [priv] * n_clients, prox, client_data, test, cfg,
                seed=seed, eval_every=rounds, backend=backend,
                rounds_per_block=rounds_per_block,
                checkpoint_dir=(os.path.join(checkpoint_dir, dataset)
                                if checkpoint_dir else None),
                checkpoint_every=checkpoint_every, resume=resume,
                compress=compress, compress_ratio=compress_ratio)
            row = res["history"][-1]
            which = "private_acc" if "private_acc" in row else "acc"
            accs.extend(row[which])
            if method in ("proxyfl", "fml") and row.get("proxy_acc") is not None:
                proxy_accs.extend(row["proxy_acc"])
            # worst case over clients AND seeds: ragged cohorts give every
            # client its own sample rate/step count, and each seed its own
            # partition, so epsilons genuinely differ
            eps = [e for e in res["epsilon"] if e is not None]
            if eps:
                eps_out = max(eps) if eps_out is None else max(eps_out,
                                                               max(eps))
        rows.append({
            "dataset": dataset, "method": method,
            "acc_mean": float(np.mean(accs)), "acc_std": float(np.std(accs)),
            "epsilon": eps_out, "rounds": rounds, "clients": n_clients,
            "dp": dp, "seconds": round(time.time() - t0, 1),
        })
        if proxy_accs:
            rows.append({
                "dataset": dataset, "method": method + "-proxy",
                "acc_mean": float(np.mean(proxy_accs)),
                "acc_std": float(np.std(proxy_accs)),
                "epsilon": eps_out, "rounds": rounds, "clients": n_clients,
                "dp": dp, "seconds": 0.0,
            })
    return rows
