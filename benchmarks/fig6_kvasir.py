"""Paper Fig. 6: gastrointestinal disease detection (Kvasir) — 8 classes,
8 clients, Dirichlet(0.5) partition, batch 128, VGG-small private+proxy.
Synthetic 8-class stand-in with the same partition structure. Claim
validated: decentralized (ProxyFL-proxy / AvgPush) learn where centralized
(FedAvg / FML-proxy) stall under DP."""
from __future__ import annotations

from .common import FULL, bench_methods


def run(full: bool = FULL):
    return bench_methods(
        "kvasir",
        ("proxyfl", "fml", "avgpush", "fedavg", "regular", "joint"),
        n_clients=8 if full else 4,
        rounds=30 if full else 3,
        seeds=range(5) if full else (0,),
        batch_size=128,
        private_arch="vgg_small" if full else "mlp",
        proxy_arch="vgg_small" if full else "mlp",
        n_train_factor=1.0 if full else 0.4,
    )
