"""Beyond-paper (the paper's own future-work ask): EMPIRICAL validation of
the DP guarantee via membership-inference attacks.

Runs a ProxyFL federation on the MNIST-like task, then attacks (a) each
client's RELEASED proxy (DP-SGD-trained — the only artifact an adversary
ever sees) and (b) the PRIVATE model (non-DP, never released), using the
loss-threshold MIA of Yeom et al. against each client's own training set.
Expectation: proxy AUC ≈ 0.5 (the (eps, delta) guarantee holds up
empirically), private AUC > proxy AUC (which is precisely why it must not
be released)."""
from __future__ import annotations

import numpy as np

from repro.configs.base import DPConfig, ProxyFLConfig
from repro.core.attacks import loss_threshold_mia
from repro.core.baselines import run_federated

from .common import FULL, federation_data, spec_of


def run(full: bool = FULL):
    n = 8 if full else 4
    client_data, test, d = federation_data("mnist", n, 0,
                                           n_train_factor=1.0 if full else 0.3)
    # per-client member/non-member split from the SAME skewed local
    # distribution — comparing members against the IID test set would
    # measure distribution inference (the client's class skew), not
    # example membership
    train_halves, holdouts = [], []
    rng = np.random.default_rng(7)
    for x, y in client_data:
        # shuffle before splitting: partition_major places the major-class
        # examples first, so a raw half-split would NOT be exchangeable and
        # the attack would measure class composition instead of membership
        perm = rng.permutation(x.shape[0])
        x, y = x[perm], y[perm]
        h = x.shape[0] // 2
        train_halves.append((x[:h], y[:h]))
        holdouts.append((x[h:], y[h:]))
    spec = spec_of("mlp", d["shape"], d["n_classes"])
    # a regime where the guarantee is MEANINGFUL (eps ~ 2): sigma=2, low
    # sampling rate — the paper's Fig. 11 lever. The same federation is run
    # with DP on and off so the proxy comparison isolates what DP buys.
    results = {}
    for dp in (True, False):
        cfg = ProxyFLConfig(n_clients=n, rounds=30 if full else 4,
                            batch_size=25,
                            dp=DPConfig(enabled=dp, noise_multiplier=2.0,
                                        clip_norm=0.5))
        results[dp] = run_federated("proxyfl", [spec] * n, spec, train_halves,
                                    test, cfg, eval_every=cfg.rounds)
    rows = []
    for k in range(n):
        members = train_halves[k]
        auc_dp = loss_threshold_mia(
            spec.apply, results[True]["clients"][k].proxy_params,
            members, holdouts[k])
        auc_nodp = loss_threshold_mia(
            spec.apply, results[False]["clients"][k].proxy_params,
            members, holdouts[k])
        auc_priv = loss_threshold_mia(
            spec.apply, results[True]["clients"][k].private_params,
            members, holdouts[k])
        rows.append({"client": k,
                     "mia_auc_proxy_dp": round(auc_dp, 4),
                     "mia_auc_proxy_no_dp": round(auc_nodp, 4),
                     "mia_auc_private_nonreleased": round(auc_priv, 4),
                     "epsilon": round(results[True]["epsilon"][k], 3)})
    rows.append({"client": "mean",
                 "mia_auc_proxy_dp": round(float(np.mean(
                     [r["mia_auc_proxy_dp"] for r in rows])), 4),
                 "mia_auc_proxy_no_dp": round(float(np.mean(
                     [r["mia_auc_proxy_no_dp"] for r in rows])), 4),
                 "mia_auc_private_nonreleased": round(float(np.mean(
                     [r["mia_auc_private_nonreleased"] for r in rows])), 4),
                 "epsilon": rows[0]["epsilon"]})
    return rows
