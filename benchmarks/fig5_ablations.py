"""Paper Fig. 5 (+ Fig. 12): ablations on the MNIST-like task.

(a) non-IID skew sweep (p_major), (b) heterogeneous private architectures,
(c) DP on/off, (d) DML weight alpha sweep (Fig. 12)."""
from __future__ import annotations

import numpy as np

from repro.configs.base import DPConfig, ProxyFLConfig
from repro.core.baselines import run_federated

from .common import FULL, bench_methods, federation_data, spec_of


def _skew(full):
    rows = []
    for pm in ((0.1, 0.3, 0.5, 0.8) if full else (0.1, 0.8)):
        for m in ("proxyfl", "regular", "joint") if not full else (
                "proxyfl", "fml", "avgpush", "fedavg", "cwt", "regular", "joint"):
            rows += [dict(r, sweep="skew", p_major=pm) for r in bench_methods(
                "mnist", (m,), n_clients=8 if full else 4,
                rounds=30 if full else 3, seeds=range(5) if full else (0,),
                p_major=pm, n_train_factor=1.0 if full else 0.4)]
    return rows


def _hetero(full):
    """Every two clients use a different private architecture (Fig. 5b)."""
    n = 4
    client_data, test, d = federation_data("mnist", n, 0,
                                           n_train_factor=1.0 if full else 0.4)
    archs = ("mlp", "lenet5", "cnn1", "cnn2")
    specs = [spec_of(a, d["shape"], d["n_classes"]) for a in archs]
    proxy = spec_of("mlp", d["shape"], d["n_classes"])
    cfg = ProxyFLConfig(n_clients=n, rounds=30 if full else 3,
                        batch_size=250, dp=DPConfig(enabled=True))
    res = run_federated("proxyfl", specs, proxy, client_data, test, cfg,
                        eval_every=cfg.rounds)
    row = res["history"][-1]
    out = []
    for k, a in enumerate(archs):
        out.append({"sweep": "hetero", "arch": a, "method": "proxyfl",
                    "acc_mean": float(row["private_acc"][k])})
    # Regular baseline per architecture
    for k, a in enumerate(archs):
        r = run_federated("regular", [specs[k]] * n, specs[k], client_data,
                          test, cfg, eval_every=cfg.rounds)
        out.append({"sweep": "hetero", "arch": a, "method": "regular",
                    "acc_mean": float(np.mean(r["history"][-1]["acc"]))})
    return out


def _dp_onoff(full):
    rows = []
    for dp in (True, False):
        rows += [dict(r, sweep="dp") for r in bench_methods(
            "mnist", ("proxyfl", "fedavg", "regular", "joint"),
            n_clients=8 if full else 4, rounds=30 if full else 3,
            seeds=range(5) if full else (0,), dp=dp,
            n_train_factor=1.0 if full else 0.4)]
    return rows


def _alpha(full):
    rows = []
    for a in ((0.1, 0.3, 0.5, 0.7, 0.9) if full else (0.1, 0.9)):
        rows += [dict(r, sweep="alpha", alpha=a) for r in bench_methods(
            "mnist", ("proxyfl",), n_clients=4, rounds=30 if full else 3,
            seeds=range(5) if full else (0,), alpha=a,
            n_train_factor=1.0 if full else 0.4)]
    return rows


def run(full: bool = FULL):
    return _skew(full) + _hetero(full) + _dp_onoff(full) + _alpha(full)
