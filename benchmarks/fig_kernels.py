"""Pallas-fused round hot path: rounds/sec fused vs plain (beyond-paper).

The two chains that dominate a round's HBM traffic are the PushSum
exchange (P·z matmul → P·w matmul → de-bias divide: three materialized
[K, D]-sized passes under plain XLA) and the DP proxy update (per-example
clip → accumulate → noise → Adam step — each a full pass over the
gradient vector). ``ProxyFLConfig.use_pallas`` fuses both into blocked
kernels (``repro.kernels``) that touch each parameter chunk ONCE per
round. This figure measures the end-to-end effect: rounds/sec plain vs
fused on identical DP cohorts at K ∈ {4, 8, 16}, plus the analytic
bytes-moved-per-round of each exchange path.

Bytes model (f32, D = proxy parameter count, exchange only):

* plain    — read [K,D] + write P·z [K,D], then read it back + write the
  de-biased [K,D]: ``4·B_D`` moved where ``B_D = 4·K·D`` bytes (the two
  [K]-sized weight passes are noise);
* fused    — read [K,D] once, write de-biased [K,D] once: ``2·B_D``.

On CPU the fused kernels run in interpret mode, so the measured speedup
there reflects dispatch/fusion differences only — the bytes column is the
portable claim, the TPU rounds/sec the target metric. Results are also
written as JSON (``REPRO_BENCH_KERNELS_JSON``, default
``fig_kernels.json`` in the CWD) including ``speedup_fused`` per cohort.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs.base import DPConfig, ProxyFLConfig
from repro.core.engine import dml_engine
from repro.nn.modules import tree_flatten_vector

from .common import FULL, federation_data, spec_of


def _time_rounds(engine, data, key, rounds: int, trials: int = 3) -> float:
    """Steady-state seconds per round (compile excluded: one warm-up
    block; BEST of ``trials``, as in fig_blocks)."""
    state = engine.init_states(key)
    state, _ = engine.run_rounds(state, data, 0, rounds, key)
    jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
    ts = []
    for _ in range(trials):
        t0 = time.time()
        state, _ = engine.run_rounds(state, data, 0, rounds, key)
        jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
        ts.append((time.time() - t0) / rounds)
    return float(np.min(ts))


def run(full: bool = FULL):
    cohorts = (4, 8, 16)  # the acceptance grid — identical in both budgets
    rounds = 8 if full else 4
    dataset = "mnist"
    key = jax.random.PRNGKey(0)

    rows = []
    for n_clients in cohorts:
        client_data, _, d = federation_data(
            dataset, n_clients, seed=0, n_train_factor=1.0 if full else 0.2)
        spec = spec_of("mlp", d["shape"], d["n_classes"])
        D = int(tree_flatten_vector(
            spec.init(jax.random.PRNGKey(0))).shape[0])
        bytes_kd = 4 * n_clients * D  # one f32 [K, D] pass
        base = None
        for fused in (False, True):
            cfg = ProxyFLConfig(
                n_clients=n_clients, rounds=rounds, local_steps=2,
                batch_size=16, seed=0, use_pallas=fused,
                dp=DPConfig(enabled=True, noise_multiplier=1.0,
                            clip_norm=1.0))
            engine = dml_engine((spec,) * n_clients, spec, cfg,
                                backend="vmap")
            sec = _time_rounds(engine, client_data, key, rounds)
            if not fused:
                base = sec
            rows.append({
                "dataset": dataset, "clients": n_clients, "d_params": D,
                "path": "fused" if fused else "plain",
                "sec_per_round": round(sec, 5),
                "rounds_per_sec": round(1.0 / sec, 2),
                "exchange_bytes_per_round": (2 if fused else 4) * bytes_kd,
                "speedup_fused": round(base / sec, 2),
            })
    path = os.environ.get("REPRO_BENCH_KERNELS_JSON", "fig_kernels.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return rows
