"""Membership-inference attack machinery + the paper's empirical privacy
claim: DP-SGD-trained proxies leak (near-)nothing even when the non-DP
private model memorizes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core.attacks import (auc_from_scores, loss_threshold_mia,
                                per_example_losses)


def test_auc_perfect_separation():
    members = np.asarray([0.1, 0.2, 0.05])
    nonmembers = np.asarray([1.0, 2.0, 3.0])
    assert auc_from_scores(members, nonmembers) == pytest.approx(1.0)


def test_auc_reversed():
    assert auc_from_scores(np.asarray([5.0, 6.0]),
                           np.asarray([0.1, 0.2])) == pytest.approx(0.0)


def test_auc_identical_distributions():
    rng = np.random.default_rng(0)
    s = rng.normal(size=4000)
    auc = auc_from_scores(s[:2000], s[2000:])
    assert abs(auc - 0.5) < 0.05


def test_auc_ties():
    # all-equal scores: exactly chance
    assert auc_from_scores(np.ones(10), np.ones(10)) == pytest.approx(0.5)


def test_auc_empty_side_raises():
    """An empty member or non-member side used to divide by zero (NaN AUC
    propagating into result tables); now it names the broken split."""
    for m, n in ((np.array([]), np.ones(3)), (np.ones(3), np.array([])),
                 (np.array([]), np.array([]))):
        with pytest.raises(ValueError, match="non-empty"):
            auc_from_scores(m, n)


@given(st.integers(0, 10_000))
def test_auc_bounds(seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=20)
    b = rng.normal(size=30)
    auc = auc_from_scores(a, b)
    assert 0.0 <= auc <= 1.0
    # antisymmetry: swapping roles flips around 0.5
    assert auc_from_scores(b, a) == pytest.approx(1.0 - auc, abs=1e-9)


def test_per_example_losses_match_ce():
    from repro.nn.losses import cross_entropy
    k = jax.random.PRNGKey(0)
    logits_w = jax.random.normal(k, (6, 4))

    def apply_fn(p, x):
        return x @ p

    x = jax.random.normal(jax.random.fold_in(k, 1), (32, 6))
    y = jax.random.randint(jax.random.fold_in(k, 2), (32,), 0, 4)
    losses = per_example_losses(apply_fn, logits_w, x, y, batch=8)
    want = float(cross_entropy(x @ logits_w, y))
    assert np.mean(losses) == pytest.approx(want, rel=1e-5)


def test_dp_reduces_membership_leakage():
    """An overfit non-DP model leaks membership; the same model trained
    with DP-SGD leaks (much) less — the mechanism that makes releasing
    ProxyFL proxies safe."""
    from repro.configs.base import DPConfig, ProxyFLConfig
    from repro.core.protocol import ModelSpec, make_ce_step
    from repro.data.synthetic import make_classification_data
    from repro.nn.vision import get_vision_model
    from repro.optim import Adam

    key = jax.random.PRNGKey(0)
    # tiny member set + noisy task → memorization is easy
    xm, ym = make_classification_data(key, 64, (8, 8, 1), 10, sep=0.5,
                                      noise=2.0)
    xn, yn = make_classification_data(jax.random.fold_in(key, 1), 512,
                                      (8, 8, 1), 10, sep=0.5, noise=2.0)
    vm = get_vision_model("mlp")
    spec = ModelSpec("mlp", lambda k: vm.init(k, (8, 8, 1), 10), vm.apply)

    aucs = {}
    for dp in (False, True):
        cfg = ProxyFLConfig(batch_size=32, lr=3e-3,
                            dp=DPConfig(enabled=dp, noise_multiplier=1.5,
                                        clip_norm=0.5))
        step = make_ce_step(spec, cfg, dp)
        params = spec.init(jax.random.PRNGKey(7))
        opt = Adam(lr=cfg.lr, weight_decay=cfg.weight_decay).init(params)
        kk = jax.random.PRNGKey(9)
        for s in range(150):
            kk, kb, kn = jax.random.split(kk, 3)
            idx = jax.random.randint(kb, (32,), 0, xm.shape[0])
            params, opt, _ = step(params, opt, (xm[idx], ym[idx]), kn)
        aucs[dp] = loss_threshold_mia(spec.apply, params, (xm, ym), (xn, yn))

    assert aucs[False] > 0.65, f"non-DP model should leak: {aucs}"
    assert aucs[True] < aucs[False] - 0.1, f"DP should reduce leakage: {aucs}"


def test_gossip_dropout():
    """PushSum with client dropout: inactive clients are untouched; active
    ones still converge to the average of the ACTIVE mass."""
    from repro.core.gossip import adjacency_matrix, debias, pushsum_mix

    K = 8
    active = np.asarray([True] * 6 + [False] * 2)
    thetas = jax.random.normal(jax.random.PRNGKey(0), (K, 3))
    theta_inactive0 = np.asarray(thetas[6:])
    w = jnp.ones((K,))
    for t in range(60):
        P = adjacency_matrix(t, K, "exponential", active=active)
        np.testing.assert_allclose(np.asarray(P).sum(0), 1.0, rtol=1e-9)
        thetas, w = pushsum_mix(thetas, w, P)
    unb = debias(thetas, w)
    target = np.asarray(jnp.mean(thetas[:6], axis=0))
    # inactive rows unchanged
    np.testing.assert_allclose(np.asarray(thetas[6:]), theta_inactive0,
                               atol=1e-5)
    for k in range(6):
        np.testing.assert_allclose(np.asarray(unb[k]), target, atol=1e-4)
