"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see ONE CPU device
(the 512-device override belongs exclusively to repro.launch.dryrun)."""
import jax
import pytest

# `hypothesis` is optional in this container: register the profile only when
# the library exists; property-based tests skip via tests/_hypothesis_compat.
try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:
    pass
else:
    settings.register_profile(
        "repro", max_examples=25, deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
    settings.load_profile("repro")


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def tiny_lm_batch():
    k = jax.random.PRNGKey(1)
    B, S, V = 4, 16, 512
    toks = jax.random.randint(k, (B, S + 1), 0, V)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
