"""Checkpointing, data pipeline and optimizer tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
from repro.data.partition import partition_dirichlet, partition_major
from repro.data.synthetic import make_classification_data, make_lm_data
from repro.optim import Adam
from repro.optim.optimizers import SGD


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32),
                  "d": jnp.asarray(2.5, jnp.bfloat16)}}
    p = os.path.join(tmp_path, "ckpt")
    save_checkpoint(p, tree)
    loaded = load_checkpoint(p, jax.tree_util.tree_map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(loaded)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_partition_major_skew():
    rng = np.random.default_rng(0)
    y = np.repeat(np.arange(10), 500)
    rng.shuffle(y)
    idxs = partition_major(rng, y, n_clients=4, per_client=300,
                           p_major=0.8, n_classes=10)
    assert len(idxs) == 4
    all_idx = np.concatenate(idxs)
    assert len(np.unique(all_idx)) == len(all_idx)  # non-overlapping
    for idx in idxs:
        assert len(idx) == 300
        counts = np.bincount(y[idx], minlength=10)
        assert counts.max() >= 0.7 * 300  # the majority class dominates


def test_partition_major_iid_setting():
    rng = np.random.default_rng(0)
    y = np.repeat(np.arange(10), 500)
    idxs = partition_major(rng, y, 4, 300, p_major=0.1, n_classes=10)
    for idx in idxs:
        counts = np.bincount(y[idx], minlength=10)
        assert counts.max() < 0.3 * 300  # roughly uniform


def test_partition_dirichlet():
    rng = np.random.default_rng(1)
    y = np.repeat(np.arange(8), 750)
    rng.shuffle(y)
    idxs = partition_dirichlet(rng, y, n_clients=8, alpha=0.5)
    assert sum(len(i) for i in idxs) <= len(y)
    assert all(len(i) > 0 for i in idxs)
    flat = np.concatenate(idxs)
    assert len(np.unique(flat)) == len(flat)


def test_classification_data_learnable():
    k = jax.random.PRNGKey(0)
    x, y = make_classification_data(k, 2000, (8, 8, 1), 10, sep=3.0)
    assert x.shape == (2000, 8, 8, 1) and y.shape == (2000,)
    # nearest-centroid classification should beat chance by a wide margin
    xf = x.reshape(2000, -1)
    cents = jnp.stack([xf[y == c].mean(0) for c in range(10)])
    pred = jnp.argmin(((xf[:, None] - cents[None]) ** 2).sum(-1), axis=1)
    assert float((pred == y).mean()) > 0.5


def test_lm_data_domains_differ():
    k = jax.random.PRNGKey(0)
    a = make_lm_data(k, 512, 64, domain=0)
    b = make_lm_data(k, 512, 64, domain=1)
    assert a.shape == (512,)
    assert not np.array_equal(np.asarray(a), np.asarray(b))
    # deterministic per (key, domain)
    a2 = make_lm_data(k, 512, 64, domain=0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a2))


def test_adam_decreases_quadratic():
    opt = Adam(lr=0.1)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 0.01 * l0


def test_adam_weight_decay_additive():
    # paper uses torch-style Adam with additive L2 (not AdamW)
    opt_wd = Adam(lr=1e-3, weight_decay=0.1)
    opt = Adam(lr=1e-3)
    params = {"w": jnp.asarray([1.0])}
    g = {"w": jnp.asarray([0.0])}
    p1, _ = opt_wd.update(g, opt_wd.init(params), params)
    p0, _ = opt.update(g, opt.init(params), params)
    assert float(p1["w"][0]) < float(p0["w"][0])  # decay pulls towards 0


def test_adam_bf16_moments():
    opt = Adam(lr=0.1, moment_dtype="bfloat16")
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state.m["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    p2, s2 = opt.update(g, state, params)
    assert p2["w"].dtype == jnp.bfloat16
    assert float(p2["w"][0]) < 1.0


def test_sgd():
    opt = SGD(lr=0.5)
    params = {"w": jnp.asarray([2.0])}
    g = {"w": jnp.asarray([1.0])}
    p2, _ = opt.update(g, opt.init(params), params)
    np.testing.assert_allclose(np.asarray(p2["w"]), [1.5])
