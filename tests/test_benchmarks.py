"""Benchmark-harness regressions: process-independent synthetic task seeds
(crc32, not salted ``hash()``), ragged Dirichlet federation_data (no
truncation, disjoint, nonempty), per-method proxy-accuracy aggregation
across seeds in ``bench_methods``, and the run.py registry staying in sync
with the fig_* modules on disk."""
import glob
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

import benchmarks.common as common

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.fast
def test_run_registry_lists_every_fig_module(capsys):
    """Every fig_* benchmark module present on disk must be registered in
    ``benchmarks.run.MODULES`` and appear in ``run.py --list`` with a
    one-line description — new figures can't be silently unregistered."""
    import benchmarks.run as run
    on_disk = {os.path.basename(p)[:-3] for p in
               glob.glob(os.path.join(REPO, "benchmarks", "fig*.py"))}
    assert on_disk, "no fig_* modules found — wrong repo layout?"
    missing = on_disk - set(run.MODULES)
    assert not missing, f"fig modules not registered in run.py: {missing}"

    assert run.main(["--list"]) == 0
    out = capsys.readouterr().out
    lines = {l.split(":", 1)[0]: l.split(":", 1)[1].strip()
             for l in out.strip().splitlines()}
    assert set(lines) == set(run.MODULES)
    for name in on_disk:
        assert name in lines, f"{name} absent from --list output"
        # "[anchor] docstring first line" — both halves non-trivial
        assert len(lines[name]) > len("[x] "), f"{name}: empty description"


@pytest.mark.fast
def test_run_registry_tiers_cover_every_module(capsys):
    """Every registry entry carries a runtime tier, the tier shows up in
    ``--list``, and ``names_for_tier`` partitions the registry — the hook
    CI's non-gating baseline step selects figures through (so ci.sh never
    hard-codes module names)."""
    import benchmarks.run as run
    for name, entry in run.MODULES.items():
        assert len(entry) == 3, f"{name}: registry entry missing tier field"
        assert entry[2] in run.TIERS, f"{name}: unknown tier {entry[2]!r}"
    fast = run.names_for_tier("fast")
    full = run.names_for_tier("full")
    assert set(fast) | set(full) == set(run.MODULES)
    assert not set(fast) & set(full)
    # the CI baseline slice: the cheap timing figures, including hier
    assert {"fig_blocks", "fig_kernels", "fig_hier"} <= set(fast)
    with pytest.raises(ValueError, match="tier"):
        run.names_for_tier("nope")
    assert run.main(["--list"]) == 0
    out = capsys.readouterr().out
    for line in out.strip().splitlines():
        name = line.split(":", 1)[0]
        assert f"({run.MODULES[name][2]})" in line, \
            f"{name}: tier absent from --list line"


@pytest.mark.fast
def test_bench_baseline_rows_are_schema_stable():
    """Every figure's rows normalize to the SAME five keys — the artifact
    contract CI archives across commits."""
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "bench_baseline", os.path.join(REPO, "scripts", "bench_baseline.py"))
    bb = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(bb)
    samples = [
        ("fig_blocks", {"clients": 8, "backend": "vmap",
                        "rounds_per_sec": 12.5}),
        ("fig_kernels", {"clients": 4, "path": "fused",
                         "rounds_per_sec": 3.1,
                         "exchange_bytes_per_round": 1024}),
        ("fig_hier", {"K": 256, "backend": "hier", "n_shards": 8,
                      "staleness": 2, "rounds_per_sec": 40.0,
                      "bytes_cross_per_client": 55000.0}),
    ]
    keys = {"figure", "K", "backend", "rounds_per_sec", "bytes_per_round"}
    for figure, row in samples:
        out = bb._normalize(figure, row)
        assert set(out) == keys, f"{figure}: schema drifted: {set(out)}"
    assert bb._normalize(*samples[1])["backend"] == "vmap-fused"
    assert bb._normalize(*samples[2])["backend"] == "hier-s8-t2"
    assert bb._normalize(*samples[2])["bytes_per_round"] == 55000.0
    assert bb._normalize(*samples[0])["bytes_per_round"] is None


def test_task_seed_is_process_independent():
    """``hash(str)`` is salted per interpreter: two processes with
    different PYTHONHASHSEED must still agree on the task seed, or every
    benchmark process trains on a DIFFERENT synthetic dataset."""
    code = ("import sys; sys.path[:0] = ['src', '.'];"
            "from benchmarks.common import task_seed_of;"
            "print(task_seed_of('kvasir'), task_seed_of('camelyon'))")
    outs = []
    for hashseed in ("0", "4242"):
        env = dict(os.environ, PYTHONHASHSEED=hashseed, JAX_PLATFORMS="cpu")
        r = subprocess.run([sys.executable, "-c", code], cwd=REPO, env=env,
                           capture_output=True, text=True, timeout=120)
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout.strip())
    assert outs[0] == outs[1], f"task seed depends on hash salt: {outs}"
    assert outs[0] == (f"{common.task_seed_of('kvasir')} "
                       f"{common.task_seed_of('camelyon')}")


@pytest.mark.fast
def test_federation_data_dirichlet_is_ragged_untruncated():
    data, (xt, yt), d = common.federation_data("kvasir", 4, seed=0,
                                               n_train_factor=0.1)
    sizes = [dk[0].shape[0] for dk in data]
    per_client = int(d["per_client"] * 0.1)
    assert sum(sizes) == per_client * 4      # every partitioned sample kept
    assert len(set(sizes)) > 1               # genuinely size-skewed
    assert min(sizes) >= 1                   # sampleable on every backend
    for dk in data:
        assert dk[0].shape[1:] == d["shape"]


@pytest.mark.fast
def test_ensure_nonempty_moves_sample_from_largest():
    rng = np.random.default_rng(0)
    idxs = [np.arange(10), np.array([], np.int64), np.arange(10, 13)]
    fixed = common._ensure_nonempty(rng, idxs)
    allv = np.concatenate(fixed)
    assert all(len(i) >= 1 for i in fixed)
    assert sorted(allv.tolist()) == list(range(13))  # nothing lost or duped


@pytest.mark.fast
def test_ensure_nonempty_does_not_reempty_donors():
    """Donating must not hollow out an earlier client: [[5], [], []] needs
    repeated passes, not one forward sweep."""
    rng = np.random.default_rng(0)
    idxs = [np.array([5]), np.array([], np.int64), np.array([], np.int64)]
    with pytest.raises(ValueError, match="fewer samples than clients"):
        common._ensure_nonempty(rng, idxs)
    idxs = [np.array([5, 6, 7]), np.array([], np.int64),
            np.array([], np.int64)]
    fixed = common._ensure_nonempty(rng, idxs)
    assert all(len(i) >= 1 for i in fixed)
    assert sorted(np.concatenate(fixed).tolist()) == [5, 6, 7]


@pytest.mark.fast
def test_bench_methods_aggregates_proxy_acc_across_seeds(monkeypatch):
    """The ``-proxy`` row must average over ALL seeds (the old code kept
    only the last seed's value), and must not leak into later methods."""
    def fake_federation_data(dataset, n_clients, seed, **kw):
        x = jnp.zeros((6, 2, 2, 1))
        y = jnp.zeros((6,), jnp.int32)
        return ([(x, y)] * n_clients, (x, y),
                {"shape": (2, 2, 1), "n_classes": 2})

    def fake_run_federated(method, specs, prox, client_data, test, cfg,
                           **kw):
        seed = kw.get("seed", 0)
        if method in ("proxyfl", "fml"):
            row = {"round": 1, "private_acc": [0.5 + seed],
                   "proxy_acc": [0.1 * (seed + 1)]}
        else:
            row = {"round": 1, "acc": [0.3]}
        # seed 0 holds the worst (largest) per-client epsilon
        return {"history": [row], "epsilon": [9.0 - seed, 3.0],
                "clients": []}

    monkeypatch.setattr(common, "federation_data", fake_federation_data)
    monkeypatch.setattr(common, "run_federated", fake_run_federated)
    rows = common.bench_methods("mnist", ("proxyfl", "fedavg"), n_clients=2,
                                rounds=1, seeds=(0, 1), dp=False)
    by_method = {r["method"]: r for r in rows}
    # mean over seeds {0.1, 0.2}, not the last seed's 0.2
    assert by_method["proxyfl-proxy"]["acc_mean"] == pytest.approx(0.15)
    assert by_method["proxyfl"]["acc_mean"] == pytest.approx(1.0)
    assert "fedavg-proxy" not in by_method  # no stale cross-method leak
    assert set(by_method) == {"proxyfl", "proxyfl-proxy", "fedavg"}
    # epsilon: worst case over clients AND seeds (9.0 from seed 0), not
    # the last seed's value
    assert by_method["proxyfl"]["epsilon"] == pytest.approx(9.0)
