"""Cross-backend conformance matrix: ONE table-driven suite asserting that
every execution backend of the FederationEngine — loop, vmap, shard_map
(1-device), async-τ0/τ>0 and the two-level hier backend — agrees across
methods, §3.4 dropout, ragged cohorts and round-block sizes. This file replaces the ad-hoc
pairwise equivalence tests previously scattered across test_engine.py,
test_blocks.py and test_ragged.py.

Two agreement grades, stated per case:

``exact``
    Params AND epsilon bit-identical (``np.testing.assert_array_equal``).
    Holds whenever the two runs execute the SAME compiled program with the
    same inputs: vmap vs async-τ0 (the τ=0 async backend runs the vmap
    round program verbatim), any round-block size vs per-round on one
    backend (blocks only remove host synchronization), and async-τ>0
    blocked vs per-round (the stale core is shared, the buffer rides in
    the scan carry).

``close``
    ``np.testing.assert_allclose(atol=1e-5, rtol=1e-4)``. Documented
    float divergence: the loop backend jits each client's step separately
    while the stacked backends run one vmapped scan — same math, different
    op order/fusion. Epsilon is still compared exactly (the accountant is
    host-side and identical).

Epsilon is part of EVERY comparison: the DP accountant step schedule is a
backend invariant (staleness delays gossip delivery, never local compute,
so sample rates and step counts cannot change — asserted explicitly by
the ``async-t2-epsilon-matches-sync`` case).

The ``pallas-*`` cases pin the Pallas-fused round hot path
(``ProxyFLConfig.use_pallas`` — fused gossip mix + DP clip→noise→step,
interpret mode on CPU): fused vs plain is ``close`` on loop, vmap and
async-τ2 (f32 kernel accumulation — same math, different reduction
order), epsilon stays EXACT (the accountant is host-side and the fused
path never changes step counts), and fused round-blocks stay bit-identical
to fused per-round execution. A run tuple may carry a third element —
``(backend, rounds_per_block, use_pallas)`` — to fuse one side only.

The ``compress-*`` cases pin the compressed proxy exchange
(``ProxyFLConfig.compress`` — top-k / int8 wire formats with error
feedback, repro.core.compress): ``compress="none"`` requested explicitly
through the run_federated override is bit-identical to the default
uncompressed protocol on loop, vmap, async-τ0 AND async-τ2 (the engine
must bypass the compression wrapper entirely, not merely approximate it),
compressed round-blocks of any size are bit-identical to compressed
per-round execution (the error-feedback residual rides the scan carry),
and topk/int8 agree loop-vs-vmap under the ``quantized`` grade below. A
run tuple may carry a FOURTH element — ``(backend, rounds_per_block,
use_pallas, compress)`` — to compress one side only.

``quantized``
    ``np.testing.assert_allclose(atol=5e-2, rtol=0)``, epsilon still
    EXACT (compression never touches the accountant — it gossips, it does
    not train). Used for topk/int8 loop-vs-vmap: the backends' ~1e-6
    float divergence can flip a top-k selection or an int8 rounding
    decision, so agreement is bounded by the quantization granularity,
    not by fp epsilon.

The ``hier-*`` cases pin the two-level [``n_shards`` × clients-per-shard]
backend: ``n_shards=1`` runs the vmap round programs VERBATIM (the
bit-identity anchor the acceptance bar names), ``n_shards>1`` executes
the SAME flat ``mix_schedule`` matrices factored by edge locality
(block-diagonal intra-shard matmul + at-most-one cross-shard edge per
client) — still ``exact`` at τ=0 because the zero cross-block entries the
dense matmul sums contribute exactly 0.0, hier τ>0 round-blocks are
bit-identical to per-round (the cross-shard in-flight buffer rides the
scan carry), epsilon is τ- and shard-invariant, and ``compress="none"``
stays bitwise (n_shards>1 refuses real codecs at construction).

The ``fast``-marked subset is the CI smoke (scripts/ci.sh --fast): it
covers loop==vmap, ragged-on-vmap, block bit-identity, the async-τ0
equivalence smoke, async-τ2 block/resume bit-identity and the compression
parity slice (none-bitwise + topk/int8 loop-vs-vmap) without exceeding
the shard budget.
"""
import dataclasses
import os
from dataclasses import dataclass, field
from typing import Tuple

import jax
import numpy as np
import pytest

from repro.configs.base import DPConfig, ProxyFLConfig
from repro.core.baselines import METHODS, run_federated
from repro.core.engine import (FederationEngine, dml_engine, round_key,
                               single_model_engine)
from repro.core.protocol import ModelSpec
from repro.data.partition import partition_dirichlet
from repro.data.synthetic import make_classification_data
from repro.nn.modules import tree_flatten_vector
from repro.nn.vision import get_vision_model

K, N_CLASSES, SHAPE = 4, 10, (14, 14, 1)


@pytest.fixture(scope="module")
def mlp_spec():
    vm = get_vision_model("mlp")
    return ModelSpec("mlp", lambda k: vm.init(k, SHAPE, N_CLASSES), vm.apply)


@pytest.fixture(scope="module")
def datasets():
    key = jax.random.PRNGKey(0)
    x, y = make_classification_data(key, 1200, SHAPE, N_CLASSES, sep=2.0)
    rect = [(x[i * 300:(i + 1) * 300], y[i * 300:(i + 1) * 300])
            for i in range(K)]
    idxs = partition_dirichlet(np.random.default_rng(0), np.asarray(y), K,
                               0.5)
    ragged = [(x[i], y[i]) for i in idxs]
    assert len({d[0].shape[0] for d in ragged}) > 1, "fixture must be ragged"
    return {"rect": rect, "ragged": ragged}


@pytest.fixture(scope="module")
def run_cache():
    """Memo of completed runs: references (e.g. the vmap B=1 trajectory)
    are shared across every case that compares against them."""
    return {}


# ---------------------------------------------------------------------------
# the matrix


@dataclass(frozen=True)
class Case:
    id: str
    # (backend, rounds_per_block[, use_pallas[, compress]]) of the
    # reference and each candidate run; backend None = run_federated's
    # default ("auto"), the optional third element fuses that run's hot
    # path (default False), the optional fourth sets that run's compress
    # mode override (default None = leave cfg.compress alone)
    ref: Tuple
    cands: Tuple
    expect: str = "exact"   # "exact" | "close" | "epsilon" | "quantized"
    method: str = "proxyfl"
    data: str = "rect"             # "rect" | "ragged"
    fast: bool = False
    cfg: Tuple = field(default=())  # ProxyFLConfig overrides, sorted items


def _c(id, ref, cands, **kw):
    cfg = {k: kw.pop(k) for k in list(kw)
           if k in ("rounds", "local_steps", "dropout_rate", "staleness",
                    "dp", "seed", "use_pallas", "compress",
                    "compress_ratio", "n_shards")}
    return Case(id=id, ref=ref, cands=tuple(cands),
                cfg=tuple(sorted(cfg.items())), **kw)


CASES = [
    # -- loop vs stacked: documented-allclose ------------------------------
    _c("dml-loop-vs-vmap", ("loop", 1), [("vmap", 1)], expect="close",
       fast=True, rounds=2, local_steps=3, dp=True),
    _c("fml-loop-vs-vmap", ("loop", 1), [("vmap", 1)], expect="close",
       method="fml", rounds=2, local_steps=2),
    _c("fedavg-loop-vs-vmap", ("loop", 1), [("vmap", 1)], expect="close",
       method="fedavg", rounds=1, local_steps=2),
    _c("avgpush-loop-vs-vmap", ("loop", 1), [("vmap", 1)], expect="close",
       method="avgpush", rounds=1, local_steps=2),
    _c("cwt-loop-vs-vmap", ("loop", 1), [("vmap", 1)], expect="close",
       method="cwt", rounds=1, local_steps=2),
    _c("regular-loop-vs-vmap", ("loop", 1), [("vmap", 1)], expect="close",
       method="regular", rounds=1, local_steps=2),
    # -- ragged cohorts (epoch mode: padding + masked sampling + per-client
    #    step masks all in play) ------------------------------------------
    _c("ragged-epoch-loop-vs-vmap", ("loop", 1), [("vmap", 1)],
       expect="close", fast=True, data="ragged", rounds=2, local_steps=0,
       dp=True),
    _c("ragged-dropout-auto-vs-loop", ("loop", 1), [(None, 1)],
       expect="close", data="ragged", rounds=2, local_steps=0,
       dropout_rate=0.3, seed=1),
    # -- round-blocks: any block size is bit-identical per backend ---------
    _c("dml-blocks-bitwise", ("vmap", 1), [("vmap", 2), ("vmap", 4)],
       fast=True, rounds=4, local_steps=2, dp=True, dropout_rate=0.25),
    _c("dml-blocks-bitwise-loop", ("loop", 1), [("loop", 2), ("loop", 4)],
       rounds=4, local_steps=2, dp=True, dropout_rate=0.25),
    _c("fedavg-blocks-bitwise", ("vmap", 1), [("vmap", 3)], fast=True,
       method="fedavg", rounds=3, local_steps=1),
    _c("avgpush-blocks-bitwise", ("vmap", 1), [("vmap", 3)],
       method="avgpush", rounds=3, local_steps=1),
    _c("cwt-blocks-bitwise", ("vmap", 1), [("vmap", 3)], fast=True,
       method="cwt", rounds=3, local_steps=1),
    _c("regular-blocks-bitwise", ("vmap", 1), [("vmap", 3)],
       method="regular", rounds=3, local_steps=1),
    _c("joint-blocks-bitwise", (None, 1), [(None, 2)], method="joint",
       rounds=2, local_steps=1),
    _c("ragged-blocks-bitwise", ("vmap", 1), [("vmap", 2)], data="ragged",
       rounds=2, local_steps=0, dp=True),
    # -- async τ=0 == vmap, bit for bit (the acceptance bar) ---------------
    _c("async-t0-vs-vmap", ("vmap", 1), [("async", 1), ("async", 3)],
       fast=True, rounds=3, local_steps=2, dp=True, dropout_rate=0.25),
    _c("async-t0-fml", ("vmap", 1), [("async", 1)], method="fml",
       rounds=2, local_steps=2),
    _c("async-t0-avgpush", ("vmap", 1), [("async", 1)], method="avgpush",
       rounds=2, local_steps=1),
    _c("async-t0-cwt", ("vmap", 1), [("async", 1)], method="cwt",
       rounds=2, local_steps=1),
    _c("async-t0-ragged", ("vmap", 1), [("async", 1), ("async", 2)],
       data="ragged", rounds=2, local_steps=0, dp=True),
    # -- async τ>0: blocked == per-round, bit for bit; epsilon is
    #    τ-invariant (the DP schedule only sees local compute) ------------
    _c("async-t2-blocks-bitwise", ("async", 1), [("async", 2), ("async", 4)],
       fast=True, rounds=4, local_steps=2, dp=True, dropout_rate=0.25,
       staleness=2),
    _c("async-t1-blocks-bitwise", ("async", 1), [("async", 3)],
       rounds=3, local_steps=0, staleness=1, data="ragged"),
    _c("async-t2-epsilon-matches-sync", ("vmap", 1), [("async", 1)],
       expect="epsilon", fast=True, rounds=3, local_steps=2, dp=True,
       dropout_rate=0.25, staleness=2),
    # -- Pallas-fused hot path vs plain XLA: allclose on every matmul-mix
    #    backend, epsilon exact (accountant untouched by fusion) ----------
    _c("pallas-vmap-vs-plain", ("vmap", 1), [("vmap", 1, True)],
       expect="close", fast=True, rounds=2, local_steps=2, dp=True),
    _c("pallas-loop-vs-plain", ("loop", 1), [("loop", 1, True)],
       expect="close", rounds=2, local_steps=2, dp=True),
    _c("pallas-async-t2-vs-plain", ("async", 1), [("async", 1, True)],
       expect="close", rounds=3, local_steps=2, dp=True, staleness=2),
    _c("pallas-ragged-vs-plain", ("vmap", 1), [("vmap", 1, True)],
       expect="close", data="ragged", rounds=2, local_steps=0, dp=True),
    # fused round-blocks == fused per-round, bit for bit (same program)
    _c("pallas-blocks-bitwise", ("vmap", 1), [("vmap", 2), ("vmap", 4)],
       fast=True, rounds=4, local_steps=2, dp=True, use_pallas=True),
    # -- compressed exchange: compress="none" requested explicitly is the
    #    uncompressed protocol VERBATIM on every backend (the engine must
    #    bypass the compression wrapper, not approximate it) -------------
    _c("compress-none-bitwise-sync",
       ("vmap", 1), [("vmap", 1, False, "none"),
                     ("async", 1, False, "none")],
       fast=True, rounds=2, local_steps=2, dp=True),
    _c("compress-none-bitwise-loop", ("loop", 1),
       [("loop", 1, False, "none")], fast=True, rounds=2, local_steps=2,
       dp=True),
    _c("compress-none-bitwise-async-t2", ("async", 1),
       [("async", 1, False, "none")], rounds=3, local_steps=2, dp=True,
       staleness=2),
    # topk/int8 loop vs vmap: agreement bounded by the quantization
    # granularity (a 1e-6 training divergence can flip a selection), with
    # epsilon compared EXACTLY — compression must never touch the
    # accountant
    _c("compress-topk-loop-vs-vmap", ("loop", 1), [("vmap", 1)],
       expect="quantized", fast=True, rounds=2, local_steps=2, dp=True,
       compress="topk"),
    _c("compress-int8-loop-vs-vmap", ("loop", 1), [("vmap", 1)],
       expect="quantized", fast=True, rounds=2, local_steps=2, dp=True,
       compress="int8"),
    # compressed round-blocks == compressed per-round, bit for bit (the
    # error-feedback residual rides the scan carry)
    _c("compress-topk-blocks-bitwise", ("vmap", 1), [("vmap", 2),
                                                     ("vmap", 4)],
       rounds=4, local_steps=2, dp=True, compress="topk",
       compress_ratio=0.1),
    _c("compress-int8-async-t2-blocks-bitwise", ("async", 1),
       [("async", 2), ("async", 4)], rounds=4, local_steps=2, dp=True,
       staleness=2, dropout_rate=0.25, compress="int8"),
    _c("compress-topk-ragged", ("vmap", 1), [("vmap", 2)], data="ragged",
       rounds=2, local_steps=0, dp=True, compress="topk"),
    # -- hier two-level backend: n_shards=1 IS the vmap program (bitwise
    #    anchor); n_shards>1 factors the SAME flat P^(t) block-diagonally
    #    and stays exact at τ=0; τ>0 blocked == per-round with the
    #    cross-shard buffer in the scan carry; epsilon τ/shard-invariant -
    _c("hier-s1-vs-vmap", ("vmap", 1), [("hier", 1), ("hier", 3)],
       fast=True, rounds=3, local_steps=2, dp=True, n_shards=1),
    _c("hier-t0-s2-vs-vmap", ("vmap", 1), [("hier", 1), ("hier", 2)],
       fast=True, rounds=4, local_steps=2, dp=True, dropout_rate=0.25,
       n_shards=2),
    _c("hier-vs-loop", ("loop", 1), [("hier", 1)], expect="close",
       rounds=2, local_steps=2, dp=True, n_shards=2),
    _c("hier-t0-ragged", ("vmap", 1), [("hier", 1), ("hier", 2)],
       data="ragged", rounds=2, local_steps=0, dp=True, n_shards=2),
    _c("hier-t2-blocks-bitwise", ("hier", 1), [("hier", 2), ("hier", 4)],
       fast=True, rounds=4, local_steps=2, dp=True, dropout_rate=0.25,
       staleness=2, n_shards=2),
    _c("hier-t2-epsilon-matches-sync", ("vmap", 1), [("hier", 1)],
       expect="epsilon", rounds=3, local_steps=2, dp=True, staleness=2,
       n_shards=2),
    _c("hier-compress-none-bitwise", ("hier", 1),
       [("hier", 1, False, "none")], rounds=2, local_steps=2, dp=True,
       n_shards=2),
]


def _mk_cfg(case: Case) -> ProxyFLConfig:
    kw = dict(case.cfg)
    dp = kw.pop("dp", False)
    return ProxyFLConfig(
        n_clients=K, batch_size=50,
        dp=DPConfig(enabled=dp, noise_multiplier=1.0, clip_norm=1.0), **kw)


def _final_flats(res):
    out = {}
    for role in ("proxy_params", "private_params", "params"):
        if hasattr(res["clients"][0], role):
            out[role] = np.stack([
                np.asarray(tree_flatten_vector(getattr(c, role)))
                for c in res["clients"]])
    return out


def _run(cache, case: Case, mlp_spec, datasets, backend, rpb,
         pallas=False, comp=None):
    memo_key = (case.method, case.data, case.cfg, backend, rpb, pallas,
                comp)
    if memo_key in cache:
        return cache[memo_key]
    cfg = _mk_cfg(case)
    data = datasets[case.data]
    res = run_federated(case.method, [mlp_spec] * K, mlp_spec, data,
                        data[0], cfg, seed=0, eval_every=cfg.rounds,
                        backend=backend, rounds_per_block=rpb,
                        use_pallas=pallas or None, compress=comp)
    out = {"flats": _final_flats(res),
           "epsilon": tuple(res["epsilon"]),
           "hist_rounds": tuple(r["round"] for r in res["history"])}
    cache[memo_key] = out
    return out


def _case_params():
    return [pytest.param(c, id=c.id,
                         marks=(pytest.mark.fast,) if c.fast else ())
            for c in CASES]


@pytest.mark.parametrize("case", _case_params())
def test_conformance(case, run_cache, mlp_spec, datasets):
    ref = _run(run_cache, case, mlp_spec, datasets, *case.ref)
    for cand in case.cands:
        backend, rpb, pallas, comp = (tuple(cand) + (False, None))[:4]
        got = _run(run_cache, case, mlp_spec, datasets, backend, rpb,
                   pallas, comp)
        label = (f"{case.id}: {case.ref} vs ({backend}, B={rpb}"
                 f"{', pallas' if pallas else ''}"
                 f"{f', compress={comp}' if comp else ''})")
        assert got["epsilon"] == ref["epsilon"], f"{label}: epsilon differs"
        if case.expect == "epsilon":
            continue
        assert got["hist_rounds"] == ref["hist_rounds"], label
        assert set(got["flats"]) == set(ref["flats"]), label
        for role, v in got["flats"].items():
            if case.expect == "exact":
                np.testing.assert_array_equal(
                    ref["flats"][role], v,
                    err_msg=f"{label}: {role} not bit-identical")
            elif case.expect == "quantized":
                np.testing.assert_allclose(
                    ref["flats"][role], v, atol=5e-2, rtol=0,
                    err_msg=f"{label}: {role} outside quantization bound")
            else:
                np.testing.assert_allclose(
                    ref["flats"][role], v, atol=1e-5, rtol=1e-4,
                    err_msg=f"{label}: {role} outside tolerance")


def test_conformance_table_sanity():
    """Every advertised backend AND every METHODS-table entry appears in
    the matrix, and ids are unique — a silently dropped column (or a new
    method added without a conformance row) would hollow the suite out."""
    ids = [c.id for c in CASES]
    assert len(ids) == len(set(ids))
    backends = {run[0] for c in CASES for run in (c.ref,) + c.cands}
    assert {"loop", "vmap", "async", "hier", None} <= backends
    missing = set(METHODS) - {c.method for c in CASES}
    assert not missing, f"METHODS without a conformance case: {missing}"
    assert any(dict(c.cfg).get("staleness") for c in CASES)
    assert any(c.data == "ragged" for c in CASES)
    assert any(c.fast for c in CASES)
    # the fused hot path must keep a column per matmul-mix backend, plus
    # one fused-vs-fused block bit-identity case
    fused_backends = {run[0] for c in CASES for run in (c.ref,) + c.cands
                      if len(run) > 2 and run[2]}
    assert {"loop", "vmap", "async"} <= fused_backends
    assert any(dict(c.cfg).get("use_pallas") for c in CASES)
    # the compressed exchange must keep: a none-bitwise column on every
    # matmul-mix backend (incl. async-τ2), a quantized loop-vs-vmap column
    # per codec, and a compressed block bit-identity case per scan carry
    none_backends = {run[0] for c in CASES for run in (c.ref,) + c.cands
                     if len(run) > 3 and run[3] == "none"}
    assert {"loop", "vmap", "async"} <= none_backends
    assert any(dict(c.cfg).get("compress") == "none"
               or (len(r) > 3 and r[3] == "none")
               for c in CASES for r in (c.ref,) + c.cands
               if dict(c.cfg).get("staleness"))
    comp_modes = {dict(c.cfg).get("compress") for c in CASES}
    assert {"topk", "int8"} <= comp_modes
    assert any(dict(c.cfg).get("compress") and c.expect == "exact"
               and any(r[1] > 1 for r in c.cands) for c in CASES)
    assert any(dict(c.cfg).get("compress") and dict(c.cfg).get("staleness")
               for c in CASES)
    # the hier two-level backend must keep: the n_shards=1 vmap-verbatim
    # anchor, an n_shards>1 EXACT column, a τ>0 block bit-identity case,
    # a ragged column and a compress-none bitwise column
    hier_cases = [c for c in CASES
                  if any(r[0] == "hier" for r in (c.ref,) + c.cands)]
    assert any(dict(c.cfg).get("n_shards") == 1 and c.expect == "exact"
               for c in hier_cases)
    assert any(dict(c.cfg).get("n_shards", 1) > 1 and c.expect == "exact"
               for c in hier_cases)
    assert any(dict(c.cfg).get("staleness")
               and any(r[1] > 1 for r in c.cands) for c in hier_cases)
    assert any(c.data == "ragged" for c in hier_cases)
    assert any(len(r) > 3 and r[3] == "none"
               for c in hier_cases for r in (c.ref,) + c.cands)


@pytest.mark.fast
def test_round_metrics_agree_across_backends(datasets, mlp_spec):
    """Per-round TRAINING metrics (loss trajectories), not just final
    params: async-τ0 must reproduce vmap's metrics bit-for-bit and the
    loop backend must agree within tolerance — on a ragged epoch-mode
    cohort, so padding/step-mask metric gathering is in play too."""
    cfg = ProxyFLConfig(n_clients=K, rounds=2, batch_size=50, local_steps=0,
                        dp=DPConfig(enabled=True))
    key = jax.random.PRNGKey(0)
    results = {}
    for backend in ("loop", "vmap", "async"):
        eng = dml_engine((mlp_spec,) * K, mlp_spec, cfg, backend=backend)
        state = eng.init_states(key)
        state, metrics = eng.run_rounds(state, datasets["ragged"], 0,
                                        cfg.rounds, key)
        results[backend] = metrics
    assert set(results["loop"]) == set(results["vmap"]) \
        == set(results["async"])
    for k in results["vmap"]:
        assert results["vmap"][k].shape == (cfg.rounds, K)
        np.testing.assert_array_equal(results["vmap"][k],
                                      results["async"][k], err_msg=k)
        np.testing.assert_allclose(results["loop"][k], results["vmap"][k],
                                   atol=1e-4, rtol=1e-3, err_msg=k)


# ---------------------------------------------------------------------------
# shard_map column: run_federated cannot construct a mesh, so the 1-device
# conformance runs at engine level (the K=4 collective equivalence runs in
# the forced multi-device subprocess of test_system, if present)


def test_shard_map_k1_matches_vmap_bitwise(datasets, mlp_spec):
    cfg = ProxyFLConfig(n_clients=1, rounds=3, batch_size=50, local_steps=2,
                        dp=DPConfig(enabled=False))
    mesh = jax.make_mesh((1,), ("clients",))
    vmap_eng = single_model_engine(mlp_spec, cfg, False, mix="pushsum",
                                   backend="vmap", n_clients=1)
    key = jax.random.PRNGKey(0)
    data = datasets["rect"][:1]
    finals = {}
    for label in ("vmap", "shard_map"):
        if label == "vmap":
            eng = vmap_eng
        else:
            eng = FederationEngine(
                cfg, n_clients=1, step_fns=vmap_eng.step_fns[0],
                init_fns=vmap_eng.init_fns[0],
                sample_fn=vmap_eng.sample_fn, backend="shard_map",
                mix="pushsum", mesh=mesh, axis="clients")
        state = eng.init_states(key)
        state, _ = eng.run_rounds(state, data, 0, cfg.rounds, key)
        finals[label] = np.asarray(
            jax.vmap(tree_flatten_vector)(state["proxy"]["params"]))
    np.testing.assert_array_equal(finals["vmap"], finals["shard_map"])


def test_hier_engine_k8_s4_matches_vmap_bitwise(mlp_spec):
    """K=8, S=4 at engine level: the exponential shift classes {1, 2, 4}
    exercise every (q, r) = divmod(shift, L) split of the factored
    cross-shard edge — pure cross-permutation (q odd, r=0), intra-only
    (shift < L) and the mixed case — so the blockdiag+scatter execution
    must reproduce the dense vmap matmul bit-for-bit on all of them."""
    cfg = ProxyFLConfig(n_clients=8, rounds=3, batch_size=50, local_steps=1,
                        n_shards=4, dp=DPConfig(enabled=False))
    key = jax.random.PRNGKey(0)
    x, y = make_classification_data(key, 400, SHAPE, N_CLASSES, sep=2.0)
    data = [(x[i * 50:(i + 1) * 50], y[i * 50:(i + 1) * 50])
            for i in range(8)]
    finals = {}
    for backend in ("vmap", "hier"):
        eng = single_model_engine(mlp_spec, cfg, False, mix="pushsum",
                                  backend=backend, n_clients=8)
        state = eng.init_states(key)
        state, _ = eng.run_rounds(state, data, 0, cfg.rounds, key)
        finals[backend] = np.asarray(
            jax.vmap(tree_flatten_vector)(state["proxy"]["params"]))
    np.testing.assert_array_equal(finals["vmap"], finals["hier"])


# ---------------------------------------------------------------------------
# commitment-chain conformance (verifiable federation)


def test_commitment_chain_backend_invariant_at_tau0(tmp_path, datasets,
                                                    mlp_spec):
    """loop, vmap and hier (S=2) snapshots of the same federation must
    produce the IDENTICAL audit trail — same per-leaf digests, same client
    commitments, same hash chain — since commitments are computed from the
    backend-portable canonical payload. lr=0 isolates the exchange: with
    local steps active the loop and stacked backends agree only to ~1e-8
    (XLA fuses the per-step chain differently — the documented-allclose
    rows of CASES), which sha256 cannot absorb; mix-only dynamics are
    bitwise across all three backends, so chain equality here pins the
    commitment layer's backend invariance without conflating it with
    float-fusion divergence."""
    import json

    cfg = ProxyFLConfig(n_clients=K, rounds=2, batch_size=50, local_steps=1,
                        lr=0.0, dp=DPConfig(enabled=True))
    chains = {}
    for backend, shards in (("loop", 1), ("vmap", 1), ("hier", 2)):
        d = os.path.join(str(tmp_path), backend)
        run_federated("proxyfl", [mlp_spec] * K, mlp_spec, datasets["rect"],
                      datasets["rect"][0], cfg, seed=0, eval_every=cfg.rounds,
                      backend=backend, n_shards=shards,
                      checkpoint_dir=d, checkpoint_every=1)
        with open(os.path.join(d, "proxyfl_s0", "audit.jsonl")) as f:
            chains[backend] = [json.loads(line) for line in f]
    assert [e["rounds_done"] for e in chains["vmap"]] == [1, 2]
    assert chains["loop"] == chains["vmap"] == chains["hier"]


# ---------------------------------------------------------------------------
# async invariants beyond pairwise agreement


@pytest.mark.fast
def test_async_stale_mass_conserved_engine_level(datasets, mlp_spec):
    """τ=2 with §3.4 dropout, lr=0 to isolate the exchange: total raw
    PushSum mass Σ z·w and total de-bias weight — clients PLUS the
    in-flight buffer — are conserved every round (the engine-level twin of
    the ``stale_gossip_reference`` property tests)."""
    cfg = ProxyFLConfig(n_clients=K, rounds=4, batch_size=50, local_steps=1,
                        lr=0.0, staleness=2, dp=DPConfig(enabled=False))
    eng = single_model_engine(mlp_spec, cfg, False, mix="pushsum",
                              backend="async")
    key = jax.random.PRNGKey(0)
    state = eng.init_states(key)

    def masses(st):
        z = np.asarray(jax.vmap(tree_flatten_vector)(
            st["clients"]["proxy"]["params"]))
        w = np.asarray(st["clients"]["w"])
        return ((z * w[:, None]).sum() + np.asarray(st["stale_theta"]).sum(),
                w.sum() + np.asarray(st["stale_w"]).sum())

    theta0, w0 = masses(state)
    assert w0 == K  # buffer starts empty, weights at 1
    masks = [np.array([True, False, True, True]),
             np.array([False, True, False, True]),
             None,
             np.array([True, True, False, False])]
    for t, act in enumerate(masks):
        state, _ = eng.run_round(state, datasets["rect"], t,
                                 round_key(key, t), active=act)
        theta_m, w_m = masses(state)
        np.testing.assert_allclose(theta_m, theta0, rtol=1e-5)
        np.testing.assert_allclose(w_m, K, rtol=1e-6)


@pytest.mark.fast
def test_async_t2_kill_resume_bit_identical(tmp_path, datasets, mlp_spec):
    """Kill an async-τ2 federation on a block edge and resume: with τ=2
    the post-resume rounds consume deliveries recorded BEFORE the kill, so
    this passes only if the in-flight buffer round-trips through the
    checkpoint bit-exactly."""
    cfg = ProxyFLConfig(n_clients=K, rounds=4, batch_size=50, local_steps=2,
                        staleness=2, dropout_rate=0.25,
                        dp=DPConfig(enabled=True, noise_multiplier=1.0,
                                    clip_norm=1.0))
    d = os.path.join(str(tmp_path), "ck")
    run = lambda c, **kw: run_federated(
        "proxyfl", [mlp_spec] * K, mlp_spec, datasets["rect"],
        datasets["rect"][0], c, seed=0, eval_every=c.rounds,
        backend="async", rounds_per_block=2, **kw)
    ref = run(cfg)  # uninterrupted, no checkpointing
    ckpt = dict(checkpoint_dir=d, checkpoint_every=2)
    run(dataclasses.replace(cfg, rounds=2), **ckpt)  # "killed" after block 1
    resumed = run(cfg, resume=True, **ckpt)
    for role, v in _final_flats(resumed).items():
        np.testing.assert_array_equal(_final_flats(ref)[role], v,
                                      err_msg=role)
    assert resumed["epsilon"] == ref["epsilon"]


def test_async_staleness_rejects_ring_mix(mlp_spec):
    """CWT's pure-permutation ring keeps no self mass: a delayed delivery
    would leave clients model-less for τ rounds — refused at construction,
    not surfaced as NaNs mid-run."""
    cfg = ProxyFLConfig(n_clients=K, rounds=1, batch_size=50, local_steps=1,
                        staleness=1, dp=DPConfig(enabled=False))
    with pytest.raises(ValueError, match="ring"):
        single_model_engine(mlp_spec, cfg, False, mix="ring",
                            backend="async")
