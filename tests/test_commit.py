"""Verifiable federation — hash-chained proxy commitments & tamper refusal.

Three layers under test: the commitment primitives in ``repro.core.commit``
(chunked leaf digests, client commitments, the hash chain), the
``FederationCheckpointer`` integration (every snapshot stamped and chained
through ``audit.jsonl``, restore REFUSES on any divergence, naming the
first divergent round and leaf), and the in-flight verification hook of
the loop backend (a byzantine-tampered transmitted proxy is refused before
mixing). The tamper matrix here is the acceptance criterion of the
verifiable-federation milestone: bit-flipped npz leaf, truncated audit
trail, reordered meta files and an in-flight bit flip must each produce a
:class:`~repro.core.commit.CommitmentError` (distinct from the config
fingerprint ``ValueError``) that names the offending round/leaf/client.
"""
import dataclasses
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import FederationCheckpointer, config_fingerprint
from repro.configs.base import DPConfig, ProxyFLConfig
from repro.core import commit
from repro.core.attacks import bitflip_proxy
from repro.core.baselines import run_federated
from repro.core.commit import (CHUNK_BYTES, GENESIS, CommitmentError,
                               chain_step, client_commitment, leaf_digest,
                               snapshot_client_digests)
from repro.core.engine import dml_engine
from repro.core.protocol import ModelSpec
from repro.data.synthetic import make_classification_data
from repro.nn.modules import tree_flatten_vector
from repro.nn.vision import get_vision_model

K, N_CLASSES, SHAPE = 4, 10, (14, 14, 1)


@pytest.fixture(scope="module")
def fed_data():
    key = jax.random.PRNGKey(0)
    x, y = make_classification_data(key, 1200, SHAPE, N_CLASSES, sep=2.0)
    return [(x[i * 300:(i + 1) * 300], y[i * 300:(i + 1) * 300])
            for i in range(K)]


@pytest.fixture(scope="module")
def mlp_spec():
    vm = get_vision_model("mlp")
    return ModelSpec("mlp", lambda k: vm.init(k, SHAPE, N_CLASSES), vm.apply)


CFG = ProxyFLConfig(n_clients=K, rounds=2, batch_size=50, local_steps=1,
                    dp=DPConfig(enabled=True))


def _run(spec, data, cfg, backend, **kw):
    return run_federated("proxyfl", [spec] * K, spec, data, data[0], cfg,
                         seed=0, eval_every=cfg.rounds, backend=backend, **kw)


@pytest.fixture(scope="module")
def committed_dir(tmp_path_factory, fed_data, mlp_spec):
    """One real 2-round vmap run with per-round checkpoints — the tamper
    matrix below each copies this directory and corrupts the copy, so a
    single training run serves every case."""
    d = str(tmp_path_factory.mktemp("committed"))
    _run(mlp_spec, fed_data, CFG, "vmap",
         checkpoint_dir=d, checkpoint_every=1)
    return os.path.join(d, "proxyfl_s0")  # run_federated's namespacing


def _copy(src, tmp_path):
    dst = os.path.join(str(tmp_path), "fed")
    shutil.copytree(src, dst)
    return dst


def _recorded_fp(d):
    """The fingerprint run_federated stamped (it folds in method/seed/arch
    context beyond the bare config) — the tamper tests want to get PAST the
    fingerprint gate and hit the commitment chain."""
    for name in sorted(os.listdir(d)):
        if name.endswith(".meta.json"):
            with open(os.path.join(d, name)) as f:
                return json.load(f).get("fingerprint")
    return None


def _restore(d, fed_data, mlp_spec, cfg=CFG, verify=False):
    eng = dml_engine((mlp_spec,) * K, mlp_spec, cfg, backend="vmap")
    key = jax.random.PRNGKey(0)
    ck = FederationCheckpointer(d, every=1, fingerprint=_recorded_fp(d),
                                verify=verify)
    return ck.restore_latest(eng, like=eng.init_states(key), base_key=None)


# ---------------------------------------------------------------------------
# commitment primitives


@pytest.mark.fast
def test_leaf_digest_covers_bytes_shape_dtype_and_chunking():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((8, 16)).astype(np.float32)
    assert leaf_digest(a) == leaf_digest(a.copy())  # deterministic
    flipped = a.copy()
    flipped.view(np.uint32)[0, 0] ^= 1  # one ULP, lowest mantissa bit
    assert leaf_digest(flipped) != leaf_digest(a)
    assert leaf_digest(a.reshape(16, 8)) != leaf_digest(a)   # same bytes
    assert leaf_digest(a.astype(np.float64)) != leaf_digest(a)
    # chunk size is part of the definition, and the chunk loop must cover
    # every byte (incl. the ragged tail and the empty-array edge)
    assert leaf_digest(a, chunk_bytes=64) != leaf_digest(a, chunk_bytes=128)
    assert leaf_digest(np.zeros(0, np.float32))  # no crash, non-empty hex
    assert CHUNK_BYTES == 1 << 20  # changing it silently rewrites history


@pytest.mark.fast
def test_client_commitment_matches_npz_recomputation():
    """A commitment computed from LIVE params equals one recomputed from
    the snapshot arrays under the npz key layout — including the bf16→f32
    canonicalization save_checkpoint applies."""
    params = {"fc1": {"w": jnp.linspace(-1, 1, 12, dtype=jnp.bfloat16)
                      .reshape(3, 4),
                      "b": jnp.arange(4, dtype=jnp.float32)}}
    digest, leaves = client_commitment(params)
    flat = {f"clients/c0002/proxy/params/{p}": np.asarray(
        a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a)
        for p, a in (("fc1/w", params["fc1"]["w"]),
                     ("fc1/b", params["fc1"]["b"]))}
    digests, leaves_out = snapshot_client_digests(flat, 3)
    assert digests["c0002"] == digest
    assert leaves_out["c0002"] == leaves
    assert leaves_out["c0000"] == {}  # absent clients digest the empty tree


@pytest.mark.fast
def test_chain_step_depends_on_every_input():
    d = {"c0000": "a" * 64, "c0001": "b" * 64}
    h = chain_step(GENESIS, 1, 2, d)
    assert h != chain_step("1" * 64, 1, 2, d)
    assert h != chain_step(GENESIS, 2, 2, d)
    assert h != chain_step(GENESIS, 1, 3, d)
    assert h != chain_step(GENESIS, 1, 2, {**d, "c0001": "c" * 64})
    assert h == chain_step(GENESIS, 1, 2, dict(reversed(d.items())))


@pytest.mark.fast
def test_commitment_error_is_distinct_and_carries_location():
    e = CommitmentError("boom", round=3, leaf="proxy/params/fc1/w", client=1)
    assert isinstance(e, ValueError)  # callers catching ValueError still do
    assert (e.round, e.leaf, e.client) == (3, "proxy/params/fc1/w", 1)
    assert CommitmentError("x").round is None


# ---------------------------------------------------------------------------
# checkpointer integration: stamp + chain


def test_snapshots_are_stamped_and_chained(committed_dir):
    ck = FederationCheckpointer(committed_dir)
    entries = ck._audit_entries()
    assert [e["rounds_done"] for e in entries] == [1, 2]
    assert entries[0]["prev_commitment"] == GENESIS
    assert entries[1]["prev_commitment"] == entries[0]["commitment"]
    for r, e in zip((1, 2), entries):
        with open(os.path.join(committed_dir,
                               f"round_{r:06d}.meta.json")) as f:
            meta = json.load(f)
        assert meta["commitment"] == e["commitment"]
        assert meta["prev_commitment"] == e["prev_commitment"]
        assert meta["fingerprint"]  # derived, never stamped null
        # the recorded per-leaf digests recompose into the commitment
        assert set(e["clients"]) == {f"c{k:04d}" for k in range(K)}
        assert e["commitment"] == chain_step(
            e["prev_commitment"], r, K, e["clients"])


def test_untampered_restore_verifies_in_strict_mode(committed_dir, fed_data,
                                                    mlp_spec):
    state, done = _restore(committed_dir, fed_data, mlp_spec, verify=True)
    assert done == 2
    assert FederationCheckpointer(committed_dir).verify_chain(2)


# ---------------------------------------------------------------------------
# the tamper matrix — every corruption refused, naming round/leaf


def test_bitflipped_npz_leaf_refused(committed_dir, tmp_path, fed_data,
                                     mlp_spec):
    d = _copy(committed_dir, tmp_path)
    npz_path = os.path.join(d, "round_000002.npz")
    with np.load(npz_path) as f:
        arrays = {k: f[k] for k in f.files}
    leaf = next(k for k in sorted(arrays)
                if k.startswith("clients/c0001/proxy/params/"))
    arrays[leaf].reshape(-1).view(np.uint32)[0] ^= 1  # single bit flip
    np.savez(npz_path, **arrays)
    with pytest.raises(CommitmentError, match="tampered") as e:
        _restore(d, fed_data, mlp_spec)
    assert e.value.round == 2
    assert e.value.client == 1
    assert e.value.leaf == leaf[len("clients/c0001/"):]
    assert e.value.leaf in str(e.value)  # refusal NAMES the leaf


def test_truncated_audit_trail_refused(committed_dir, tmp_path, fed_data,
                                       mlp_spec):
    d = _copy(committed_dir, tmp_path)
    audit = os.path.join(d, "audit.jsonl")
    with open(audit) as f:
        first = f.readline()
    with open(audit, "w") as f:
        f.write(first)  # round 2's entry gone
    with pytest.raises(CommitmentError, match="no entry for round 2") as e:
        _restore(d, fed_data, mlp_spec)
    assert e.value.round == 2


def test_reordered_meta_files_refused(committed_dir, tmp_path, fed_data,
                                      mlp_spec):
    d = _copy(committed_dir, tmp_path)
    m1 = os.path.join(d, "round_000001.meta.json")
    m2 = os.path.join(d, "round_000002.meta.json")
    tmp = m1 + ".swap"
    os.replace(m1, tmp), os.replace(m2, m1), os.replace(tmp, m2)
    with pytest.raises(CommitmentError, match="swapped") as e:
        _restore(d, fed_data, mlp_spec)
    assert e.value.round == 2


def test_rewritten_audit_entry_refused(committed_dir, tmp_path, fed_data,
                                       mlp_spec):
    """Rewriting a PAST entry breaks the chain at that round even though
    the restored round itself is untouched — that is the point of chaining."""
    d = _copy(committed_dir, tmp_path)
    audit = os.path.join(d, "audit.jsonl")
    with open(audit) as f:
        entries = [json.loads(line) for line in f]
    entries[0]["clients"]["c0000"] = "f" * 64
    with open(audit, "w") as f:
        for e in entries:
            f.write(json.dumps(e, sort_keys=True) + "\n")
    with pytest.raises(CommitmentError) as e:
        _restore(d, fed_data, mlp_spec)
    assert e.value.round == 1  # FIRST divergent round, not the latest


def test_resave_is_idempotent_and_forks_are_refused(tmp_path, fed_data,
                                                    mlp_spec):
    """Replaying a save of an audited round (the resume path re-saves the
    round it restored) verifies bit-identity and appends nothing; saving an
    EARLIER round than the trail records would fork history and is refused."""
    eng = dml_engine((mlp_spec,) * K, mlp_spec, CFG, backend="vmap")
    key = jax.random.PRNGKey(0)
    state = eng.init_states(key)
    ck = FederationCheckpointer(str(tmp_path), every=1)
    ck.save(eng, state, 1, base_key=key)     # rounds_done=2
    ck.save(eng, state, 1, base_key=key)     # same payload: no-op
    assert len(ck._audit_entries()) == 1
    with pytest.raises(CommitmentError, match="fork"):
        ck.save(eng, state, 0, base_key=key)  # rounds_done=1 never audited
    # a bit-identical replay of an AUDITED round is fine even when later
    # rounds exist (a killed run deterministically re-run into its own
    # directory — the blocked-cadence scenario of tests/test_blocks.py)
    ck2 = FederationCheckpointer(os.path.join(str(tmp_path), "b"), every=1)
    ck2.save(eng, state, 0, base_key=key)
    ck2.save(eng, state, 1, base_key=key)
    ck2.save(eng, state, 0, base_key=key)    # audited replay: no-op
    assert len(ck2._audit_entries()) == 2


# ---------------------------------------------------------------------------
# in-flight verification (loop backend receipt check)


def test_inflight_tamper_refused_on_loop_backend(fed_data, mlp_spec):
    cfg = dataclasses.replace(CFG, rounds=1)
    with pytest.raises(CommitmentError, match="in flight") as e:
        _run(mlp_spec, fed_data, cfg, "loop", verify_commitments=True,
             transmit_tamper=bitflip_proxy(2, bit=22, index=5))
    assert e.value.client == 2
    assert e.value.round == 0


def test_inflight_tamper_unverified_silently_diverges(fed_data, mlp_spec):
    """The control: WITHOUT verification the same single-bit flip completes
    and corrupts the federation — which is why the receipt check exists."""
    cfg = dataclasses.replace(CFG, rounds=1)
    clean = _run(mlp_spec, fed_data, cfg, "loop")
    tampered = _run(mlp_spec, fed_data, cfg, "loop",
                    transmit_tamper=bitflip_proxy(2, bit=22, index=5))
    a = np.stack([np.asarray(tree_flatten_vector(c.proxy_params))
                  for c in clean["clients"]])
    b = np.stack([np.asarray(tree_flatten_vector(c.proxy_params))
                  for c in tampered["clients"]])
    assert not np.array_equal(a, b)


def test_verified_run_trajectory_is_bit_identical(fed_data, mlp_spec):
    """verify_commitments observes state but never changes it — the claim
    behind excluding the flag from the config fingerprint. Running AFTER
    the tamper tests above also regresses the engine-cache leak: engines
    are LRU-cached by config, so run_federated must reset the
    transmit_tamper hook or the previous test's adversary corrupts (and
    here, fails verification of) this clean run."""
    cfg = dataclasses.replace(CFG, rounds=1)
    ref = _run(mlp_spec, fed_data, cfg, "loop")
    ver = _run(mlp_spec, fed_data, cfg, "loop", verify_commitments=True)
    for role in ("proxy_params", "private_params"):
        a = np.stack([np.asarray(tree_flatten_vector(getattr(c, role)))
                      for c in ref["clients"]])
        b = np.stack([np.asarray(tree_flatten_vector(getattr(c, role)))
                      for c in ver["clients"]])
        np.testing.assert_array_equal(a, b, err_msg=role)


# ---------------------------------------------------------------------------
# checkpoint-integrity bugfix regressions


@pytest.mark.fast
def test_latest_round_survives_corrupt_pointer(committed_dir, tmp_path):
    """A garbage LATEST file used to crash latest_round() with an unguarded
    int(); now every corruption falls back to the directory scan."""
    d = _copy(committed_dir, tmp_path)
    ck = FederationCheckpointer(d)
    for garbage in ("", "deadbeef", "round_xyz", "round_", "round_000009"):
        with open(os.path.join(d, "LATEST"), "w") as f:
            f.write(garbage)
        assert ck.latest_round() == 2, repr(garbage)


@pytest.mark.fast
def test_pointer_and_scan_share_completeness_criterion(committed_dir,
                                                       tmp_path):
    """LATEST points at round 2 but its meta.json is gone: the pointer path
    must NOT trust the npz alone (it used to, while the scan required
    meta.json — the two discovery paths could disagree); both now resolve
    to the newest snapshot with npz + manifest + meta all on disk."""
    d = _copy(committed_dir, tmp_path)
    os.remove(os.path.join(d, "round_000002.meta.json"))
    assert FederationCheckpointer(d).latest_round() == 1
    os.remove(os.path.join(d, "round_000001.json"))  # manifest counts too
    assert FederationCheckpointer(d).latest_round() is None


def test_fingerprintless_checkpointer_still_blocks_config_drift(
        tmp_path, fed_data, mlp_spec):
    """Constructing the checkpointer without a fingerprint used to make the
    check silently vacuous (None stamped, None compared). Now save derives
    one from the engine's own config, and a restore under a drifted config
    refuses with the fingerprint ValueError (NOT a CommitmentError)."""
    eng = dml_engine((mlp_spec,) * K, mlp_spec, CFG, backend="vmap")
    key = jax.random.PRNGKey(0)
    state = eng.init_states(key)
    ck = FederationCheckpointer(str(tmp_path), every=1)  # no fingerprint
    ck.save(eng, state, 0, base_key=key)
    with open(os.path.join(str(tmp_path), "round_000001.meta.json")) as f:
        assert json.load(f)["fingerprint"]
    drifted = dataclasses.replace(CFG, lr=5e-4)
    eng2 = dml_engine((mlp_spec,) * K, mlp_spec, drifted, backend="vmap")
    ck2 = FederationCheckpointer(str(tmp_path), every=1)
    with pytest.raises(ValueError, match="fingerprint") as e:
        ck2.restore_latest(eng2, like=eng2.init_states(key))
    assert not isinstance(e.value, CommitmentError)
    # the original config still restores (derivation is stable)
    assert ck.restore_latest(eng, like=state)[1] == 1


def test_null_recorded_fingerprint_warns_and_strict_refuses(
        committed_dir, tmp_path, fed_data, mlp_spec):
    """Legacy snapshots that stamped fingerprint=null warn loudly on
    restore, and refuse outright under verify_commitments."""
    d = _copy(committed_dir, tmp_path)
    mp = os.path.join(d, "round_000002.meta.json")
    with open(mp) as f:
        meta = json.load(f)
    meta["fingerprint"] = None
    with open(mp, "w") as f:
        json.dump(meta, f)
    with pytest.warns(UserWarning, match="no config fingerprint"):
        state, done = _restore(d, fed_data, mlp_spec)
    assert done == 2
    with pytest.raises(CommitmentError, match="refusing"):
        _restore(d, fed_data, mlp_spec, verify=True)
