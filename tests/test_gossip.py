"""PushSum gossip (paper §3.4): column-stochasticity of P^(t), de-biased
convergence to the uniform average, exponential-graph O(1) communication,
and equivalence of the simulation and shard_map backends."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core.gossip import (adjacency_matrix, adjacency_schedule,
                               comm_cost_per_round, debias,
                               exponential_offsets, gossip_shift,
                               hier_gossip_reference, hier_layout,
                               hier_mix_schedule, hier_mix_split, mix_matrix,
                               mix_schedule, pushsum_mix, shift_schedule,
                               stale_gossip_reference, stale_mix_schedule)

pytestmark = pytest.mark.fast  # host-side graph algebra, no model compiles


@given(st.integers(0, 40), st.integers(1, 33),
       st.sampled_from(["exponential", "ring", "full"]))
def test_adjacency_column_stochastic(t, K, topology):
    P = adjacency_matrix(t, K, topology)
    assert P.shape == (K, K)
    np.testing.assert_allclose(P.sum(axis=0), 1.0, rtol=1e-9)
    assert (P >= 0).all()


def test_exponential_offsets():
    assert exponential_offsets(8) == [1, 2, 4]
    assert exponential_offsets(16) == [1, 2, 4, 8]
    assert exponential_offsets(2) == [1]
    assert exponential_offsets(1) == [0]


def test_exponential_reaches_everyone():
    """After ceil(log2 K) rounds every client has (transitively) received
    information from every other — the paper's Fig. 2 property."""
    K = 8
    reach = np.eye(K, dtype=bool)
    for t in range(int(np.ceil(np.log2(K)))):
        P = adjacency_matrix(t, K, "exponential")
        reach = ((P > 0) @ reach) | reach
    assert reach.all()


@given(st.integers(2, 16), st.integers(0, 3))
def test_pushsum_converges_to_average(K, seed):
    """Mixing without local training converges, after de-biasing, to the
    uniform average of the initial proxies (paper §3.4 limit argument)."""
    k = jax.random.PRNGKey(seed)
    thetas0 = jax.random.normal(k, (K, 5))
    target = jnp.mean(thetas0, axis=0)
    thetas, w = thetas0, jnp.ones((K,))
    for t in range(60):
        P = adjacency_matrix(t, K, "exponential")
        thetas, w = pushsum_mix(thetas, w, P)
    unb = debias(thetas, w)
    np.testing.assert_allclose(np.asarray(unb),
                               np.tile(np.asarray(target), (K, 1)), atol=1e-4)


def test_pushsum_weights_conserved():
    K = 8
    thetas = jax.random.normal(jax.random.PRNGKey(0), (K, 3))
    w = jnp.ones((K,))
    total0 = float(jnp.sum(thetas)) , float(jnp.sum(w))
    for t in range(5):
        P = adjacency_matrix(t, K, "exponential")
        thetas, w = pushsum_mix(thetas, w, P)
    # column-stochastic mixing conserves the total mass of θ and w
    np.testing.assert_allclose(float(jnp.sum(w)), K, rtol=1e-6)
    np.testing.assert_allclose(float(jnp.sum(thetas)), total0[0], rtol=1e-5)


@given(st.integers(0, 10), st.integers(2, 64))
def test_gossip_shift_matches_adjacency(t, K):
    s = gossip_shift(t, K, "exponential")
    P = adjacency_matrix(t, K, "exponential")
    for k in range(K):
        assert P[(k + s) % K, k] > 0


# ---------------------------------------------------------------------------
# block schedules: the stacked P^(t0..t0+T) the round-block scan consumes


@given(st.integers(0, 40), st.integers(1, 12), st.integers(1, 17),
       st.sampled_from(["exponential", "ring", "full"]),
       st.sampled_from(["pushsum", "mean", "ring", "none"]),
       st.integers(0, 2 ** 31 - 1))
def test_mix_schedule_matches_per_round_matrices(t0, T, K, topology, mix,
                                                 mask_seed):
    """The vectorized block schedule must equal the per-t host matrices
    EXACTLY — same floats, bit for bit — for every (mix, topology) pair
    under a random §3.4 active-mask trajectory, and stay column-stochastic
    every round. This is the host-side half of blocked == per-round
    bit-identity."""
    rng = np.random.default_rng(mask_seed)
    active = rng.random((T, K)) < 0.7
    active[~active.any(axis=1), 0] = True  # every round keeps >= 1 client
    for act in (None, active):
        S = mix_schedule(mix, t0, T, K, topology, active=act)
        assert S.shape == (T, K, K)
        np.testing.assert_allclose(S.sum(axis=1), 1.0, atol=1e-12)
        for i in range(T):
            a_t = None if (act is None or mix == "none") else act[i]
            np.testing.assert_array_equal(
                S[i], mix_matrix(mix, t0 + i, K, topology, a_t),
                err_msg=f"{mix}/{topology} K={K} t0={t0} round {i}")


def test_mix_schedule_matches_per_round_matrices_deterministic():
    """Pinned-case twin of the property test so the invariant is exercised
    even where hypothesis is unavailable (see tests/_hypothesis_compat)."""
    rng = np.random.default_rng(7)
    for mix in ("pushsum", "mean", "ring", "none"):
        for topology in ("exponential", "ring", "full"):
            for K, t0, T in ((1, 0, 3), (2, 5, 4), (8, 2, 7), (16, 31, 5)):
                active = rng.random((T, K)) < 0.6
                active[~active.any(axis=1), 0] = True
                for act in (None, active):
                    S = mix_schedule(mix, t0, T, K, topology, active=act)
                    np.testing.assert_allclose(S.sum(axis=1), 1.0,
                                               atol=1e-12)
                    for i in range(T):
                        a_t = (None if (act is None or mix == "none")
                               else act[i])
                        np.testing.assert_array_equal(
                            S[i], mix_matrix(mix, t0 + i, K, topology, a_t),
                            err_msg=f"{mix}/{topology} K={K} t0={t0} i={i}")


def test_adjacency_schedule_rejects_bad_mask_shape():
    with pytest.raises(AssertionError):
        adjacency_schedule(0, 3, 4, active=np.ones((2, 4), bool))


def test_shift_schedule_matches_gossip_shift():
    for topology in ("exponential", "ring", "full"):
        for A in (1, 2, 5, 9):
            s = shift_schedule(3, 10, A, topology)
            assert s.shape == (10,)
            for i in range(10):
                assert s[i] == gossip_shift(3 + i, A, topology)


# ---------------------------------------------------------------------------
# stale gossip (async backend): diag/off-diag split + delayed-delivery
# invariants. Each property test has a pinned deterministic twin so the
# invariants are exercised even where hypothesis is unavailable.


def _random_active(rng, T, K, p=0.7):
    active = rng.random((T, K)) < p
    active[~active.any(axis=1), 0] = True  # every round keeps >= 1 client
    return active


def _slow_stale(z0, w0, Ps, tau):
    """Independent message-queue implementation of staleness-τ PushSum:
    every (send_round -> delivery_round) message is an explicit queue
    entry, delivered when its time comes. The vectorized
    ``stale_gossip_reference`` (and through it the engine's async backend)
    must agree — this is the buffer-rotation-correctness oracle."""
    z = np.asarray(z0, np.float64).copy()
    w = np.asarray(w0, np.float64).copy()
    queue = []  # (delivery_round, recv_theta[K, D], recv_w[K])
    for t, P in enumerate(Ps):
        P = np.asarray(P, np.float64)
        kept = np.diag(P).copy()
        sent = P - np.diag(kept)
        theta = z * w[:, None]
        if tau == 0:
            mixed, w = P @ theta, P @ w
        else:
            queue.append((t + tau, sent @ theta, sent @ w))
            r_t = np.zeros_like(theta)
            r_w = np.zeros_like(w)
            for due, qt, qw in queue:
                if due == t:
                    r_t, r_w = qt, qw
            queue = [m for m in queue if m[0] > t]
            mixed = kept[:, None] * theta + r_t
            w = kept * w + r_w
        z = mixed / w[:, None]
    return z, w, queue


def _check_split(mix, topology, t0, T, K, active):
    for act in (None, active):
        kept, sent = stale_mix_schedule(mix, t0, T, K, topology, active=act)
        S = mix_schedule(mix, t0, T, K, topology, active=act)
        assert kept.shape == (T, K) and sent.shape == (T, K, K)
        assert (kept >= 0).all() and (sent >= 0).all()
        idx = np.arange(K)
        np.testing.assert_array_equal(sent[:, idx, idx], 0.0)
        # split + diagonal reassembles P EXACTLY, and column-stochasticity
        # survives the split: kept_k + sum_j sent_jk == 1 every round
        recon = sent.copy()
        recon[:, idx, idx] = kept
        np.testing.assert_array_equal(recon, S)
        np.testing.assert_allclose(kept + sent.sum(axis=1), 1.0, atol=1e-12)


@given(st.integers(0, 40), st.integers(1, 10), st.integers(1, 17),
       st.sampled_from(["exponential", "ring", "full"]),
       st.sampled_from(["pushsum", "mean", "ring", "none"]),
       st.integers(0, 2 ** 31 - 1))
def test_stale_split_column_stochastic_and_exact(t0, T, K, topology, mix,
                                                 mask_seed):
    active = _random_active(np.random.default_rng(mask_seed), T, K)
    _check_split(mix, topology, t0, T, K, active)


def test_stale_split_column_stochastic_and_exact_deterministic():
    rng = np.random.default_rng(11)
    for mix in ("pushsum", "mean", "ring", "none"):
        for K, t0, T in ((1, 0, 3), (2, 5, 4), (8, 2, 7), (16, 31, 5)):
            _check_split(mix, "exponential", t0, T, K,
                         _random_active(rng, T, K))


def _check_mass_conservation(K, D, T, tau, mix, seed, active):
    rng = np.random.default_rng(seed)
    z0 = rng.normal(size=(K, D))
    w0 = np.ones(K)
    Ps = [mix_matrix(mix, t, K, "exponential",
                     None if active is None else active[t])
          for t in range(T)]
    theta0, wm0 = (z0 * w0[:, None]).sum(), w0.sum()
    for cut in range(1, T + 1):  # invariant holds after EVERY round
        z, w, buf_t, buf_w = stale_gossip_reference(z0, w0, Ps[:cut], tau)
        np.testing.assert_allclose(
            (z * w[:, None]).sum() + buf_t.sum(), theta0, rtol=1e-9,
            err_msg=f"theta mass lost at round {cut} (tau={tau})")
        np.testing.assert_allclose(
            w.sum() + buf_w.sum(), wm0, rtol=1e-12,
            err_msg=f"w mass lost at round {cut} (tau={tau})")
        assert (w > 0).all()  # de-bias weights stay valid under delay


@given(st.integers(2, 9), st.integers(1, 8), st.integers(0, 4),
       st.sampled_from(["pushsum", "mean"]), st.integers(0, 2 ** 31 - 1),
       st.booleans())
def test_stale_gossip_mass_conserved(K, T, tau, mix, seed, dropout):
    """Total raw PushSum mass Σ z·w and total de-bias weight Σ w — clients
    PLUS the in-flight buffer — are conserved after every round, for any
    staleness and any §3.4 dropout trajectory. (ring is excluded: a zero
    diagonal plus delay leaves clients model-less, which the engine
    rejects at construction.)"""
    active = (_random_active(np.random.default_rng(seed + 1), T, K)
              if dropout else None)
    _check_mass_conservation(K, 3, T, tau, mix, seed, active)


def test_stale_gossip_mass_conserved_deterministic():
    rng = np.random.default_rng(5)
    for K, T, tau, mix in ((2, 4, 0, "pushsum"), (5, 6, 1, "pushsum"),
                           (8, 5, 2, "mean"), (3, 8, 4, "pushsum")):
        _check_mass_conservation(K, 3, T, tau, mix, int(rng.integers(1e6)),
                                 _random_active(rng, T, K))


def _check_rotation(K, T, tau, seed, active):
    rng = np.random.default_rng(seed)
    z0 = rng.normal(size=(K, 3))
    w0 = np.ones(K)
    Ps = [mix_matrix("pushsum", t, K, "exponential",
                     None if active is None else active[t])
          for t in range(T)]
    z, w, buf_t, buf_w = stale_gossip_reference(z0, w0, Ps, tau)
    sz, sw, queue = _slow_stale(z0, w0, Ps, tau)
    np.testing.assert_allclose(z, sz, rtol=1e-9)
    np.testing.assert_allclose(w, sw, rtol=1e-12)
    # the rotating buffer holds exactly the queue's undelivered messages,
    # oldest (next delivery) first
    assert buf_t.shape == (tau, K, 3) and len(queue) == min(tau, T)
    for i, (due, qt, qw) in enumerate(sorted(queue)):
        row = tau - len(queue) + i  # cold-start zeros precede real sends
        np.testing.assert_allclose(buf_t[row], qt, rtol=1e-12)
        np.testing.assert_allclose(buf_w[row], qw, rtol=1e-12)


@given(st.integers(2, 9), st.integers(1, 8), st.integers(0, 4),
       st.integers(0, 2 ** 31 - 1), st.booleans())
def test_stale_buffer_rotation_matches_message_queue(K, T, tau, seed,
                                                     dropout):
    """The τ-deep rotating buffer must behave exactly like an explicit
    per-message delivery queue (send at t, deliver at t+τ) — the
    independent oracle for buffer rotation correctness."""
    active = (_random_active(np.random.default_rng(seed + 1), T, K)
              if dropout else None)
    _check_rotation(K, T, tau, seed, active)


def test_stale_buffer_rotation_matches_message_queue_deterministic():
    rng = np.random.default_rng(17)
    for K, T, tau in ((2, 3, 1), (4, 6, 2), (5, 2, 4), (8, 8, 3)):
        _check_rotation(K, T, tau, int(rng.integers(1e6)),
                        _random_active(rng, T, K))


def test_stale_reference_tau0_equals_sync():
    """τ=0 (immediate delivery) must reproduce the synchronous PushSum
    trajectory — the host-side twin of the engine's async-τ0 == vmap
    bit-identity."""
    K, D, T = 6, 4, 7
    rng = np.random.default_rng(3)
    z = rng.normal(size=(K, D))
    w = np.ones(K)
    Ps = [mix_matrix("pushsum", t, K, "exponential") for t in range(T)]
    ref_z, ref_w = z.copy(), w.copy()
    for P in Ps:
        theta = ref_z * ref_w[:, None]
        mixed, ref_w = pushsum_mix(jnp.asarray(theta), jnp.asarray(ref_w), P)
        ref_w = np.asarray(ref_w)
        ref_z = np.asarray(mixed) / ref_w[:, None]
    got_z, got_w, buf_t, buf_w = stale_gossip_reference(z, w, Ps, 0)
    np.testing.assert_allclose(got_z, ref_z, rtol=1e-6)
    np.testing.assert_allclose(got_w, ref_w, rtol=1e-6)
    assert buf_t.shape == (0, K, D) and buf_w.shape == (0, K)


def test_stale_consensus_is_fixed_point():
    """If every client already holds the consensus vector, staleness must
    not perturb it: mixing RAW numerators θ = z·w (not the de-biased z)
    is what makes delivered mass arrive with its matching weight."""
    K, T, tau = 5, 10, 2
    c = np.array([1.5, -2.0, 0.25])
    z = np.tile(c, (K, 1))
    Ps = [mix_matrix("pushsum", t, K, "exponential") for t in range(T)]
    got_z, got_w, _, _ = stale_gossip_reference(z, np.ones(K), Ps, tau)
    np.testing.assert_allclose(got_z, z, rtol=1e-12)


# ---------------------------------------------------------------------------
# hierarchical gossip (hier backend): block-diag + cross-permutation
# factoring of the SAME flat P^(t), with staleness on cross-shard edges only


def _divisors(K):
    return [s for s in range(1, K + 1) if K % s == 0]


def _check_hier_split(mix, topology, t0, T, K, S, active):
    L = K // S
    for act in (None, active):
        blocks, src, scale = hier_mix_schedule(mix, t0, T, K, S, topology,
                                               active=act)
        Ps = mix_schedule(mix, t0, T, K, topology, active=act)
        assert blocks.shape == (T, S, L, L)
        assert src.shape == (T, K) and scale.shape == (T, K)
        shard = np.arange(K) // L
        idx = np.arange(K)
        for i in range(T):
            # factoring is a SUM decomposition with disjoint supports:
            # blockdiag(blocks) + scatter(src, scale) rebuilds P EXACTLY
            recon = np.zeros((K, K))
            for s in range(S):
                recon[s * L:(s + 1) * L, s * L:(s + 1) * L] = blocks[i, s]
            cross_rows = scale[i] != 0.0
            recon[idx[cross_rows], src[i, cross_rows]] += scale[i, cross_rows]
            np.testing.assert_array_equal(
                recon, Ps[i],
                err_msg=f"{mix}/{topology} K={K} S={S} t0={t0} round {i}")
            # every cross edge really crosses a shard boundary; a client
            # with no cross in-edge points at itself with weight 0
            assert (shard[idx[cross_rows]] != shard[src[i, cross_rows]]).all()
            np.testing.assert_array_equal(src[i, ~cross_rows],
                                          idx[~cross_rows])


@given(st.integers(0, 40), st.integers(1, 8),
       st.sampled_from([(2, 2), (4, 2), (8, 4), (12, 3), (16, 16)]),
       st.sampled_from(["exponential", "ring"]),
       st.sampled_from(["pushsum", "ring", "none"]),
       st.integers(0, 2 ** 31 - 1))
def test_hier_split_rebuilds_flat_schedule(t0, T, KS, topology, mix,
                                           mask_seed):
    K, S = KS
    active = _random_active(np.random.default_rng(mask_seed), T, K)
    _check_hier_split(mix, topology, t0, T, K, S, active)


def test_hier_split_rebuilds_flat_schedule_deterministic():
    rng = np.random.default_rng(23)
    for mix in ("pushsum", "ring", "none"):
        for K, t0, T in ((2, 0, 3), (4, 5, 4), (8, 2, 7), (16, 31, 5)):
            for S in _divisors(K):
                _check_hier_split(mix, "exponential", t0, T, K, S,
                                  _random_active(rng, T, K))


def test_hier_split_rejects_dense_cross_part():
    """Dense mixing (mean / topology='full') has O(K) cross in-edges per
    client — no O(1) collective schedule exists, so factoring must refuse
    rather than silently densify (S=1 is fine: everything is intra)."""
    P = mix_matrix("mean", 0, 8, "exponential")
    hier_mix_split(P, 1)
    for S in (2, 4, 8):
        with pytest.raises(ValueError, match="cross-shard"):
            hier_mix_split(P, S)


def test_hier_layout_validation():
    assert hier_layout(8, 4) == (4, 2)
    assert hier_layout(6, 1) == (1, 6)
    for bad in (0, 5, 9):
        with pytest.raises(ValueError, match="n_shards"):
            hier_layout(8, bad)


def _check_hier_mass(K, T, tau, S, seed, active):
    rng = np.random.default_rng(seed)
    z0 = rng.normal(size=(K, 3))
    w0 = np.ones(K)
    Ps = [mix_matrix("pushsum", t, K, "exponential",
                     None if active is None else active[t])
          for t in range(T)]
    theta0 = (z0 * w0[:, None]).sum()
    for cut in range(1, T + 1):  # invariant holds after EVERY round
        z, w, buf_t, buf_w = hier_gossip_reference(z0, w0, Ps[:cut], S, tau)
        np.testing.assert_allclose(
            (z * w[:, None]).sum() + buf_t.sum(), theta0, rtol=1e-9,
            err_msg=f"theta mass lost at round {cut} (S={S}, tau={tau})")
        np.testing.assert_allclose(
            w.sum() + buf_w.sum(), w0.sum(), rtol=1e-12,
            err_msg=f"w mass lost at round {cut} (S={S}, tau={tau})")
        assert (w > 0).all()  # intra-shard sync mass keeps de-bias valid


@given(st.sampled_from([(4, 2), (8, 2), (8, 4), (12, 3), (9, 3)]),
       st.integers(1, 6), st.integers(0, 3), st.integers(0, 2 ** 31 - 1),
       st.booleans())
def test_hier_gossip_mass_conserved(KS, T, tau, seed, dropout):
    """Σ z·w and Σ w over clients PLUS the cross-shard in-flight buffer are
    conserved after every round for any (n_shards, τ, dropout) — the hier
    twin of the async conservation law."""
    K, S = KS
    active = (_random_active(np.random.default_rng(seed + 1), T, K)
              if dropout else None)
    _check_hier_mass(K, T, tau, S, seed, active)


def test_hier_gossip_mass_conserved_deterministic():
    rng = np.random.default_rng(29)
    for K, S, T, tau in ((4, 2, 5, 0), (8, 4, 6, 1), (8, 2, 4, 2),
                         (12, 3, 5, 3), (16, 16, 4, 2)):
        _check_hier_mass(K, T, tau, S, int(rng.integers(1e6)),
                         _random_active(rng, T, K))


def test_hier_reference_tau0_equals_flat_bitwise():
    """At τ=0 the factored application must equal the flat synchronous
    reference bit-for-bit, for EVERY shard count: with at most one
    cross-shard in-edge per client the factored row sum performs the same
    additions as the dense row dot (zeros add exactly), so n_shards is a
    pure execution-layout parameter — the host-side half of the engine's
    hier-τ0 == vmap bit-identity."""
    K, D, T = 8, 5, 7
    rng = np.random.default_rng(31)
    z0 = rng.normal(size=(K, D))
    w0 = np.ones(K)
    active = _random_active(rng, T, K)
    for act in (None, active):
        Ps = [mix_matrix("pushsum", t, K, "exponential",
                         None if act is None else act[t]) for t in range(T)]
        ref = stale_gossip_reference(z0, w0, Ps, 0)
        for S in _divisors(K):
            got = hier_gossip_reference(z0, w0, Ps, S, 0)
            np.testing.assert_array_equal(got[0], ref[0])
            np.testing.assert_array_equal(got[1], ref[1])
            assert got[2].shape == (0, K, D)


def test_hier_one_client_per_shard_equals_stale():
    """With L=1 every off-diagonal edge is cross-shard, so hier-τ must
    reproduce the flat stale reference exactly: the async backend is the
    S=K corner of the hier algebra."""
    K, T = 8, 6
    rng = np.random.default_rng(37)
    z0 = rng.normal(size=(K, 4))
    w0 = np.ones(K)
    Ps = [mix_matrix("pushsum", t, K, "exponential") for t in range(T)]
    for tau in (1, 2, 3):
        got = hier_gossip_reference(z0, w0, Ps, K, tau)
        ref = stale_gossip_reference(z0, w0, Ps, tau)
        for g, r in zip(got, ref):
            np.testing.assert_allclose(g, r, rtol=1e-12)


def test_hier_consensus_is_fixed_point():
    """Consensus survives partial-shard delay: mixing RAW numerators across
    the cross-shard buffer keeps delivered mass paired with its weight."""
    K, T = 12, 10
    c = np.array([0.75, -1.25, 2.0])
    z = np.tile(c, (K, 1))
    Ps = [mix_matrix("pushsum", t, K, "exponential") for t in range(T)]
    for S, tau in ((3, 1), (4, 2), (12, 3)):
        got_z, got_w, _, _ = hier_gossip_reference(z, np.ones(K), Ps, S, tau)
        np.testing.assert_allclose(got_z, z, rtol=1e-12)


def test_comm_cost_scaling():
    """Fig. 4: centralized cost grows linearly with K; decentralized cost is
    constant; proxy-based cost scales with the proxy (not private) size."""
    mb, pb = 100e6, 10e6
    c8 = comm_cost_per_round("fedavg", 8, mb, pb)
    c64 = comm_cost_per_round("fedavg", 64, mb, pb)
    assert abs(c64 / c8 - 8.0) < 1e-9
    p8 = comm_cost_per_round("proxyfl", 8, mb, pb)
    p64 = comm_cost_per_round("proxyfl", 64, mb, pb)
    assert p8 == p64
    assert p8 < comm_cost_per_round("avgpush", 8, mb, pb)
    assert comm_cost_per_round("regular", 8, mb, pb) == 0.0


def test_mix_matrix_rules():
    """Every METHODS-table aggregation is one column-stochastic matrix:
    mean/ring keep the PushSum weight at exactly 1; pushsum under an active
    mask leaves inactive clients' columns AND rows at identity."""
    K = 6
    act = np.array([True, True, False, True, False, True])
    for mix in ("pushsum", "mean", "ring", "none"):
        P = mix_matrix(mix, 2, K, "exponential", act)
        np.testing.assert_allclose(P.sum(axis=0), 1.0, atol=1e-12)
        w2 = P @ np.ones(K)
        np.testing.assert_allclose(w2, 1.0, atol=1e-12)  # uniform in-degree
        if mix != "none":
            for k in np.where(~act)[0]:
                assert P[k, k] == 1.0
                np.testing.assert_array_equal(P[k, np.arange(K) != k], 0.0)


def test_active_permutation_matches_matrix():
    """The shard_map dropout path (perm over the ACTIVE subset + per-device
    keep factors) must equal the matrix backend on the same P^(t)."""
    K, D, t = 5, 3, 0
    act = [True, False, True, True, False]
    active_idx = [i for i, a in enumerate(act) if a]
    A = len(active_idx)
    thetas = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (K, D)))
    w = np.ones(K)
    P = mix_matrix("pushsum", t, K, "exponential", np.asarray(act))
    ref_t = P @ thetas

    shift = gossip_shift(t, A, "exponential")
    keep = np.where(act, 0.5, 1.0)[:, None]
    recv = np.zeros_like(thetas)
    for p, src in enumerate(active_idx):
        dst = active_idx[(p + shift) % A]
        recv[dst] += 0.5 * thetas[src]
    np.testing.assert_allclose(keep * thetas + recv, ref_t, rtol=1e-6)


def test_distributed_backend_matches_simulation():
    """One gossip round via shard_map/ppermute over a 1-device mesh is only
    runnable for K=1, so emulate K clients with vmap over a stacked axis and
    compare against the matrix backend on the same P^(t)."""
    K, D, t = 4, 7, 1
    k = jax.random.PRNGKey(0)
    thetas = jax.random.normal(k, (K, D))
    w = jnp.ones((K,))
    P = adjacency_matrix(t, K, "exponential")
    ref_t, ref_w = pushsum_mix(thetas, w, P)

    # manual ppermute semantics: each client k sends (1-sw)·x to k+shift
    shift = gossip_shift(t, K, "exponential")
    send = 0.5 * thetas
    recv = jnp.roll(send, shift, axis=0)
    got_t = 0.5 * thetas + recv
    got_w = 0.5 * w + jnp.roll(0.5 * w, shift, axis=0)
    np.testing.assert_allclose(np.asarray(got_t), np.asarray(ref_t), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(ref_w), rtol=1e-6)
