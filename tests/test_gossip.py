"""PushSum gossip (paper §3.4): column-stochasticity of P^(t), de-biased
convergence to the uniform average, exponential-graph O(1) communication,
and equivalence of the simulation and shard_map backends."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core.gossip import (adjacency_matrix, adjacency_schedule,
                               comm_cost_per_round, debias,
                               exponential_offsets, gossip_shift, mix_matrix,
                               mix_schedule, pushsum_mix, shift_schedule)

pytestmark = pytest.mark.fast  # host-side graph algebra, no model compiles


@given(st.integers(0, 40), st.integers(1, 33),
       st.sampled_from(["exponential", "ring", "full"]))
def test_adjacency_column_stochastic(t, K, topology):
    P = adjacency_matrix(t, K, topology)
    assert P.shape == (K, K)
    np.testing.assert_allclose(P.sum(axis=0), 1.0, rtol=1e-9)
    assert (P >= 0).all()


def test_exponential_offsets():
    assert exponential_offsets(8) == [1, 2, 4]
    assert exponential_offsets(16) == [1, 2, 4, 8]
    assert exponential_offsets(2) == [1]
    assert exponential_offsets(1) == [0]


def test_exponential_reaches_everyone():
    """After ceil(log2 K) rounds every client has (transitively) received
    information from every other — the paper's Fig. 2 property."""
    K = 8
    reach = np.eye(K, dtype=bool)
    for t in range(int(np.ceil(np.log2(K)))):
        P = adjacency_matrix(t, K, "exponential")
        reach = ((P > 0) @ reach) | reach
    assert reach.all()


@given(st.integers(2, 16), st.integers(0, 3))
def test_pushsum_converges_to_average(K, seed):
    """Mixing without local training converges, after de-biasing, to the
    uniform average of the initial proxies (paper §3.4 limit argument)."""
    k = jax.random.PRNGKey(seed)
    thetas0 = jax.random.normal(k, (K, 5))
    target = jnp.mean(thetas0, axis=0)
    thetas, w = thetas0, jnp.ones((K,))
    for t in range(60):
        P = adjacency_matrix(t, K, "exponential")
        thetas, w = pushsum_mix(thetas, w, P)
    unb = debias(thetas, w)
    np.testing.assert_allclose(np.asarray(unb),
                               np.tile(np.asarray(target), (K, 1)), atol=1e-4)


def test_pushsum_weights_conserved():
    K = 8
    thetas = jax.random.normal(jax.random.PRNGKey(0), (K, 3))
    w = jnp.ones((K,))
    total0 = float(jnp.sum(thetas)) , float(jnp.sum(w))
    for t in range(5):
        P = adjacency_matrix(t, K, "exponential")
        thetas, w = pushsum_mix(thetas, w, P)
    # column-stochastic mixing conserves the total mass of θ and w
    np.testing.assert_allclose(float(jnp.sum(w)), K, rtol=1e-6)
    np.testing.assert_allclose(float(jnp.sum(thetas)), total0[0], rtol=1e-5)


@given(st.integers(0, 10), st.integers(2, 64))
def test_gossip_shift_matches_adjacency(t, K):
    s = gossip_shift(t, K, "exponential")
    P = adjacency_matrix(t, K, "exponential")
    for k in range(K):
        assert P[(k + s) % K, k] > 0


# ---------------------------------------------------------------------------
# block schedules: the stacked P^(t0..t0+T) the round-block scan consumes


@given(st.integers(0, 40), st.integers(1, 12), st.integers(1, 17),
       st.sampled_from(["exponential", "ring", "full"]),
       st.sampled_from(["pushsum", "mean", "ring", "none"]),
       st.integers(0, 2 ** 31 - 1))
def test_mix_schedule_matches_per_round_matrices(t0, T, K, topology, mix,
                                                 mask_seed):
    """The vectorized block schedule must equal the per-t host matrices
    EXACTLY — same floats, bit for bit — for every (mix, topology) pair
    under a random §3.4 active-mask trajectory, and stay column-stochastic
    every round. This is the host-side half of blocked == per-round
    bit-identity."""
    rng = np.random.default_rng(mask_seed)
    active = rng.random((T, K)) < 0.7
    active[~active.any(axis=1), 0] = True  # every round keeps >= 1 client
    for act in (None, active):
        S = mix_schedule(mix, t0, T, K, topology, active=act)
        assert S.shape == (T, K, K)
        np.testing.assert_allclose(S.sum(axis=1), 1.0, atol=1e-12)
        for i in range(T):
            a_t = None if (act is None or mix == "none") else act[i]
            np.testing.assert_array_equal(
                S[i], mix_matrix(mix, t0 + i, K, topology, a_t),
                err_msg=f"{mix}/{topology} K={K} t0={t0} round {i}")


def test_mix_schedule_matches_per_round_matrices_deterministic():
    """Pinned-case twin of the property test so the invariant is exercised
    even where hypothesis is unavailable (see tests/_hypothesis_compat)."""
    rng = np.random.default_rng(7)
    for mix in ("pushsum", "mean", "ring", "none"):
        for topology in ("exponential", "ring", "full"):
            for K, t0, T in ((1, 0, 3), (2, 5, 4), (8, 2, 7), (16, 31, 5)):
                active = rng.random((T, K)) < 0.6
                active[~active.any(axis=1), 0] = True
                for act in (None, active):
                    S = mix_schedule(mix, t0, T, K, topology, active=act)
                    np.testing.assert_allclose(S.sum(axis=1), 1.0,
                                               atol=1e-12)
                    for i in range(T):
                        a_t = (None if (act is None or mix == "none")
                               else act[i])
                        np.testing.assert_array_equal(
                            S[i], mix_matrix(mix, t0 + i, K, topology, a_t),
                            err_msg=f"{mix}/{topology} K={K} t0={t0} i={i}")


def test_adjacency_schedule_rejects_bad_mask_shape():
    with pytest.raises(AssertionError):
        adjacency_schedule(0, 3, 4, active=np.ones((2, 4), bool))


def test_shift_schedule_matches_gossip_shift():
    for topology in ("exponential", "ring", "full"):
        for A in (1, 2, 5, 9):
            s = shift_schedule(3, 10, A, topology)
            assert s.shape == (10,)
            for i in range(10):
                assert s[i] == gossip_shift(3 + i, A, topology)


def test_comm_cost_scaling():
    """Fig. 4: centralized cost grows linearly with K; decentralized cost is
    constant; proxy-based cost scales with the proxy (not private) size."""
    mb, pb = 100e6, 10e6
    c8 = comm_cost_per_round("fedavg", 8, mb, pb)
    c64 = comm_cost_per_round("fedavg", 64, mb, pb)
    assert abs(c64 / c8 - 8.0) < 1e-9
    p8 = comm_cost_per_round("proxyfl", 8, mb, pb)
    p64 = comm_cost_per_round("proxyfl", 64, mb, pb)
    assert p8 == p64
    assert p8 < comm_cost_per_round("avgpush", 8, mb, pb)
    assert comm_cost_per_round("regular", 8, mb, pb) == 0.0


def test_mix_matrix_rules():
    """Every METHODS-table aggregation is one column-stochastic matrix:
    mean/ring keep the PushSum weight at exactly 1; pushsum under an active
    mask leaves inactive clients' columns AND rows at identity."""
    K = 6
    act = np.array([True, True, False, True, False, True])
    for mix in ("pushsum", "mean", "ring", "none"):
        P = mix_matrix(mix, 2, K, "exponential", act)
        np.testing.assert_allclose(P.sum(axis=0), 1.0, atol=1e-12)
        w2 = P @ np.ones(K)
        np.testing.assert_allclose(w2, 1.0, atol=1e-12)  # uniform in-degree
        if mix != "none":
            for k in np.where(~act)[0]:
                assert P[k, k] == 1.0
                np.testing.assert_array_equal(P[k, np.arange(K) != k], 0.0)


def test_active_permutation_matches_matrix():
    """The shard_map dropout path (perm over the ACTIVE subset + per-device
    keep factors) must equal the matrix backend on the same P^(t)."""
    K, D, t = 5, 3, 0
    act = [True, False, True, True, False]
    active_idx = [i for i, a in enumerate(act) if a]
    A = len(active_idx)
    thetas = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (K, D)))
    w = np.ones(K)
    P = mix_matrix("pushsum", t, K, "exponential", np.asarray(act))
    ref_t = P @ thetas

    shift = gossip_shift(t, A, "exponential")
    keep = np.where(act, 0.5, 1.0)[:, None]
    recv = np.zeros_like(thetas)
    for p, src in enumerate(active_idx):
        dst = active_idx[(p + shift) % A]
        recv[dst] += 0.5 * thetas[src]
    np.testing.assert_allclose(keep * thetas + recv, ref_t, rtol=1e-6)


def test_distributed_backend_matches_simulation():
    """One gossip round via shard_map/ppermute over a 1-device mesh is only
    runnable for K=1, so emulate K clients with vmap over a stacked axis and
    compare against the matrix backend on the same P^(t)."""
    from repro.core.gossip import pushsum_gossip_shard
    K, D, t = 4, 7, 1
    k = jax.random.PRNGKey(0)
    thetas = jax.random.normal(k, (K, D))
    w = jnp.ones((K,))
    P = adjacency_matrix(t, K, "exponential")
    ref_t, ref_w = pushsum_mix(thetas, w, P)

    # manual ppermute semantics: each client k sends (1-sw)·x to k+shift
    shift = gossip_shift(t, K, "exponential")
    send = 0.5 * thetas
    recv = jnp.roll(send, shift, axis=0)
    got_t = 0.5 * thetas + recv
    got_w = 0.5 * w + jnp.roll(0.5 * w, shift, axis=0)
    np.testing.assert_allclose(np.asarray(got_t), np.asarray(ref_t), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(ref_w), rtol=1e-6)
