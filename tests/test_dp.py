"""DP-SGD (Eq. 7) unit + property tests: clipping invariants, noise
calibration, and equivalence of the three per-example gradient schedules
(scan / vectorized / scan-of-vmap chunked)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, st

from repro.core.dp import (add_gaussian_noise, clip_by_global_norm,
                           dp_gradient, dp_gradient_chunked, non_dp_gradient)


def _tree_strategy():
    arr = st.lists(st.floats(-10, 10, allow_nan=False, width=32),
                   min_size=1, max_size=8)
    return st.fixed_dictionaries({
        "a": arr, "b": st.fixed_dictionaries({"c": arr}),
    })


@given(_tree_strategy(), st.floats(0.1, 5.0))
def test_clip_by_global_norm_bound(tree_lists, c):
    tree = jax.tree_util.tree_map(
        lambda x: jnp.asarray(x, jnp.float32), tree_lists,
        is_leaf=lambda x: isinstance(x, list))
    clipped, norm = clip_by_global_norm(tree, c)
    cn = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                      for x in jax.tree_util.tree_leaves(clipped)))
    assert float(cn) <= c * (1 + 1e-4)
    # un-clipped when already inside the ball (atol: XLA flushes
    # subnormals to zero, so exact equality fails on denormal inputs)
    if float(norm) <= c:
        for a, b in zip(jax.tree_util.tree_leaves(clipped),
                        jax.tree_util.tree_leaves(tree)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1.2e-38)


def test_noise_statistics():
    tree = {"w": jnp.zeros((50_000,))}
    noisy = add_gaussian_noise(tree, jax.random.PRNGKey(0), stddev=2.0)
    x = np.asarray(noisy["w"])
    assert abs(x.mean()) < 0.05
    assert abs(x.std() - 2.0) < 0.05


def _quadratic_setup(B=8, d=6, seed=0):
    k = jax.random.PRNGKey(seed)
    X = jax.random.normal(k, (B, d))
    y = jax.random.normal(jax.random.fold_in(k, 1), (B,))
    params = {"w": jax.random.normal(jax.random.fold_in(k, 2), (d,)),
              "b": jnp.zeros(())}

    def loss(p, batch):
        xb, yb = batch
        pred = xb @ p["w"] + p["b"]
        return jnp.mean((pred - yb) ** 2)

    return params, (X, y), loss


def test_dp_schedules_agree_at_zero_noise():
    params, batch, loss = _quadratic_setup()
    key = jax.random.PRNGKey(0)
    kw = dict(clip_norm=0.7, noise_multiplier=0.0)
    g1, _ = dp_gradient(loss, params, batch, key, vectorized=False, **kw)
    g2, _ = dp_gradient(loss, params, batch, key, vectorized=True, **kw)
    g3, _ = dp_gradient_chunked(
        lambda p, ex: loss(p, ex), params,
        {"x": batch[0], "y": batch[1]} if False else batch, key, chunk=4, **kw)
    for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g3)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_dp_grad_bounded_sensitivity():
    """Replacing one example changes the (noise-free) summed clipped gradient
    by at most 2C/B in L2 — the DP sensitivity bound the Gaussian mechanism
    relies on."""
    params, (X, y), loss = _quadratic_setup(B=8)
    key = jax.random.PRNGKey(0)
    C = 0.5
    g1, _ = dp_gradient(loss, params, (X, y), key, clip_norm=C,
                        noise_multiplier=0.0)
    X2 = X.at[3].set(X[3] + 100.0)  # adversarial replacement
    g2, _ = dp_gradient(loss, params, (X2, y), key, clip_norm=C,
                        noise_multiplier=0.0)
    diff = jnp.sqrt(sum(jnp.sum((a - b) ** 2) for a, b in zip(
        jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2))))
    B = X.shape[0]
    assert float(diff) <= 2 * C / B + 1e-6


def test_dp_noise_applied():
    params, batch, loss = _quadratic_setup()
    g0, _ = dp_gradient(loss, params, batch, jax.random.PRNGKey(0),
                        clip_norm=1.0, noise_multiplier=0.0)
    g1, _ = dp_gradient(loss, params, batch, jax.random.PRNGKey(0),
                        clip_norm=1.0, noise_multiplier=1.0)
    diffs = [float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree_util.tree_leaves(g0), jax.tree_util.tree_leaves(g1))]
    assert max(diffs) > 0


def test_microbatch_groups():
    params, batch, loss = _quadratic_setup(B=8)
    key = jax.random.PRNGKey(0)
    # microbatch=B collapses to plain clipped batch gradient
    gm, _ = dp_gradient(loss, params, batch, key, clip_norm=1e9,
                        noise_multiplier=0.0, microbatch=8)
    gp, _ = non_dp_gradient(loss, params, batch)
    for a, b in zip(jax.tree_util.tree_leaves(gm), jax.tree_util.tree_leaves(gp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_non_dp_accum_equivalence():
    params, batch, loss = _quadratic_setup(B=8)
    g1, m1 = non_dp_gradient(loss, params, batch, accum=1)
    g4, m4 = non_dp_gradient(loss, params, batch, accum=4)
    for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
