"""Per-architecture smoke tests (reduced variants, one forward/train step on
CPU, shape + finite checks) and the decode-path equivalence property:
prefill+decode through the KV/SSM cache must reproduce the no-cache
forward logits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.base import DPConfig, InputShape, ProxyFLConfig
from repro.configs.registry import proxy_of, smoke_variant
from repro.launch.steps import (StepOptions, init_serve_state,
                                init_train_state, input_specs,
                                make_decode_step, make_train_step)
from repro.nn.model import forward, init_cache, init_model

ARCHS = [a for a in list_archs()]


def _inputs(cfg, B=2, S=16, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    if cfg.modality == "audio":
        tok = jax.random.randint(key, (B, S, cfg.n_codebooks), 0, cfg.vocab_size)
    else:
        tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    img = None
    if cfg.modality == "vlm":
        img = jax.random.normal(key, (B, cfg.n_image_tokens, cfg.frontend_dim),
                                jnp.dtype(cfg.dtype))
    return tok, img


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = smoke_variant(get_config(arch))
    params = init_model(jax.random.PRNGKey(0), cfg)
    tok, img = _inputs(cfg)
    logits, cache, aux = forward(params, cfg, tok, img)
    S_out = tok.shape[1] + (cfg.n_image_tokens if cfg.modality == "vlm" else 0)
    if cfg.modality == "audio":
        assert logits.shape == (2, S_out, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (2, S_out, cfg.vocab_size)
    assert cache is None
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = smoke_variant(get_config(arch))
    proxy = smoke_variant(proxy_of(cfg))
    fl = ProxyFLConfig(dp=DPConfig(enabled=True), batch_size=2)
    opts = StepOptions(remat=False, accum=1, dp_chunk=2)
    state = init_train_state(jax.random.PRNGKey(0), cfg, proxy, fl, opts)
    sh = InputShape("t", 16, 2, "train")
    specs = input_specs(cfg, sh)
    k = jax.random.PRNGKey(1)
    batch = {}
    for name, sds in specs.items():
        if sds.dtype == jnp.int32:
            batch[name] = jax.random.randint(k, sds.shape, 0, cfg.vocab_size)
        else:
            batch[name] = jax.random.normal(k, sds.shape, sds.dtype)
    step = jax.jit(make_train_step(cfg, proxy, fl, opts))
    new_state, metrics = step(state, batch, k)
    assert bool(jnp.isfinite(metrics["private_loss"]))
    assert bool(jnp.isfinite(metrics["proxy_loss"]))
    # params actually moved (embed values ~0.02 have bf16 resolution well
    # below the lr=1e-3 step; norm weights at 1.0 do not — that's what the
    # fp32 master copy is for, so check it moved too)
    before = state["private"]["params"]["embed"]["e"]
    after = new_state["private"]["params"]["embed"]["e"]
    assert not bool(jnp.allclose(before, after))
    opt = new_state["private"]["opt"]
    assert int(opt.t) == 1
    if opt.p32 is not None:
        b32 = state["private"]["opt"].p32["norm_f"]["g"]
        a32 = opt.p32["norm_f"]["g"]
        assert not bool(jnp.allclose(b32, a32))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Prefill S0 tokens then decode the rest one by one; the logits at each
    decoded position must match the full no-cache forward (the KV cache,
    sliding windows, MLA latents and SSM states all agree with attention
    over the raw sequence)."""
    cfg = smoke_variant(get_config(arch))
    if cfg.dtype != "float32":
        cfg = cfg.with_(dtype="float32")
    if cfg.moe is not None:
        # capacity-MoE drops depend on batch composition; equivalence holds
        # exactly only in the dropless regime (capacity ≥ all tokens)
        import dataclasses
        cfg = cfg.with_(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S, S0 = 2, 12, 7
    tok, img = _inputs(cfg, B=B, S=S, key=jax.random.PRNGKey(3))
    full, _, _ = forward(params, cfg, tok, img)

    n_img = cfg.n_image_tokens if cfg.modality == "vlm" else 0
    cache = init_cache(cfg, B, S + n_img, dtype=jnp.float32)
    pre = tok[:, :S0]
    logits, cache, _ = forward(params, cfg, pre, img, cache=cache, pos_offset=0)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, :S0 + n_img]),
                               rtol=2e-4, atol=2e-4)
    for i in range(S0, S):
        step_tok = tok[:, i:i + 1]
        logits, cache, _ = forward(params, cfg, step_tok, None, cache=cache,
                                   pos_offset=i + n_img)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full[:, n_img + i]),
            rtol=2e-4, atol=2e-4, err_msg=f"{arch} pos {i}")


@pytest.mark.parametrize("arch", ["gemma3-4b", "qwen2-7b-swa"])
def test_sliding_window_masks_past(arch):
    """A token beyond every sliding window must not influence logits at the
    end of a long-enough sequence (locality property)."""
    cfg = smoke_variant(get_config(arch))
    cfg = cfg.with_(dtype="float32")
    # force a 2-layer all-local stack so the receptive field is tiny
    from repro.configs.base import LayerSpec
    w = 4
    cfg = cfg.with_(n_layers=2, prefix=(),
                    pattern=(LayerSpec(kind="attn", ffn="dense", window=w),))
    params = init_model(jax.random.PRNGKey(0), cfg)
    S = 12
    tok = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab_size)
    out1, _, _ = forward(params, cfg, tok)
    # perturb a token beyond the stacked receptive field of the last position
    reach = cfg.n_layers * (w - 1)
    assert S > reach + 1
    tok2 = tok.at[0, 0].set((tok[0, 0] + 1) % cfg.vocab_size)
    out2, _, _ = forward(params, cfg, tok2)
    np.testing.assert_allclose(np.asarray(out1[0, -1]), np.asarray(out2[0, -1]),
                               rtol=1e-5, atol=1e-5)


def test_ring_cache_prefill_wrap():
    """Sliding-window ring cache: prefill LONGER than the window must keep
    only the last ``window`` keys and still match the no-cache forward."""
    from repro.configs.base import LayerSpec
    cfg = smoke_variant(get_config("gemma3-4b")).with_(
        dtype="float32", n_layers=2, prefix=(),
        pattern=(LayerSpec(kind="attn", ffn="dense", window=6),))
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 1, 16
    tok = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    full, _, _ = forward(params, cfg, tok)
    cache = init_cache(cfg, B, S, dtype=jnp.float32)
    # ring allocated at window size, far below max_len ([R, B, slots, H, hd])
    assert cache["stack"][0]["k"].shape[2] == 6
    S0 = 11  # prefill wraps the 6-slot ring almost twice
    logits, cache, _ = forward(params, cfg, tok[:, :S0], cache=cache, pos_offset=0)
    np.testing.assert_allclose(np.asarray(logits[:, -1]), np.asarray(full[:, S0 - 1]),
                               rtol=2e-4, atol=2e-4)
    for i in range(S0, S):
        logits, cache, _ = forward(params, cfg, tok[:, i:i + 1], cache=cache,
                                   pos_offset=i)
        np.testing.assert_allclose(np.asarray(logits[:, 0]), np.asarray(full[:, i]),
                                   rtol=2e-4, atol=2e-4, err_msg=f"pos {i}")


def test_serve_steps_run():
    cfg = smoke_variant(get_config("qwen2-7b"))
    sh = InputShape("d", 32, 2, "decode")
    state = init_serve_state(jax.random.PRNGKey(0), cfg, sh)
    opts = StepOptions(remat=False)
    dec = jax.jit(make_decode_step(cfg, opts))
    batch = {"tokens": jnp.zeros((2, 1), jnp.int32),
             "pos": jnp.asarray(3, jnp.int32)}
    state2, logits = dec(state, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


def test_moe_router_balanced_aux():
    """Router aux loss is positive and differentiable."""
    cfg = smoke_variant(get_config("arctic-480b"))
    params = init_model(jax.random.PRNGKey(0), cfg)
    tok, _ = _inputs(cfg)

    def loss(p):
        _, _, aux = forward(p, cfg, tok)
        return aux

    v, g = jax.value_and_grad(loss)(params)
    assert float(v) > 0
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0
