"""Federation checkpointing & resumption.

Three layers under test: key-path restore in ``repro.checkpoint.ckpt``
(missing/unexpected keys must raise, dtypes must round-trip), the
``FederationEngine.save_state``/``restore_state`` hooks (backend-portable,
bit-exact, accountant counters restored), and the end-to-end resume
contract through ``run_federated`` — a run killed after round t and
resumed from its checkpoint finishes bit-identically to an uninterrupted
run, including the §3.4 active-mask schedule."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (FederationCheckpointer, config_fingerprint,
                              load_checkpoint, save_checkpoint)
from repro.configs.base import DPConfig, ProxyFLConfig
from repro.core.accountant import PrivacyAccountant
from repro.core.baselines import run_federated
from repro.core.engine import active_mask, dml_engine
from repro.core.protocol import ModelSpec
from repro.data.synthetic import make_classification_data
from repro.nn.modules import tree_flatten_vector
from repro.nn.vision import get_vision_model
from repro.optim import Adam

K, N_CLASSES, SHAPE = 4, 10, (14, 14, 1)


@pytest.fixture(scope="module")
def fed_data():
    key = jax.random.PRNGKey(0)
    x, y = make_classification_data(key, 1200, SHAPE, N_CLASSES, sep=2.0)
    return [(x[i * 300:(i + 1) * 300], y[i * 300:(i + 1) * 300])
            for i in range(K)]


@pytest.fixture(scope="module")
def mlp_spec():
    vm = get_vision_model("mlp")
    return ModelSpec("mlp", lambda k: vm.init(k, SHAPE, N_CLASSES), vm.apply)


def _flat_clients(eng, state):
    return np.stack([np.asarray(tree_flatten_vector(
        eng.client_state(state, k)["proxy"]["params"])) for k in range(K)])


# ---------------------------------------------------------------------------
# ckpt.py: key-path restore


@pytest.mark.fast
def test_roundtrip_preserves_dtypes_incl_bf16_and_int(tmp_path):
    opt = Adam(lr=1e-3, moment_dtype="bfloat16")
    params = {"w": jnp.linspace(-1, 1, 8, dtype=jnp.bfloat16)}
    tree = {"params": params, "opt": opt.init(params),
            "counters": {"steps": jnp.asarray(7, jnp.int32),
                         "mask": jnp.asarray([True, False]),
                         "ids": jnp.arange(3, dtype=jnp.uint32)}}
    p = os.path.join(tmp_path, "ckpt")
    save_checkpoint(p, tree)
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    loaded = load_checkpoint(p, like)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(loaded)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


@pytest.mark.fast
def test_load_checkpoint_reports_missing_and_unexpected_keys(tmp_path):
    p = os.path.join(tmp_path, "ckpt")
    save_checkpoint(p, {"a": jnp.ones(2), "gone": jnp.ones(3)})
    with pytest.raises(KeyError) as e:
        load_checkpoint(p, {"a": jnp.zeros(2), "absent": jnp.zeros(1)})
    msg = str(e.value)
    assert "absent" in msg and "gone" in msg  # both directions listed


@pytest.mark.fast
def test_load_checkpoint_shape_mismatch_raises(tmp_path):
    p = os.path.join(tmp_path, "ckpt")
    save_checkpoint(p, {"a": jnp.ones((2, 3))})
    with pytest.raises(ValueError, match="shape"):
        load_checkpoint(p, {"a": jnp.zeros((3, 2))})


@pytest.mark.fast
def test_load_checkpoint_not_fooled_by_reordered_template(tmp_path):
    """Restore matches by key path: a template whose flatten order differs
    from the saved tree's must still land every leaf in the right slot
    (the old zip(keys, leaves) pairing silently swapped same-shape leaves
    whenever the orders diverged)."""
    p = os.path.join(tmp_path, "ckpt")
    save_checkpoint(p, {"a": jnp.full(3, 1.0), "b": jnp.full(3, 2.0)})
    # same key set, same shapes — only the insertion order differs
    loaded = load_checkpoint(p, {"b": jnp.zeros(3), "a": jnp.zeros(3)})
    np.testing.assert_array_equal(np.asarray(loaded["a"]), 1.0)
    np.testing.assert_array_equal(np.asarray(loaded["b"]), 2.0)


# ---------------------------------------------------------------------------
# engine save_state / restore_state


@pytest.mark.fast
def test_engine_state_roundtrip_bit_exact(tmp_path, fed_data, mlp_spec):
    cfg = ProxyFLConfig(n_clients=K, rounds=2, batch_size=50, local_steps=2,
                        dp=DPConfig(enabled=True))
    key = jax.random.PRNGKey(3)
    eng = dml_engine((mlp_spec,) * K, mlp_spec, cfg, backend="vmap")
    eng.attach_accountants([PrivacyAccountant(1.0, 0.2) for _ in range(K)])
    state = eng.init_states(key)
    state, _ = eng.run_round(state, fed_data, 0, key)
    path = os.path.join(tmp_path, "round_000001")
    eng.save_state(path, state, 0, base_key=key)
    for a in eng.accountants:
        a.steps = 999  # must be overwritten by restore
    restored, rounds_done = eng.restore_state(
        path, like=eng.init_states(key), base_key=key)
    assert rounds_done == 1
    assert all(a.steps == 2 for a in eng.accountants)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="base RNG key"):
        eng.restore_state(path, like=eng.init_states(key),
                          base_key=jax.random.PRNGKey(999))
    # seed 0's key data is all zeros — it must still count as "recorded"
    p0 = os.path.join(tmp_path, "seed0")
    eng.save_state(p0, state, 0, base_key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="base RNG key"):
        eng.restore_state(p0, like=eng.init_states(key),
                          base_key=jax.random.PRNGKey(1))


def test_checkpoint_is_backend_portable(tmp_path, fed_data, mlp_spec):
    """A snapshot written by the vmap engine restores into a loop engine
    (and back) with identical leaves — state is stored per client."""
    cfg = ProxyFLConfig(n_clients=K, rounds=1, batch_size=50, local_steps=1,
                        dp=DPConfig(enabled=False))
    key = jax.random.PRNGKey(0)
    veng = dml_engine((mlp_spec,) * K, mlp_spec, cfg, backend="vmap")
    leng = dml_engine((mlp_spec,) * K, mlp_spec, cfg, backend="loop")
    state, _ = veng.run_round(veng.init_states(key), fed_data, 0, key)
    path = os.path.join(tmp_path, "snap")
    veng.save_state(path, state, 0)
    lstate, done = leng.restore_state(path, like=leng.init_states(key))
    assert done == 1 and isinstance(lstate, list) and len(lstate) == K
    np.testing.assert_array_equal(_flat_clients(veng, state),
                                  _flat_clients(leng, lstate))


@pytest.mark.fast
def test_checkpointer_fingerprint_mismatch_refuses(tmp_path, fed_data,
                                                   mlp_spec):
    cfg = ProxyFLConfig(n_clients=K, rounds=1, batch_size=50, local_steps=1,
                        dp=DPConfig(enabled=False))
    key = jax.random.PRNGKey(0)
    eng = dml_engine((mlp_spec,) * K, mlp_spec, cfg, backend="vmap")
    state = eng.init_states(key)
    ck = FederationCheckpointer(str(tmp_path), every=1,
                                fingerprint=config_fingerprint(cfg))
    ck.save(eng, state, 0, base_key=key)
    other = dataclasses.replace(cfg, lr=5e-4)
    ck2 = FederationCheckpointer(str(tmp_path), every=1,
                                 fingerprint=config_fingerprint(other))
    with pytest.raises(ValueError, match="fingerprint"):
        ck2.restore_latest(eng, like=state)
    # rounds/backend are excluded: extending the horizon keeps the print
    assert (config_fingerprint(cfg)
            == config_fingerprint(dataclasses.replace(cfg, rounds=99)))


def _perturbed(value):
    """A same-type value different from ``value`` for any config field."""
    if dataclasses.is_dataclass(value):
        return dataclasses.replace(value,
                                   clip_norm=value.clip_norm + 1.0)
    if isinstance(value, bool):
        return not value
    if isinstance(value, int):
        return value + 1
    if isinstance(value, float):
        return value + 0.25
    if isinstance(value, str):
        return value + "_x"
    if value is None:
        return 0.5
    raise TypeError(f"add a perturbation for {type(value)}")


@pytest.mark.fast
def test_fingerprint_covers_every_config_field():
    """Dynamic twin of fedlint FED004: perturbing ANY non-excluded
    ProxyFLConfig field must change the fingerprint (a field the hash
    ignores lets a resume silently continue under a different run), and
    perturbing an excluded field must NOT (that is what the exclusion
    claims)."""
    from repro.checkpoint.federation import DEFAULT_FINGERPRINT_EXCLUDE

    cfg = ProxyFLConfig()
    base = config_fingerprint(cfg)
    for f in dataclasses.fields(ProxyFLConfig):
        mutated = dataclasses.replace(
            cfg, **{f.name: _perturbed(getattr(cfg, f.name))})
        fp = config_fingerprint(mutated)
        if f.name in DEFAULT_FINGERPRINT_EXCLUDE:
            assert fp == base, (
                f"excluded field {f.name!r} leaked into the fingerprint")
        else:
            assert fp != base, (
                f"field {f.name!r} is invisible to config_fingerprint — "
                f"resumes could silently change it")


@pytest.mark.fast
def test_checkpointer_cadence_latest_and_rotation(tmp_path, fed_data,
                                                  mlp_spec):
    cfg = ProxyFLConfig(n_clients=K, rounds=4, batch_size=50, local_steps=1,
                        dp=DPConfig(enabled=False))
    key = jax.random.PRNGKey(0)
    eng = dml_engine((mlp_spec,) * K, mlp_spec, cfg, backend="vmap")
    state = eng.init_states(key)
    ck = FederationCheckpointer(str(tmp_path), every=2, keep=1)
    assert [t for t in range(4) if ck.should_save(t)] == [1, 3]
    for t in range(4):
        state, _ = eng.run_round(state, fed_data, t,
                                 jax.random.fold_in(key, 10_000 + t))
        ck.maybe_save(eng, state, t, base_key=key)
    assert ck.saved_rounds() == [4]  # keep=1 rotated round_000002 away
    assert ck.latest_round() == 4
    assert ck.restore_latest(eng, like=eng.init_states(key))[1] == 4
    empty = FederationCheckpointer(os.path.join(str(tmp_path), "void"))
    assert empty.latest_round() is None
    assert empty.restore_latest(eng, like=state) is None


# ---------------------------------------------------------------------------
# end-to-end resume through run_federated


def _run(method, spec, data, cfg, backend, **kw):
    return run_federated(method, [spec] * K, spec, data, data[0], cfg,
                         seed=0, eval_every=cfg.rounds, backend=backend, **kw)


@pytest.mark.fast
def test_resume_bit_identical_vmap(tmp_path, fed_data, mlp_spec):
    """Kill after round 1 of 3, resume, and the final proxy/private params
    and epsilon match the uninterrupted run EXACTLY (vmap backend, DP on,
    dropout on — so the active-mask schedule must replay too)."""
    cfg = ProxyFLConfig(n_clients=K, rounds=3, batch_size=50, local_steps=2,
                        dropout_rate=0.25, seed=5,
                        dp=DPConfig(enabled=True))
    full = _run("proxyfl", mlp_spec, fed_data, cfg, "vmap")
    d = str(tmp_path)
    _run("proxyfl", mlp_spec, fed_data, dataclasses.replace(cfg, rounds=1),
         "vmap", checkpoint_dir=d, checkpoint_every=1)
    resumed = _run("proxyfl", mlp_spec, fed_data, cfg, "vmap",
                   checkpoint_dir=d, checkpoint_every=1, resume=True)
    for role in ("proxy_params", "private_params"):
        a = np.stack([np.asarray(tree_flatten_vector(getattr(c, role)))
                      for c in full["clients"]])
        b = np.stack([np.asarray(tree_flatten_vector(getattr(c, role)))
                      for c in resumed["clients"]])
        np.testing.assert_array_equal(a, b, err_msg=role)
    assert full["epsilon"] == resumed["epsilon"]
    assert resumed["history"][-1]["round"] == cfg.rounds


def test_resume_equivalence_loop_vs_vmap(tmp_path, fed_data, mlp_spec):
    """Resumed trajectories agree across backends within the same numerical
    tolerance as uninterrupted loop==vmap equivalence."""
    cfg = ProxyFLConfig(n_clients=K, rounds=2, batch_size=50, local_steps=2,
                        dp=DPConfig(enabled=True))
    out = {}
    for backend in ("loop", "vmap"):
        d = os.path.join(str(tmp_path), backend)
        _run("proxyfl", mlp_spec, fed_data,
             dataclasses.replace(cfg, rounds=1), backend,
             checkpoint_dir=d, checkpoint_every=1)
        res = _run("proxyfl", mlp_spec, fed_data, cfg, backend,
                   checkpoint_dir=d, checkpoint_every=1, resume=True)
        out[backend] = np.stack([
            np.asarray(tree_flatten_vector(c.proxy_params))
            for c in res["clients"]])
    np.testing.assert_allclose(out["loop"], out["vmap"],
                               atol=1e-5, rtol=1e-4)


def test_resume_single_model_method(tmp_path, fed_data, mlp_spec):
    """The single-model engine path (fedavg) checkpoints and resumes
    bit-identically too."""
    cfg = ProxyFLConfig(n_clients=K, rounds=2, batch_size=50, local_steps=2,
                        dp=DPConfig(enabled=False))
    full = _run("fedavg", mlp_spec, fed_data, cfg, "vmap")
    d = str(tmp_path)
    _run("fedavg", mlp_spec, fed_data, dataclasses.replace(cfg, rounds=1),
         "vmap", checkpoint_dir=d, checkpoint_every=1)
    resumed = _run("fedavg", mlp_spec, fed_data, cfg, "vmap",
                   checkpoint_dir=d, checkpoint_every=1, resume=True)
    a = np.stack([np.asarray(tree_flatten_vector(c.params))
                  for c in full["clients"]])
    b = np.stack([np.asarray(tree_flatten_vector(c.params))
                  for c in resumed["clients"]])
    np.testing.assert_array_equal(a, b)


@pytest.mark.fast
def test_resume_of_finished_run_reevaluates(tmp_path, fed_data, mlp_spec):
    """Resuming a run whose checkpoint already reached cfg.rounds executes
    zero rounds but still returns a final history row and client states."""
    cfg = ProxyFLConfig(n_clients=K, rounds=1, batch_size=50, local_steps=1,
                        dp=DPConfig(enabled=False))
    d = str(tmp_path)
    first = _run("proxyfl", mlp_spec, fed_data, cfg, "vmap",
                 checkpoint_dir=d, checkpoint_every=1)
    again = _run("proxyfl", mlp_spec, fed_data, cfg, "vmap",
                 checkpoint_dir=d, checkpoint_every=1, resume=True)
    assert again["history"][-1]["round"] == cfg.rounds
    a = np.stack([np.asarray(tree_flatten_vector(c.proxy_params))
                  for c in first["clients"]])
    b = np.stack([np.asarray(tree_flatten_vector(c.proxy_params))
                  for c in again["clients"]])
    np.testing.assert_array_equal(a, b)


@pytest.mark.fast
def test_active_mask_schedule_survives_restore():
    """§3.4 dropout masks depend only on (cfg.seed, t) — a resumed run at
    round t draws the same mask the killed run would have."""
    cfg = ProxyFLConfig(n_clients=8, dropout_rate=0.4, seed=13)
    pre_kill = [active_mask(t, 8, cfg) for t in range(6)]
    # "restart": a fresh process re-derives masks from the config alone
    resumed_cfg = ProxyFLConfig(n_clients=8, dropout_rate=0.4, seed=13)
    for t in range(3, 6):
        np.testing.assert_array_equal(pre_kill[t],
                                      active_mask(t, 8, resumed_cfg))
