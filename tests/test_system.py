"""End-to-end system tests: the training and serving drivers run as a user
would invoke them, and the dry-run module keeps its device-count contract."""
import subprocess
import sys


def _run(mod, *args, timeout=900):
    return subprocess.run(
        [sys.executable, "-m", mod, *args], capture_output=True, text=True,
        timeout=timeout, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})


def test_train_driver_end_to_end():
    r = _run("repro.launch.train", "--arch", "qwen1.5-4b", "--smoke",
             "--clients", "2", "--rounds", "1", "--steps-per-round", "1",
             "--batch", "2", "--seq", "32")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "round 1/1" in r.stdout
    assert "eps=" in r.stdout  # privacy accounted


def test_serve_driver_end_to_end():
    r = _run("repro.launch.serve", "--arch", "qwen1.5-4b", "--smoke",
             "--batch", "2", "--prompt-len", "8", "--gen", "3")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "decoded" in r.stdout


def test_dryrun_sets_device_count_first():
    """The XLA_FLAGS override must be the first statements of dryrun.py —
    and must NOT leak into any other module."""
    src = open("src/repro/launch/dryrun.py").read()
    lines = [l for l in src.splitlines() if l and not l.startswith("#")]
    assert lines[0] == "import os"
    assert "xla_force_host_platform_device_count=512" in lines[1]
    for f in ("src/repro/launch/mesh.py", "src/repro/launch/steps.py",
              "tests/conftest.py", "benchmarks/run.py"):
        assert "force_host_platform_device_count" not in open(f).read(), f


def test_single_device_visible_in_tests():
    import jax
    assert len(jax.devices()) == 1
