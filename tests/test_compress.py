"""Compressed proxy exchange (repro.core.compress): the top-k / int8
codecs against their numpy oracles, the public-copy conservation
invariants (property-based + pinned deterministic twin: sender and
receivers advance the copy in lockstep, truncated mass stays in the
implicit residual, silent clients' copies are untouched), the engine
held to the ``compressed_gossip_reference`` executable spec, w-mass
conservation under compression on the stale backend, kill/resume
bit-identity with the copies in the checkpoint, and the guard rails
(shard_map rejection, fingerprint refusal across a compression-config
change, wire-byte reduction floors).

Cross-backend agreement (compress="none" bitwise, topk/int8 loop-vs-vmap
under the quantized grade, compressed block bit-identity) lives in the
conformance matrix — tests/test_conformance.py ``compress-*`` cases."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.configs.base import DPConfig, ProxyFLConfig
from repro.core.baselines import run_federated
from repro.core.compress import (CompressionSpec, compress_round_key,
                                 compress_spec, compressed_gossip_reference,
                                 encode_decode, ef_encode_reference,
                                 int8_reference, topk_k, topk_reference,
                                 wire_bytes)
from repro.core.engine import (FederationEngine, round_key,
                               single_model_engine)
from repro.core.gossip import mix_matrix
from repro.core.protocol import ModelSpec
from repro.nn.modules import tree_flatten_vector
from repro.nn.vision import get_vision_model

K, N_CLASSES, SHAPE = 4, 10, (14, 14, 1)


@pytest.fixture(scope="module")
def mlp_spec():
    vm = get_vision_model("mlp")
    return ModelSpec("mlp", lambda k: vm.init(k, SHAPE, N_CLASSES), vm.apply)


@pytest.fixture(scope="module")
def dataset():
    from repro.data.synthetic import make_classification_data
    x, y = make_classification_data(jax.random.PRNGKey(0), 400, SHAPE,
                                    N_CLASSES, sep=2.0)
    return [(x[i * 100:(i + 1) * 100], y[i * 100:(i + 1) * 100])
            for i in range(K)]


# ---------------------------------------------------------------------------
# codecs vs numpy oracles


@pytest.mark.fast
@pytest.mark.parametrize("shape", [(1, 1), (2, 7), (3, 64), (4, 333),
                                   (5, 1024)])
def test_topk_matches_reference(shape):
    """lax.top_k codec == stable-argsort numpy oracle, bitwise — over odd/
    ragged D including the k=1 floor, on values that exercise bf16 wire
    rounding (normals well inside bf16 range)."""
    u = np.asarray(jax.random.normal(jax.random.PRNGKey(shape[1]), shape,
                                     jnp.float32))
    for ratio in (0.1, 0.25, 1.0):
        spec = CompressionSpec(mode="topk", ratio=ratio)
        got = np.asarray(encode_decode(jnp.asarray(u),
                                       jax.random.PRNGKey(0), spec))
        np.testing.assert_array_equal(got, topk_reference(u, ratio))
        assert (np.count_nonzero(got, axis=1)
                <= topk_k(shape[1], ratio)).all()


@pytest.mark.fast
def test_topk_tie_breaking_pinned():
    """Equal-magnitude ties resolve lowest-index-first on BOTH sides
    (lax.top_k's contract == stable argsort) — a silent tie-flip would
    break loop/vmap bit-agreement of the deterministic codec."""
    u = np.array([[0.5, -2.0, 2.0, 1.0, -1.0]], np.float32)
    spec = CompressionSpec(mode="topk", ratio=0.4)  # k = 2
    got = np.asarray(encode_decode(jnp.asarray(u), jax.random.PRNGKey(0),
                                   spec))
    np.testing.assert_array_equal(got, topk_reference(u, 0.4))
    np.testing.assert_array_equal(got, [[0.0, -2.0, 2.0, 0.0, 0.0]])


@pytest.mark.fast
@pytest.mark.parametrize("D", [3, 50, 512])
def test_int8_matches_reference(D):
    """int8 stochastic rounding == numpy oracle when both consume the SAME
    U[0,1) noise block (drawn from the real codec key schedule)."""
    u = np.asarray(jax.random.normal(jax.random.PRNGKey(D), (3, D),
                                     jnp.float32)) * 5.0
    key = compress_round_key(jax.random.PRNGKey(7))
    noise = jax.random.uniform(key, u.shape, jnp.float32)
    spec = CompressionSpec(mode="int8")
    got = np.asarray(encode_decode(jnp.asarray(u), key, spec))
    np.testing.assert_array_equal(got, int8_reference(u, np.asarray(noise)))
    # the wire alphabet really is 8-bit: decoded / scale ∈ [-127, 127] ints
    scale = np.maximum(np.abs(u).max(axis=1), 1e-12) / 127.0
    q = got / scale[:, None]
    np.testing.assert_allclose(q, np.round(q), atol=1e-4)
    assert np.abs(q).max() <= 127.0 + 1e-4


# ---------------------------------------------------------------------------
# public-copy conservation: c + (m − pub') == m − pub, lockstep copies


def _conservation_case(seed: int, mode: str, D: int, drop: int):
    rng = np.random.default_rng(seed)
    m = rng.normal(size=(K, D)).astype(np.float32)
    pub = rng.normal(scale=0.9, size=(K, D)).astype(np.float32)
    P = np.asarray(mix_matrix("pushsum", seed, K, "exponential", None),
                   np.float32)
    sent = P.copy()
    np.fill_diagonal(sent, 0.0)
    if drop:  # a client with no off-diagonal column mass transmits nothing
        sent[:, drop % K] = 0.0
    spec = CompressionSpec(mode=mode)
    noise = rng.random(size=(K, D)).astype(np.float32)
    c, pub2 = ef_encode_reference(m, pub, sent, spec, noise=noise)
    sends = sent.sum(axis=0) > 0
    u = m - pub
    # transmitting clients: the copy advances in LOCKSTEP with the wire
    # (pub' is exactly pub + c — what every receiver reconstructs), so
    # the owed mass splits exactly between the wire and the implicit
    # residual: c + (m − pub') ≈ m − pub, with truncation living in the
    # residual, never destroyed
    np.testing.assert_array_equal(pub2[sends], (pub + c)[sends])
    np.testing.assert_allclose((c + (m - pub2))[sends], u[sends],
                               rtol=1e-6, atol=1e-6)
    if mode == "topk":
        # the delta's dropped coordinates carry c = 0 exactly, so their
        # owed mass survives bitwise; kept coordinates ship bf16
        k = topk_k(D, spec.ratio)
        assert (np.count_nonzero(c[sends], axis=1) <= k).all()
        dropped = sends[:, None] & (c == 0.0)
        np.testing.assert_array_equal((m - pub2)[dropped], u[dropped])
    # silent clients: nothing on the wire, copy untouched — receivers saw
    # no update, so advancing pub through a §3.4 dropout would
    # desynchronize sender and receivers
    np.testing.assert_array_equal(c[~sends], 0.0)
    np.testing.assert_array_equal(pub2[~sends], pub[~sends])


@given(st.integers(0, 1000), st.sampled_from(["topk", "int8"]),
       st.integers(1, 200), st.integers(0, K))
def test_ef_conservation_property(seed, mode, D, drop):
    """Wire-plus-residual mass is conserved, copies advance in lockstep,
    and silent clients keep their copy, for any message/copy/topology
    draw."""
    _conservation_case(seed, mode, D, drop)


@pytest.mark.fast
def test_ef_conservation_pinned():
    """Deterministic twin of the conservation property (runs even when
    hypothesis is not installed)."""
    for seed, mode, D, drop in [(0, "topk", 64, 0), (1, "topk", 7, 2),
                                (2, "int8", 64, 0), (3, "int8", 33, 1)]:
        _conservation_case(seed, mode, D, drop)


@pytest.mark.fast
def test_jax_ef_matches_reference_through_mix():
    """One full compressed sync round on device == the numpy executable
    spec, including the public copies it leaves behind (both sides
    warm-start the copies at z0)."""
    from repro.core.compress import compressed_pushsum_mix
    rng = np.random.default_rng(5)
    z = rng.normal(size=(K, 96)).astype(np.float32)
    w = np.ones(K, np.float32)
    P = np.asarray(mix_matrix("pushsum", 3, K, "exponential", None),
                   np.float32)
    spec = CompressionSpec(mode="topk", ratio=0.25)
    z2, w2, pub2 = compressed_pushsum_mix(
        jnp.asarray(z), jnp.asarray(w), jnp.asarray(P),
        jnp.asarray(z), jax.random.PRNGKey(0), spec)
    ref_z, ref_w, ref_pub = compressed_gossip_reference(z, w, [P], spec)
    np.testing.assert_allclose(np.asarray(z2), ref_z, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(w2), ref_w, rtol=1e-7)
    np.testing.assert_allclose(np.asarray(pub2), ref_pub, rtol=1e-6,
                               atol=1e-7)


# ---------------------------------------------------------------------------
# wire formats


@pytest.mark.fast
def test_wire_bytes_reduction_floors():
    """The byte claims the benchmarks and CI gate rely on: ≥4x for top-k
    at ratio 0.25 (6.4x structural), ~4x for int8, at paper-scale D."""
    for D in (1_000, 44_860, 1_000_000):
        none = wire_bytes("none", D)
        assert none == 4 * D
        assert none / wire_bytes("topk", D, 0.25) >= 4.0
        assert none / wire_bytes("int8", D) >= 3.9
    assert wire_bytes("topk", 8, 1.0) == 1 + 16  # bitmap + all values
    with pytest.raises(ValueError):
        wire_bytes("gzip", 100)


@pytest.mark.fast
def test_compress_spec_none_is_bypass(mlp_spec):
    """compress="none" builds NO spec and NO state wrapper: the engine
    runs the uncompressed round programs verbatim (bitwise equality across
    backends is pinned by the conformance compress-none cases)."""
    cfg = ProxyFLConfig(n_clients=K, rounds=1, batch_size=50,
                        dp=DPConfig(enabled=False))
    assert compress_spec(cfg) is None
    eng = single_model_engine(mlp_spec, cfg, False, mix="pushsum",
                              backend="vmap")
    assert eng.compress is None and not eng._compressed
    state = eng.init_states(jax.random.PRNGKey(0))
    assert "ef_state" not in state
    ceng = single_model_engine(
        mlp_spec, dataclasses.replace(cfg, compress="topk"), False,
        mix="pushsum", backend="vmap")
    cstate = ceng.init_states(jax.random.PRNGKey(0))
    assert cstate["ef_state"].shape[0] == K and cstate["ef_state"].dtype \
        == jnp.float32
    # warm start: the copies ARE the initial proxies (the one-time setup
    # broadcast), not zeros
    np.testing.assert_array_equal(
        np.asarray(cstate["ef_state"]),
        np.asarray(jax.vmap(tree_flatten_vector)(
            cstate["clients"]["proxy"]["params"])).astype(np.float32))


def test_shard_map_rejects_compression(mlp_spec):
    """The ppermute exchange ships full-precision tensors — compression
    must refuse at construction, not silently run uncompressed."""
    cfg = ProxyFLConfig(n_clients=1, rounds=1, batch_size=50,
                        compress="int8", dp=DPConfig(enabled=False))
    vmap_eng = single_model_engine(mlp_spec, cfg, False, mix="pushsum",
                                   backend="vmap", n_clients=1)
    mesh = jax.make_mesh((1,), ("clients",))
    with pytest.raises(ValueError, match="shard_map"):
        FederationEngine(cfg, n_clients=1, step_fns=vmap_eng.step_fns[0],
                         init_fns=vmap_eng.init_fns[0],
                         sample_fn=vmap_eng.sample_fn, backend="shard_map",
                         mix="pushsum", mesh=mesh, axis="clients")


# ---------------------------------------------------------------------------
# engine vs executable spec: lr=0 isolates the exchange


def test_engine_matches_compressed_gossip_reference(mlp_spec, dataset):
    """With lr=0 (local steps are exact no-ops) the engine's compressed
    vmap rounds must reproduce ``compressed_gossip_reference`` — z, w AND
    the carried public copies — from the same z0 and round schedule
    (both warm-start the copies at z0)."""
    T = 3
    cfg = ProxyFLConfig(n_clients=K, rounds=T, batch_size=50, local_steps=1,
                        lr=0.0, compress="topk", compress_ratio=0.25,
                        dp=DPConfig(enabled=False))
    eng = single_model_engine(mlp_spec, cfg, False, mix="pushsum",
                              backend="vmap")
    key = jax.random.PRNGKey(0)
    state = eng.init_states(key)
    z0 = np.asarray(jax.vmap(tree_flatten_vector)(
        state["clients"]["proxy"]["params"]))
    w0 = np.asarray(state["clients"]["w"])
    state, _ = eng.run_rounds(state, dataset, 0, T, key)
    z = np.asarray(jax.vmap(tree_flatten_vector)(
        state["clients"]["proxy"]["params"]))
    Ps = [np.asarray(mix_matrix("pushsum", t, K, cfg.topology, None))
          for t in range(T)]
    ref_z, ref_w, ref_pub = compressed_gossip_reference(
        z0, w0, Ps, CompressionSpec(mode="topk", ratio=0.25))
    np.testing.assert_allclose(z, ref_z, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(state["clients"]["w"]), ref_w,
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(state["ef_state"]), ref_pub,
                               rtol=1e-5, atol=1e-6)


def test_stale_w_mass_conserved_under_compression(mlp_spec, dataset):
    """async τ=2 + int8 + §3.4 dropout: de-bias weights are NEVER
    compressed, so total w-mass (clients + in-flight buffer) stays exactly
    K every round even while the θ payload is quantized."""
    cfg = ProxyFLConfig(n_clients=K, rounds=4, batch_size=50, local_steps=1,
                        lr=0.0, staleness=2, compress="int8",
                        dp=DPConfig(enabled=False))
    eng = single_model_engine(mlp_spec, cfg, False, mix="pushsum",
                              backend="async")
    key = jax.random.PRNGKey(0)
    state = eng.init_states(key)
    masks = [np.array([True, False, True, True]), None,
             np.array([False, True, False, True]), None]
    for t, act in enumerate(masks):
        state, _ = eng.run_round(state, dataset, t, round_key(key, t),
                                 active=act)
        w_mass = (np.asarray(state["clients"]["w"]).sum()
                  + np.asarray(state["stale_w"]).sum())
        np.testing.assert_allclose(w_mass, K, rtol=1e-6)


# ---------------------------------------------------------------------------
# trajectories, checkpoints, guard rails (run_federated level)


def _run(mlp_spec, dataset, cfg, **kw):
    return run_federated("proxyfl", [mlp_spec] * K, mlp_spec, dataset,
                         dataset[0], cfg, seed=0, eval_every=cfg.rounds,
                         backend="vmap", **kw)


def _proxy_flats(res):
    return np.stack([np.asarray(tree_flatten_vector(c.proxy_params))
                     for c in res["clients"]])


@pytest.mark.fast
def test_compression_engages(mlp_spec, dataset):
    """topk/int8 trajectories genuinely differ from uncompressed (the
    dispatch is live, not a silent fall-through) and from each other."""
    cfg = ProxyFLConfig(n_clients=K, rounds=2, batch_size=50, local_steps=2,
                        dp=DPConfig(enabled=False))
    flats = {mode: _proxy_flats(_run(mlp_spec, dataset, dataclasses.replace(
        cfg, compress=mode))) for mode in ("none", "topk", "int8")}
    assert not np.array_equal(flats["none"], flats["topk"])
    assert not np.array_equal(flats["none"], flats["int8"])
    assert not np.array_equal(flats["topk"], flats["int8"])


def test_compressed_kill_resume_bit_identical(tmp_path, mlp_spec, dataset):
    """Kill a compressed (topk) federation at a checkpoint edge and
    resume: bit-identity holds only if the codec's public copies
    round-trip through the snapshot exactly."""
    cfg = ProxyFLConfig(n_clients=K, rounds=4, batch_size=50, local_steps=2,
                        compress="topk", compress_ratio=0.1,
                        dp=DPConfig(enabled=True, noise_multiplier=1.0,
                                    clip_norm=1.0))
    d = os.path.join(str(tmp_path), "ck")
    ref = _run(mlp_spec, dataset, cfg)
    ckpt = dict(checkpoint_dir=d, checkpoint_every=2)
    _run(mlp_spec, dataset, dataclasses.replace(cfg, rounds=2), **ckpt)
    resumed = _run(mlp_spec, dataset, cfg, resume=True, **ckpt)
    np.testing.assert_array_equal(_proxy_flats(ref), _proxy_flats(resumed))
    assert resumed["epsilon"] == ref["epsilon"]
    # the copies are real state by round 2: nonzero in the snapshot
    import glob
    npz = sorted(glob.glob(os.path.join(d, "proxyfl_s0", "*.npz")))
    assert npz, "checkpoint snapshots missing"
    snap = np.load(npz[-1])
    rkeys = [k for k in snap.files if "compress_ef_state" in k]
    assert rkeys, f"no codec state in checkpoint: {snap.files[:8]}..."
    assert any(np.abs(snap[k]).sum() > 0 for k in rkeys)


def test_fingerprint_refuses_compression_mismatch(tmp_path, mlp_spec,
                                                  dataset):
    """A checkpoint written uncompressed must refuse to resume into a
    compressed run (and vice versa) — the trajectory cannot be replayed
    across a compression-config change."""
    cfg = ProxyFLConfig(n_clients=K, rounds=2, batch_size=50, local_steps=1,
                        dp=DPConfig(enabled=False))
    d = os.path.join(str(tmp_path), "ck")
    ckpt = dict(checkpoint_dir=d, checkpoint_every=1)
    _run(mlp_spec, dataset, cfg, **ckpt)
    with pytest.raises(ValueError, match="fingerprint"):
        _run(mlp_spec, dataset,
             dataclasses.replace(cfg, rounds=3, compress="topk"),
             resume=True, **ckpt)
