"""Loss-function unit + property tests. The TP-friendly CE rewrite must be
numerically identical to the naive take_along_axis formulation."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, st

from repro.nn.losses import (accuracy, cross_entropy, dml_loss, kl_divergence,
                             macro_accuracy)


def _naive_ce(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    return jnp.mean(nll)


@given(st.integers(0, 2**31 - 1), st.integers(2, 6), st.integers(2, 33))
def test_ce_matches_naive(seed, b, v):
    k = jax.random.PRNGKey(seed)
    logits = 4.0 * jax.random.normal(k, (b, 5, v))
    labels = jax.random.randint(jax.random.fold_in(k, 1), (b, 5), 0, v)
    np.testing.assert_allclose(float(cross_entropy(logits, labels)),
                               float(_naive_ce(logits, labels)),
                               rtol=1e-5, atol=1e-6)


@given(st.integers(0, 2**31 - 1))
def test_kl_nonnegative_and_zero_at_self(seed):
    k = jax.random.PRNGKey(seed)
    p = jax.random.normal(k, (3, 4, 11))
    q = jax.random.normal(jax.random.fold_in(k, 1), (3, 4, 11))
    assert float(kl_divergence(p, q)) >= -1e-6
    assert abs(float(kl_divergence(p, p))) < 1e-6


def test_kl_asymmetric():
    k = jax.random.PRNGKey(0)
    p = jax.random.normal(k, (2, 3, 9))
    q = 3.0 * jax.random.normal(jax.random.fold_in(k, 1), (2, 3, 9))
    assert not np.isclose(float(kl_divergence(p, q)), float(kl_divergence(q, p)))


def test_dml_loss_interpolates():
    k = jax.random.PRNGKey(0)
    own = jax.random.normal(k, (4, 8, 13))
    peer = jax.random.normal(jax.random.fold_in(k, 1), (4, 8, 13))
    labels = jax.random.randint(jax.random.fold_in(k, 2), (4, 8), 0, 13)
    ce = float(cross_entropy(own, labels))
    kl = float(kl_divergence(own, peer))
    for a in (0.0, 0.3, 1.0):
        expect = (1 - a) * ce + a * kl
        got = float(dml_loss(own, peer, labels, a))
        np.testing.assert_allclose(got, expect, rtol=1e-5)


def test_dml_no_gradient_through_peer():
    k = jax.random.PRNGKey(0)
    own = jax.random.normal(k, (2, 4, 7))
    labels = jnp.zeros((2, 4), jnp.int32)

    def f(peer):
        return dml_loss(own, peer, labels, 0.5)

    g = jax.grad(f)(jax.random.normal(jax.random.fold_in(k, 1), (2, 4, 7)))
    assert float(jnp.abs(g).max()) == 0.0


def test_ce_masked():
    k = jax.random.PRNGKey(0)
    logits = jax.random.normal(k, (2, 6, 5))
    labels = jax.random.randint(jax.random.fold_in(k, 1), (2, 6), 0, 5)
    mask = jnp.zeros((2, 6)).at[:, :3].set(1.0)
    full = cross_entropy(logits[:, :3], labels[:, :3])
    masked = cross_entropy(logits, labels, mask)
    np.testing.assert_allclose(float(full), float(masked), rtol=1e-5)


def test_macro_accuracy_balanced_vs_skewed():
    # a constant predictor gets high accuracy on skewed labels but low
    # macro-accuracy
    labels = jnp.asarray([0] * 9 + [1])
    logits = jnp.tile(jnp.asarray([[5.0, 0.0]]), (10, 1))
    assert abs(float(accuracy(logits, labels)) - 0.9) < 1e-6
    assert abs(float(macro_accuracy(logits, labels, 2)) - 0.5) < 1e-6
