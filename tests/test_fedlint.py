"""fedlint's own tests: every rule must FIRE on its bad snippet, stay
quiet on the good twin, and — the pinned baseline — report zero findings
on the real tree. The perturbation tests are the acceptance contract:
adding an unfingerprinted config field or an uncheckpointed scan-carry
key to a copy of the real sources must produce a finding."""

import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))

from tools.fedlint import cli  # noqa: E402


def lint(root, paths, select=None):
    findings, errors = cli.run(paths, root=root, select=select)
    assert not errors, errors
    return findings


def tree(tmp_path, files):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src)
    return tmp_path


def rules_of(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# FED001 rng-discipline
# ---------------------------------------------------------------------------

def test_rng_whitelist_fires_on_rogue_site(tmp_path):
    root = tree(tmp_path, {"src/repro/core/rogue.py": (
        "import jax\n"
        "def helper():\n"
        "    return jax.random.PRNGKey(0)\n")})
    fs = lint(root, ["src"], select=["FED001"])
    assert len(fs) == 1 and fs[0].rule == "FED001"
    assert "non-canonical site" in fs[0].message
    assert fs[0].line == 3


def test_rng_whitelist_quiet_on_canonical_site(tmp_path):
    # the canonical round_key site, at its real path + function name
    root = tree(tmp_path, {"src/repro/core/engine.py": (
        "import jax\n"
        "ROUND_KEY_OFFSET = 10_000\n"
        "def round_key(base, t):\n"
        "    return jax.random.fold_in(base, ROUND_KEY_OFFSET + t)\n")})
    assert lint(root, ["src"], select=["FED001"]) == []


def test_rng_double_consume_fires(tmp_path):
    root = tree(tmp_path, {"src/repro/data/dbl.py": (
        "import jax\n"
        "def f(k):\n"
        "    a = jax.random.normal(k, (2,))\n"
        "    b = jax.random.uniform(k, (2,))\n"
        "    return a + b\n")})
    fs = lint(root, ["src"], select=["FED001"])
    assert len(fs) == 1 and "already consumed" in fs[0].message
    assert fs[0].line == 4


def test_rng_double_consume_respects_rebind_and_fold_in(tmp_path):
    root = tree(tmp_path, {"src/repro/data/ok.py": (
        "import jax\n"
        "def f(k):\n"
        "    a = jax.random.normal(k, (2,))\n"
        "    k = jax.random.fold_in(k, 1)\n"       # derivation, not a draw
        "    b = jax.random.uniform(k, (2,))\n"    # k was rebound anyway
        "    return a + b\n")})
    assert lint(root, ["src"], select=["FED001"]) == []


# ---------------------------------------------------------------------------
# FED002 trace-hygiene
# ---------------------------------------------------------------------------

BAD_TRACED = (
    "import jax\n"
    "import jax.numpy as jnp\n"
    "@jax.jit\n"
    "def f(x):\n"
    "    if jnp.sum(x) > 0:\n"
    "        return x.item()\n"
    "    return float(x)\n")


def test_trace_hygiene_fires_inside_jit(tmp_path):
    root = tree(tmp_path, {"src/repro/core/badtrace.py": BAD_TRACED})
    fs = lint(root, ["src"], select=["FED002"])
    msgs = " | ".join(f.message for f in fs)
    assert len(fs) == 3
    assert ".item()" in msgs and "boolifies" in msgs and "float()" in msgs


def test_trace_hygiene_quiet_outside_traced_code(tmp_path):
    # identical body, no @jax.jit and never passed to a transform
    root = tree(tmp_path, {"src/repro/core/oktrace.py":
                           BAD_TRACED.replace("@jax.jit\n", "")})
    assert lint(root, ["src"], select=["FED002"]) == []


def test_trace_hygiene_follows_scan_bodies(tmp_path):
    root = tree(tmp_path, {"src/repro/core/scanbody.py": (
        "import jax\n"
        "def body(c, x):\n"
        "    return c, x.item()\n"
        "def run(xs):\n"
        "    return jax.lax.scan(body, 0, xs)\n")})
    fs = lint(root, ["src"], select=["FED002"])
    assert len(fs) == 1 and fs[0].line == 3


def test_trace_hygiene_allows_static_argname_coercion(tmp_path):
    root = tree(tmp_path, {"src/repro/core/staticok.py": (
        "import functools\n"
        "import jax\n"
        "@functools.partial(jax.jit, static_argnames=('eps',))\n"
        "def f(x, eps):\n"
        "    return x * float(eps)\n")})
    assert lint(root, ["src"], select=["FED002"]) == []


# ---------------------------------------------------------------------------
# FED003 carry-coverage (perturbs a copy of the REAL engine.py)
# ---------------------------------------------------------------------------

def engine_tree(tmp_path, extra=""):
    src = (REPO / "src/repro/core/engine.py").read_text() + extra
    return tree(tmp_path, {"src/repro/core/engine.py": src})


def test_carry_coverage_clean_on_real_engine(tmp_path):
    root = engine_tree(tmp_path)
    assert lint(root, ["src"], select=["FED003"]) == []


def test_carry_coverage_fires_on_uncheckpointed_key(tmp_path):
    root = engine_tree(tmp_path, extra=(
        "\n\ndef _fedlint_probe(base):\n"
        "    wrapper = {\"clients\": base}\n"
        "    wrapper[\"never_checkpointed\"] = 1\n"
        "    return wrapper\n"))
    fs = lint(root, ["src"], select=["FED003"])
    assert len(fs) == 2  # missing from BOTH _ckpt_payload and restore_state
    assert all("never_checkpointed" in f.message for f in fs)


def test_carry_coverage_fires_on_dropped_hier_buffer(tmp_path):
    """Deleting the hier cross-shard buffer from ``_ckpt_payload`` on a
    copy of the REAL engine must be a finding: a hier-τ>0 resume without
    the in-flight buffer silently replays a different trajectory."""
    src = (REPO / "src/repro/core/engine.py").read_text()
    line = '            payload["hier_buffer"] = state["hier_buffer"]\n'
    assert line in src, "engine _ckpt_payload hier line moved — update test"
    root = tree(tmp_path, {"src/repro/core/engine.py":
                           src.replace(line, "", 1)})
    fs = lint(root, ["src"], select=["FED003"])
    assert len(fs) == 1, [f.message for f in fs]
    assert "hier_buffer" in fs[0].message


# ---------------------------------------------------------------------------
# FED004 fingerprint-coverage (perturbs copies of the REAL sources)
# ---------------------------------------------------------------------------

FP_FILES = ("src/repro/configs/base.py",
            "src/repro/checkpoint/federation.py",
            "src/repro/launch/train.py",
            "benchmarks/common.py")


def fp_tree(tmp_path, mutate=None):
    files = {rel: (REPO / rel).read_text() for rel in FP_FILES}
    if mutate:
        rel, old, new = mutate
        assert old in files[rel]
        files[rel] = files[rel].replace(old, new, 1)
    return tree(tmp_path, files)


def test_fingerprint_clean_on_real_sources(tmp_path):
    root = fp_tree(tmp_path)
    assert lint(root, ["src"], select=["FED004"]) == []


def test_fingerprint_fires_on_unthreaded_field(tmp_path):
    root = fp_tree(tmp_path, mutate=(
        "src/repro/configs/base.py",
        "    alpha: float = 0.5",
        "    debug_knob: int = 0\n    alpha: float = 0.5"))
    fs = lint(root, ["src"], select=["FED004"])
    # not settable from either entry point
    assert len(fs) == 2
    assert all("debug_knob" in f.message for f in fs)


def test_fingerprint_fires_on_uncommented_exclude(tmp_path):
    root = fp_tree(tmp_path, mutate=(
        "src/repro/checkpoint/federation.py",
        "DEFAULT_FINGERPRINT_EXCLUDE = (",
        "DEFAULT_FINGERPRINT_EXCLUDE = (\n    \"seed\","))
    fs = lint(root, ["src"], select=["FED004"])
    assert any("no justifying comment" in f.message for f in fs)


def test_fingerprint_fires_on_stale_exclude(tmp_path):
    root = fp_tree(tmp_path, mutate=(
        "src/repro/checkpoint/federation.py",
        "DEFAULT_FINGERPRINT_EXCLUDE = (",
        "DEFAULT_FINGERPRINT_EXCLUDE = (\n"
        "    \"not_a_field\",  # bogus\n"))
    fs = lint(root, ["src"], select=["FED004"])
    assert any("not a ProxyFLConfig field" in f.message for f in fs)


# ---------------------------------------------------------------------------
# FED005 kernel-dtype
# ---------------------------------------------------------------------------

BAD_KERNEL = (
    "import jax\n"
    "import jax.numpy as jnp\n"
    "from jax.experimental import pallas as pl\n"
    "def _bad_kernel(x_ref, y_ref, o_ref):\n"
    "    o_ref[...] = jnp.dot(x_ref[...], y_ref[...])\n"
    "def run(x, y, out_shape):\n"
    "    return pl.pallas_call(_bad_kernel, out_shape=out_shape,\n"
    "                          interpret=True)(x, y)\n")

GOOD_KERNEL = (
    "import jax\n"
    "import jax.numpy as jnp\n"
    "from jax.experimental import pallas as pl\n"
    "from repro.kernels import resolve_interpret\n"
    "def _good_kernel(x_ref, y_ref, o_ref):\n"
    "    acc = jnp.dot(x_ref[...], y_ref[...],\n"
    "                  preferred_element_type=jnp.float32)\n"
    "    o_ref[...] = acc.astype(o_ref.dtype)\n"
    "def run(x, y, out_shape, interpret=None):\n"
    "    return pl.pallas_call(_good_kernel, out_shape=out_shape,\n"
    "                          interpret=resolve_interpret(interpret)\n"
    "                          )(x, y)\n")


def test_kernel_dtype_fires_on_bad_kernel(tmp_path):
    root = tree(tmp_path, {"src/repro/kernels/badk.py": BAD_KERNEL})
    fs = lint(root, ["src"], select=["FED005"])
    msgs = " | ".join(f.message for f in fs)
    assert len(fs) == 2
    assert "hardcoded interpret" in msgs
    assert "preferred_element_type" in msgs


def test_kernel_dtype_quiet_on_good_kernel(tmp_path):
    root = tree(tmp_path, {"src/repro/kernels/goodk.py": GOOD_KERNEL})
    assert lint(root, ["src"], select=["FED005"]) == []


def test_kernel_dtype_ignores_non_kernel_paths(tmp_path):
    root = tree(tmp_path, {"src/repro/core/notkernel.py": BAD_KERNEL})
    assert lint(root, ["src"], select=["FED005"]) == []


# ---------------------------------------------------------------------------
# suppressions (driver-level)
# ---------------------------------------------------------------------------

def test_suppression_with_reason_drops_finding(tmp_path):
    root = tree(tmp_path, {"src/repro/core/supp.py": (
        "import jax\n"
        "def helper():\n"
        "    # fedlint: disable=FED001 -- fixture demonstrating suppression\n"
        "    return jax.random.PRNGKey(0)\n")})
    assert lint(root, ["src"]) == []


def test_suppression_without_reason_is_its_own_finding(tmp_path):
    root = tree(tmp_path, {"src/repro/core/supp.py": (
        "import jax\n"
        "def helper():\n"
        "    return jax.random.PRNGKey(0)  # fedlint: disable=FED001\n")})
    fs = lint(root, ["src"])
    assert rules_of(fs) == {"FED000"}
    assert "mandatory" in fs[0].message


def test_suppression_of_unknown_rule_is_flagged(tmp_path):
    root = tree(tmp_path, {"src/repro/core/supp.py": (
        "x = 1  # fedlint: disable=FED999 -- typo'd rule id\n")})
    fs = lint(root, ["src"])
    assert rules_of(fs) == {"FED000"}
    assert "unknown rule" in fs[0].message


# ---------------------------------------------------------------------------
# the pinned baseline + CLI surface
# ---------------------------------------------------------------------------

def test_real_tree_is_clean():
    """THE baseline: the shipped tree has zero findings. If a rule change
    or a source change breaks this, either fix the true positive or
    extend the config tables/suppressions in the same diff."""
    assert lint(REPO, ["src", "benchmarks"]) == []


def test_cli_exit_codes_and_github_format(tmp_path, capsys):
    root = tree(tmp_path, {"src/repro/core/rogue.py": (
        "import jax\n"
        "k = jax.random.PRNGKey(0)\n")})
    rc = cli.main(["--root", str(root), "--format=github", "src"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "::error file=src/repro/core/rogue.py,line=2," in out
    root2 = tree(tmp_path / "clean", {"src/repro/core/empty.py": "x = 1\n"})
    assert cli.main(["--root", str(root2), "src"]) == 0


def test_cli_rejects_unknown_rule_selection(tmp_path):
    with pytest.raises(SystemExit):
        cli.run(["src"], root=REPO, select=["FED042"])
