"""FederationEngine semantics: §3.4 dropout/join (inactive clients frozen,
PushSum mass conserved under time-varying membership), the unified mixing
matrices behind every METHODS-table aggregation rule, checkpoint round-
trips, and backend construction rules. Cross-backend EQUIVALENCE (loop ==
vmap == async-τ0, blocked == per-round, ...) lives in the table-driven
matrix of tests/test_conformance.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DPConfig, ProxyFLConfig
from repro.core.engine import (FederationEngine, active_mask, dml_engine,
                               single_model_engine)
from repro.core.gossip import mix_matrix, pushsum_mix
from repro.core.protocol import ModelSpec
from repro.data.synthetic import make_classification_data
from repro.nn.modules import tree_flatten_vector
from repro.nn.vision import get_vision_model

K, N_CLASSES, SHAPE = 4, 10, (14, 14, 1)


@pytest.fixture(scope="module")
def fed_data():
    key = jax.random.PRNGKey(0)
    x, y = make_classification_data(key, 1200, SHAPE, N_CLASSES, sep=2.0)
    return [(x[i * 300:(i + 1) * 300], y[i * 300:(i + 1) * 300])
            for i in range(K)]


@pytest.fixture(scope="module")
def mlp_spec():
    vm = get_vision_model("mlp")
    return ModelSpec("mlp", lambda k: vm.init(k, SHAPE, N_CLASSES), vm.apply)


def _flat_clients(states):
    if isinstance(states, list):  # loop backend
        return np.stack([np.asarray(tree_flatten_vector(s["proxy"]["params"]))
                         for s in states])
    return np.asarray(jax.vmap(tree_flatten_vector)(states["proxy"]["params"]))


def _flat_private(states):
    if isinstance(states, list):
        return np.stack([np.asarray(tree_flatten_vector(s["private"]["params"]))
                         for s in states])
    return np.asarray(
        jax.vmap(tree_flatten_vector)(states["private"]["params"]))


# ---------------------------------------------------------------------------
# dropout / join (§3.4)


@pytest.mark.fast
@pytest.mark.parametrize("backend", ("loop", "vmap"))
def test_dropout_mass_conservation(fed_data, mlp_spec, backend):
    """With clients dropping in/out every round, PushSum stays column-
    stochastic on the full cohort: total parameter mass and total w are
    conserved, and an inactive client's state is untouched that round.
    lr=0 isolates the gossip dynamics from local training."""
    cfg = ProxyFLConfig(n_clients=K, rounds=4, batch_size=50, local_steps=1,
                        lr=0.0, dp=DPConfig(enabled=False))
    key = jax.random.PRNGKey(0)
    eng = single_model_engine(mlp_spec, cfg, False, mix="pushsum",
                              backend=backend)
    state = eng.init_states(key)
    mass0 = _flat_clients(state).sum()
    masks = [np.array([True, False, True, True]),
             np.array([False, True, False, True]),
             None,
             np.array([True, True, False, False])]
    for t, act in enumerate(masks):
        before = _flat_clients(state)
        state, _ = eng.run_round(state, fed_data, t,
                                 jax.random.fold_in(key, t), active=act)
        after = _flat_clients(state)
        w = np.asarray([np.asarray(s["w"]) for s in eng.export_states(state)])
        np.testing.assert_allclose(after.sum(), mass0, rtol=1e-5)
        np.testing.assert_allclose(w.sum(), K, rtol=1e-6)
        if act is not None:
            for k in np.where(~act)[0]:
                np.testing.assert_array_equal(before[k], after[k])


def test_dropout_schedule_deterministic():
    cfg = ProxyFLConfig(n_clients=8, dropout_rate=0.5, seed=11)
    a = [active_mask(t, 8, cfg) for t in range(5)]
    b = [active_mask(t, 8, cfg) for t in range(5)]
    for ma, mb in zip(a, b):
        np.testing.assert_array_equal(ma, mb)
        assert ma.sum() >= 1  # min_active floor
    assert any((m != a[0]).any() for m in a[1:])  # time-varying
    assert active_mask(0, 8, ProxyFLConfig(n_clients=8)) is None


@pytest.mark.fast
def test_mix_matrices_column_stochastic_with_active():
    act = np.array([True, False, True, True, False, True])
    for mix in ("pushsum", "mean", "ring", "none"):
        for t in range(4):
            P = mix_matrix(mix, t, 6, "exponential", act if mix != "none" else None)
            np.testing.assert_allclose(P.sum(axis=0), 1.0, atol=1e-9,
                                       err_msg=mix)
            # inactive clients: identity column AND row (no send, no recv)
            if mix != "none":
                for k in np.where(~act)[0]:
                    assert P[k, k] == 1.0 and P[:, k].sum() == 1.0
                    assert P[k, :].sum() == 1.0


def test_cwt_ring_is_pure_permutation():
    P = mix_matrix("ring", 0, 5, "exponential")
    assert ((P == 0) | (P == 1)).all() and (P.sum(axis=1) == 1).all()
    thetas = jnp.arange(5.0)[:, None]
    mixed, w = pushsum_mix(thetas, jnp.ones(5), P)
    # client k receives client k-1's model (cyclical weight transfer)
    np.testing.assert_allclose(np.asarray(mixed)[:, 0], [4., 0., 1., 2., 3.])
    np.testing.assert_allclose(np.asarray(w), 1.0)


# ---------------------------------------------------------------------------
# shard_map backend (1-device smoke; K=4 equivalence runs in the forced
# multi-device subprocess of test_system, if present)


def test_shard_map_backend_smoke(fed_data, mlp_spec, tmp_path):
    import os
    mesh = jax.make_mesh((1,), ("clients",))
    cfg = ProxyFLConfig(n_clients=1, rounds=1, batch_size=50, local_steps=2,
                        dp=DPConfig(enabled=False))
    vmap_eng = single_model_engine(mlp_spec, cfg, False, mix="pushsum",
                                   backend="vmap")
    eng = FederationEngine(
        cfg, n_clients=1, step_fns=vmap_eng.step_fns[0],
        init_fns=vmap_eng.init_fns[0], sample_fn=vmap_eng.sample_fn,
        backend="shard_map", mix="pushsum", mesh=mesh, axis="clients")
    key = jax.random.PRNGKey(0)
    state = eng.init_states(key)
    state, metrics = eng.run_round(state, fed_data[:1], 0, key)
    assert np.isfinite(metrics["loss"]).all()
    # snapshot gathers mesh-resident state off-device and restores bit-exact
    path = os.path.join(str(tmp_path), "snap")
    eng.save_state(path, state, 0, base_key=key)
    restored, done = eng.restore_state(path, like=eng.init_states(key),
                                       base_key=key)
    assert done == 1
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.fast
def test_save_restore_midrun_keeps_backend_equivalence(tmp_path, fed_data,
                                                       mlp_spec):
    """Checkpoint after round 0, restore, finish round 1: each backend's
    resumed trajectory is bit-identical to its own uninterrupted one, and
    loop==vmap equivalence survives the round trip."""
    import os
    cfg = ProxyFLConfig(n_clients=K, rounds=2, batch_size=50, local_steps=2,
                        dp=DPConfig(enabled=True))
    key = jax.random.PRNGKey(0)
    finals = {}
    for backend in ("loop", "vmap"):
        eng = dml_engine((mlp_spec,) * K, mlp_spec, cfg, backend=backend)
        state = eng.init_states(key)
        state, _ = eng.run_round(state, fed_data, 0,
                                 jax.random.fold_in(key, 10_000))
        path = os.path.join(str(tmp_path), backend)
        eng.save_state(path, state, 0, base_key=key)
        cont, _ = eng.run_round(state, fed_data, 1,
                                jax.random.fold_in(key, 10_001))
        restored, done = eng.restore_state(path, like=eng.init_states(key))
        assert done == 1
        resumed, _ = eng.run_round(restored, fed_data, 1,
                                   jax.random.fold_in(key, 10_001))
        np.testing.assert_array_equal(_flat_clients(cont),
                                      _flat_clients(resumed))
        finals[backend] = _flat_clients(resumed)
    np.testing.assert_allclose(finals["loop"], finals["vmap"],
                               atol=1e-5, rtol=1e-4)


@pytest.mark.fast
def test_loop_metrics_collate_heterogeneous_keys(fed_data):
    """Two architectures emitting DIFFERENT metric keys must collate to a
    union of keys with NaN fill, not raise KeyError (loop backend)."""
    def init(key):
        return {"proxy": {"params": {"a": jnp.zeros(3)}, "opt": ()},
                "w": jnp.ones((), jnp.float32)}

    def step_a(state, batch, key):
        return state, {"loss": jnp.float32(1.0), "aux_a": jnp.float32(2.0)}

    def step_b(state, batch, key):
        return state, {"loss": jnp.float32(3.0), "aux_b": jnp.float32(4.0)}

    cfg = ProxyFLConfig(n_clients=2, rounds=1, batch_size=4, local_steps=1,
                        dp=DPConfig(enabled=False))
    eng = FederationEngine(cfg, n_clients=2, step_fns=[step_a, step_b],
                           init_fns=[init, init],
                           sample_fn=lambda d, k, n_valid=None: d,
                           backend="loop", mix="none")
    state = eng.init_states(jax.random.PRNGKey(0))
    _, metrics = eng.run_round(state, [fed_data[0], fed_data[1]], 0,
                               jax.random.PRNGKey(1))
    assert set(metrics) == {"loss", "aux_a", "aux_b"}
    np.testing.assert_allclose(metrics["loss"], [1.0, 3.0])
    np.testing.assert_allclose(metrics["aux_a"], [2.0, np.nan])
    np.testing.assert_allclose(metrics["aux_b"], [np.nan, 4.0])
    # same union semantics when one client sits the round out: the union
    # covers ACTIVE clients' keys, the dropout's slots are NaN
    _, metrics = eng.run_round(state, [fed_data[0], fed_data[1]], 1,
                               jax.random.PRNGKey(2),
                               active=np.array([True, False]))
    assert set(metrics) == {"loss", "aux_a"}
    np.testing.assert_allclose(metrics["loss"], [1.0, np.nan])
    np.testing.assert_allclose(metrics["aux_a"], [2.0, np.nan])


def test_heterogeneous_requires_loop(fed_data, mlp_spec):
    vm = get_vision_model("lenet5")
    other = ModelSpec("lenet5", lambda k: vm.init(k, SHAPE, N_CLASSES),
                      vm.apply)
    cfg = ProxyFLConfig(n_clients=2, rounds=1, batch_size=50, local_steps=1,
                        dp=DPConfig(enabled=False))
    eng = dml_engine((mlp_spec, other), mlp_spec, cfg)  # auto -> loop
    assert eng.backend == "loop"
    with pytest.raises(AssertionError):
        dml_engine((mlp_spec, other), mlp_spec, cfg, backend="vmap")
