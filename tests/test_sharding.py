"""Sharding-rule unit tests on an AbstractMesh (no devices needed): the
PartitionSpecs produced for every full-size architecture must divide the
tensor dims they shard, and the placement policy (row/column parallel,
expert parallel, vocab-sharded embeddings, tp/zero1/zero3 modes) must hold."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.configs.base import DPConfig, InputShape, ProxyFLConfig
from repro.configs.registry import proxy_of
from repro.launch.sharding import (batch_pspec, cache_pspecs, choose_mode,
                                   param_pspec, tree_pspecs)

def _abstract_mesh(sizes, names):
    try:  # jax >= 0.5: AbstractMesh(axis_sizes, axis_names)
        return jax.sharding.AbstractMesh(sizes, names)
    except TypeError:  # jax 0.4.x: AbstractMesh(((name, size), ...))
        return jax.sharding.AbstractMesh(tuple(zip(names, sizes)))


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH3 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))
SIZES = {"data": 16, "model": 16}


def _check_divisible(tree, specs):
    flat_s, _ = jax.tree_util.tree_flatten(tree)
    flat_p, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    for sds, spec in zip(flat_s, flat_p):
        for d, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for a in axes:
                n *= SIZES.get(a, 2)
            assert sds.shape[d] % n == 0, (sds.shape, spec, d)


@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_divide(arch):
    from repro.launch.steps import StepOptions, train_state_shapes
    cfg = get_config(arch)
    shapes = train_state_shapes(cfg, proxy_of(cfg),
                                ProxyFLConfig(dp=DPConfig()), StepOptions())
    for fsdp in (False, True):
        specs = tree_pspecs(shapes["private"]["params"], MESH, fsdp_data=fsdp)
        _check_divisible(shapes["private"]["params"], specs)


def test_row_parallel_on_input_dim():
    spec = param_pspec("prefix/0/mixer/wo/w", (4096, 1024), MESH)
    assert spec[0] == "model"  # contraction dim sharded (row parallel)
    spec = param_pspec("prefix/0/mixer/wq/w", (1024, 4096), MESH)
    assert spec[1] == "model"  # output dim sharded (column parallel)


def test_embed_vocab_sharded():
    spec = param_pspec("embed/e", (102400, 5120), MESH)
    assert spec[0] == "model"
    # audio codebook tables are [K, V, d]
    spec = param_pspec("embed/e", (4, 2048, 1536), MESH)
    assert spec[1] == "model"


def test_stack_dim_never_sharded():
    spec = param_pspec("stack/0/ffn/gate/w", (28, 3584, 18944), MESH)
    assert spec[0] is None


def test_small_tensors_replicated():
    spec = param_pspec("prefix/0/norm1/g", (4096,), MESH)
    assert all(s is None for s in spec)


def test_expert_parallel_flag():
    shape = (30, 160, 5120, 1536)  # [stack, experts, d, d_ff]
    tp = param_pspec("stack/0/ffn/gate", shape, MESH, expert_parallel=False)
    ep = param_pspec("stack/0/ffn/gate", shape, MESH, expert_parallel=True)
    assert ep[1] == "model"
    assert tp[1] != "model"


def test_client_stacked_pod_leading():
    spec = param_pspec("stack/0/ffn/gate/w", (2, 28, 3584, 18944), MESH3,
                       client_stacked=True)
    assert spec[0] == "pod"
    assert spec[1] is None  # stack dim after the client dim


def test_choose_mode_thresholds():
    small = {"w": jax.ShapeDtypeStruct((1000, 1000), jnp.float32)}  # 4MB
    assert choose_mode(small, MESH) == "tp"
    big = {"w": jax.ShapeDtypeStruct((200_000, 8192), jnp.bfloat16)}  # 3.3GB
    # params/16 (0.2GB) fits a 1GB budget; params+opt/16 (~1.4GB) doesn't
    assert choose_mode(big, MESH, budget_bytes=1.0e9) == "zero1"
    assert choose_mode(big, MESH, budget_bytes=0.1e9) == "zero3"


def test_batch_pspec_long_context():
    # batch=1: shard the sequence dim instead
    spec = batch_pspec((1, 524288), MESH)
    assert spec[0] is None and spec[1] is not None
    spec = batch_pspec((256, 4096), MESH)
    assert spec[0] is not None


def test_cache_specs_divide():
    from repro.launch.steps import serve_state_shapes
    cfg = get_config("gemma3-4b")
    shapes = serve_state_shapes(cfg, InputShape("d", 32768, 128, "decode"))
    specs = cache_pspecs(shapes["cache"], MESH)
    _check_divisible(shapes["cache"], specs)


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "deepseek-v2-236b",
                                  "arctic-480b", "jamba-1.5-large-398b"])
def test_big_archs_get_zero3(arch):
    from repro.launch.steps import StepOptions, train_state_shapes
    cfg = get_config(arch)
    shapes = train_state_shapes(cfg, proxy_of(cfg),
                                ProxyFLConfig(dp=DPConfig()), StepOptions())
    assert choose_mode(shapes["private"]["params"], MESH) == "zero3"


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "qwen2-7b", "gemma3-4b",
                                  "falcon-mamba-7b", "musicgen-medium"])
def test_small_archs_replicate(arch):
    from repro.launch.steps import StepOptions, train_state_shapes
    cfg = get_config(arch)
    shapes = train_state_shapes(cfg, proxy_of(cfg),
                                ProxyFLConfig(dp=DPConfig()), StepOptions())
    assert choose_mode(shapes["private"]["params"], MESH) in ("tp", "zero1")
