"""Runtime twin of fedlint's FED001: the three canonical key domains are
PAIRWISE DISJOINT over their full operating range.

The schedule (src/repro/core/engine.py, src/repro/core/compress.py):

* per-client init / per-round client streams: ``fold_in(base, k)`` with
  ``k < ROUND_KEY_OFFSET``,
* per-round keys: ``round_key(base, t) = fold_in(base,
  ROUND_KEY_OFFSET + t)``,
* codec keys: ``compress_round_key(rk) = fold_in(rk,
  COMPRESS_KEY_FOLD)``.

The static rule pins WHERE keys may be minted; this pins that the minted
streams never collide — the property a refactor of the 10_000 offset (or
of COMPRESS_KEY_FOLD) would silently break, correlating "independent"
client batches with round noise and voiding the DP accounting.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compress import COMPRESS_KEY_FOLD, compress_round_key
from repro.core.engine import ROUND_KEY_OFFSET, round_key


def _key_set(keys):
    """Set of raw key-data tuples for a batch of vmapped keys."""
    data = np.asarray(jax.random.key_data(keys))
    return {tuple(int(v) for v in row) for row in data.reshape(
        data.shape[0], -1)}


def _streams(base, n):
    ts = jnp.arange(n)
    per_client = jax.vmap(lambda k: jax.random.fold_in(base, k))(ts)
    rounds = jax.vmap(lambda t: round_key(base, t))(ts)
    codec = jax.vmap(compress_round_key)(rounds)
    return per_client, rounds, codec


def test_schedule_constants_pinned():
    # the contract below is stated FOR these values; moving them is a
    # conscious schedule change and must retire/extend this test
    assert ROUND_KEY_OFFSET == 10_000
    assert COMPRESS_KEY_FOLD == 987_654_321
    assert COMPRESS_KEY_FOLD > ROUND_KEY_OFFSET * 2


def test_streams_pairwise_disjoint_full_range():
    """t, k sweep the ENTIRE [0, ROUND_KEY_OFFSET) operating range: every
    per-client stream, every round key, every codec key — no collisions
    within a stream, none across streams."""
    base = jax.random.PRNGKey(0)
    per_client, rounds, codec = _streams(base, ROUND_KEY_OFFSET)
    s_client, s_round, s_codec = map(_key_set, (per_client, rounds, codec))
    n = ROUND_KEY_OFFSET
    assert len(s_client) == len(s_round) == len(s_codec) == n
    assert not s_client & s_round
    assert not s_client & s_codec
    assert not s_round & s_codec


def test_streams_disjoint_across_seeds():
    """The disjointness is not a seed-0 accident, and none of the streams
    reproduce the base key itself."""
    for seed in (1, 7, 123):
        base = jax.random.PRNGKey(seed)
        per_client, rounds, codec = _streams(base, 512)
        s_client, s_round, s_codec = map(_key_set,
                                         (per_client, rounds, codec))
        assert len(s_client | s_round | s_codec) == 3 * 512
        base_tup = next(iter(_key_set(jnp.stack([base]))))
        assert base_tup not in (s_client | s_round | s_codec)


def test_round_key_matches_documented_definition():
    """round_key is DEFINED as fold_in(base, OFFSET + t): the checkpoint
    format's round addressing depends on this exact equation, so a
    refactor that preserves disjointness but changes the mapping still
    breaks resume."""
    base = jax.random.PRNGKey(3)
    for t in (0, 1, 999):
        lhs = jax.random.key_data(round_key(base, t))
        rhs = jax.random.key_data(
            jax.random.fold_in(base, ROUND_KEY_OFFSET + t))
        np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))
