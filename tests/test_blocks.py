"""Round-block execution (``FederationEngine.run_rounds``) engine-level
semantics: stacked [T, K] metric trajectories, block-edge bulk accountant
stepping, checkpoint cadences cut to block edges, the shard_map block, and
batched-vs-sequential cohort evaluation. The end-to-end blocked ==
per-round BIT-IDENTITY assertions (every method × backend × block size,
dropout and DP included) live in the table-driven matrix of
tests/test_conformance.py."""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.configs.base import DPConfig, ProxyFLConfig
from repro.core.baselines import run_federated
from repro.core.engine import dml_engine, round_key, single_model_engine
from repro.core.protocol import ModelSpec, evaluate, evaluate_batched
from repro.data.synthetic import make_classification_data
from repro.nn.modules import tree_flatten_vector
from repro.nn.vision import get_vision_model

K, N_CLASSES, SHAPE = 4, 10, (14, 14, 1)


@pytest.fixture(scope="module")
def fed_data():
    key = jax.random.PRNGKey(0)
    x, y = make_classification_data(key, 1200, SHAPE, N_CLASSES, sep=2.0)
    return [(x[i * 300:(i + 1) * 300], y[i * 300:(i + 1) * 300])
            for i in range(K)]


@pytest.fixture(scope="module")
def mlp_spec():
    vm = get_vision_model("mlp")
    return ModelSpec("mlp", lambda k: vm.init(k, SHAPE, N_CLASSES), vm.apply)


def _final_flats(res):
    out = {}
    for role in ("proxy_params", "private_params", "params"):
        if hasattr(res["clients"][0], role):
            out[role] = np.stack([
                np.asarray(tree_flatten_vector(getattr(c, role)))
                for c in res["clients"]])
    return out


# ---------------------------------------------------------------------------
# engine-level semantics


@pytest.mark.fast
def test_hier_blocks_stack_and_compile_once(fed_data, mlp_spec):
    """A multi-block hier run pads/stacks the cohort data exactly ONCE and
    compiles exactly ONE block program: the factored exchange schedule
    (blocks/src/scale) enters as a runtime argument, so every later block
    must hit both the stacked-data cache and the compiled scan — a
    per-block re-stack or re-trace would silently destroy the amortized
    round-block throughput fig_hier claims."""
    cfg = ProxyFLConfig(n_clients=K, rounds=8, batch_size=50, local_steps=1,
                        n_shards=2, staleness=2, dp=DPConfig(enabled=False))
    eng = single_model_engine(mlp_spec, cfg, False, mix="pushsum",
                              backend="hier")
    eng._data_cache.clear()
    eng._stack_misses = 0
    key = jax.random.PRNGKey(0)
    state = eng.init_states(key)
    misses, progs = [], []
    for blk in range(4):
        state, _ = eng.run_rounds(state, fed_data, blk * 2, 2, key)
        misses.append(eng._stack_misses)
        progs.append(len(eng._rounds))
    assert misses == [1, 1, 1, 1], f"per-block stack misses grew: {misses}"
    assert progs == [1, 1, 1, 1], f"per-block program count grew: {progs}"


@pytest.mark.fast
def test_run_rounds_metrics_stacked_per_round(fed_data, mlp_spec):
    """run_rounds returns [n_rounds, K] metric trajectories matching the
    per-round run_round values bit-for-bit (NaN rows for §3.4 dropouts)."""
    cfg = ProxyFLConfig(n_clients=K, rounds=3, batch_size=50, local_steps=2,
                        dropout_rate=0.3, seed=3,
                        dp=DPConfig(enabled=False))
    key = jax.random.PRNGKey(0)
    eng = dml_engine((mlp_spec,) * K, mlp_spec, cfg, backend="vmap")
    state = eng.init_states(key)
    state_b, ms = eng.run_rounds(state, fed_data, 0, 3, key)

    state_r = eng.init_states(key)
    rows = []
    for t in range(3):
        state_r, m = eng.run_round(state_r, fed_data, t, round_key(key, t))
        rows.append(m)
    for k in ms:
        assert ms[k].shape == (3, K)
        np.testing.assert_array_equal(
            ms[k], np.stack([r[k] for r in rows]), err_msg=k)
    np.testing.assert_array_equal(
        np.asarray(jax.vmap(tree_flatten_vector)(state_b["proxy"]["params"])),
        np.asarray(jax.vmap(tree_flatten_vector)(state_r["proxy"]["params"])))


@pytest.mark.fast
def test_run_rounds_bulk_accountant_matches_per_round(fed_data, mlp_spec):
    """Block-edge bulk accountant stepping lands on the same step counters
    (and therefore the same epsilon) as per-round stepping, dropout
    included."""
    from repro.core.accountant import PrivacyAccountant
    cfg = ProxyFLConfig(n_clients=K, rounds=4, batch_size=50, local_steps=2,
                        dropout_rate=0.4, seed=5,
                        dp=DPConfig(enabled=True))
    key = jax.random.PRNGKey(0)
    counts = {}
    for label, drive in (("block", lambda e, s: e.run_rounds(
            s, fed_data, 0, 4, key)[0]),
            ("perround", None)):
        eng = single_model_engine(mlp_spec, cfg, True, mix="pushsum",
                                  backend="vmap")
        eng.attach_accountants(
            [PrivacyAccountant(1.0, 0.1, 1e-5) for _ in range(K)])
        state = eng.init_states(key)
        if drive is not None:
            state = drive(eng, state)
        else:
            for t in range(4):
                state, _ = eng.run_round(state, fed_data, t,
                                         round_key(key, t))
        counts[label] = [a.steps for a in eng.accountants]
    assert counts["block"] == counts["perround"]


@pytest.mark.fast
def test_blocked_checkpoint_cadence_lands_on_block_edges(tmp_path, fed_data,
                                                         mlp_spec):
    """checkpoint_every=2 with rounds_per_block=4: blocks are CUT at the
    cadence rounds, so the snapshot set equals the per-round loop's, and a
    kill-after-block resume replays bit-identically."""
    from repro.checkpoint.federation import FederationCheckpointer
    cfg = ProxyFLConfig(n_clients=K, rounds=5, batch_size=50, local_steps=1,
                        dp=DPConfig(enabled=False))
    d = os.path.join(str(tmp_path), "ck")
    run = lambda c, **kw: run_federated(
        "proxyfl", [mlp_spec] * K, mlp_spec, fed_data, fed_data[0], c,
        seed=0, eval_every=c.rounds, backend="vmap", rounds_per_block=4,
        checkpoint_dir=d, checkpoint_every=2, **kw)
    ref = run(cfg)
    saved = FederationCheckpointer(
        os.path.join(d, "proxyfl_s0")).saved_rounds()
    assert saved == [2, 4]  # exactly the per-round cadence
    # kill after the first block-edge snapshot, resume, finish
    killed = run(dataclasses.replace(cfg, rounds=2))
    resumed = run(cfg, resume=True)
    for role, v in _final_flats(resumed).items():
        np.testing.assert_array_equal(_final_flats(ref)[role], v,
                                      err_msg=role)
    assert resumed["history"][-1]["round"] == cfg.rounds


def test_run_rounds_shard_map_block_bit_identical(fed_data, mlp_spec):
    """The shard_map block (per-round collective schedules unrolled inside
    one jit) replays run_round bit-exactly — 1-device mesh smoke; the K=4
    equivalence runs in the forced multi-device subprocess elsewhere."""
    from repro.core.engine import FederationEngine
    mesh = jax.make_mesh((1,), ("clients",))
    cfg = ProxyFLConfig(n_clients=1, rounds=2, batch_size=50, local_steps=2,
                        dp=DPConfig(enabled=False))
    vmap_eng = single_model_engine(mlp_spec, cfg, False, mix="pushsum",
                                   backend="vmap")
    key = jax.random.PRNGKey(0)
    finals = {}
    for label in ("block", "perround"):
        eng = FederationEngine(
            cfg, n_clients=1, step_fns=vmap_eng.step_fns[0],
            init_fns=vmap_eng.init_fns[0], sample_fn=vmap_eng.sample_fn,
            backend="shard_map", mix="pushsum", mesh=mesh, axis="clients")
        state = eng.init_states(key)
        if label == "block":
            state, ms = eng.run_rounds(state, fed_data[:1], 0, 2, key)
            assert ms["loss"].shape == (2, 1)
        else:
            for t in range(2):
                state, _ = eng.run_round(state, fed_data[:1], t,
                                         round_key(key, t))
        finals[label] = np.asarray(
            jax.vmap(tree_flatten_vector)(state["proxy"]["params"]))
    np.testing.assert_array_equal(finals["block"], finals["perround"])


# ---------------------------------------------------------------------------
# batched evaluation


@pytest.mark.fast
def test_evaluate_batched_matches_sequential(fed_data, mlp_spec):
    x, y = fed_data[0]
    params = [mlp_spec.init(jax.random.PRNGKey(s)) for s in range(3)]
    stacked = jax.tree_util.tree_map(lambda *xs: jax.numpy.stack(xs), *params)
    batched = evaluate_batched(mlp_spec, stacked, x, y, batch=128)
    seq = [evaluate(mlp_spec, p, x, y, batch=128) for p in params]
    np.testing.assert_allclose(batched, seq, atol=1e-12)
