"""Integration tests of the full ProxyFL protocol and all paper baselines
at toy scale (synthetic non-IID image data, MLP/CNN clients)."""
import jax
import numpy as np
import pytest

from repro.configs.base import DPConfig, ProxyFLConfig
from repro.core.baselines import METHODS, final_mean_acc, run_federated
from repro.core.protocol import ModelSpec
from repro.data.partition import partition_major
from repro.data.synthetic import make_classification_data
from repro.nn.vision import get_vision_model

K, N_CLASSES, SHAPE = 4, 10, (14, 14, 1)


@pytest.fixture(scope="module")
def fed_data():
    key = jax.random.PRNGKey(0)
    x, y = make_classification_data(key, 3000, SHAPE, N_CLASSES, sep=2.0)
    xt, yt = make_classification_data(jax.random.fold_in(key, 1), 800, SHAPE,
                                      N_CLASSES, sep=2.0)
    rng = np.random.default_rng(0)
    idxs = partition_major(rng, np.asarray(y), K, 400, 0.8, N_CLASSES)
    return [(x[i], y[i]) for i in idxs], (xt, yt)


@pytest.fixture(scope="module")
def mlp_spec():
    vm = get_vision_model("mlp")
    return ModelSpec("mlp", lambda k: vm.init(k, SHAPE, N_CLASSES), vm.apply)


@pytest.mark.parametrize("method", METHODS)
def test_every_method_runs(method, fed_data, mlp_spec):
    client_data, test = fed_data
    cfg = ProxyFLConfig(n_clients=K, rounds=1, batch_size=100,
                        dp=DPConfig(enabled=True))
    res = run_federated(method, [mlp_spec] * K, mlp_spec, client_data, test,
                        cfg, eval_every=1)
    assert res["history"], method
    acc = final_mean_acc(res)
    assert 0.0 <= acc <= 1.0
    if method != "regular" or True:
        assert res["epsilon"][0] is not None  # DP accounted for every method


def test_proxyfl_beats_regular_noniid(fed_data, mlp_spec):
    """The paper's core claim at toy scale: under non-IID skew with DP,
    ProxyFL's private models generalize better than isolated Regular
    training."""
    client_data, test = fed_data
    cfg = ProxyFLConfig(n_clients=K, rounds=3, batch_size=100,
                        dp=DPConfig(enabled=True), seed=0)
    prox = run_federated("proxyfl", [mlp_spec] * K, mlp_spec, client_data,
                         test, cfg, eval_every=3)
    reg = run_federated("regular", [mlp_spec] * K, mlp_spec, client_data,
                        test, cfg, eval_every=3)
    assert final_mean_acc(prox) > final_mean_acc(reg) + 0.05


def test_proxyfl_private_beats_proxy(fed_data, mlp_spec):
    """Private models (non-DP) retain higher utility than the DP-trained
    proxies — the mechanism that motivates the two-model design."""
    client_data, test = fed_data
    cfg = ProxyFLConfig(n_clients=K, rounds=3, batch_size=100,
                        dp=DPConfig(enabled=True))
    res = run_federated("proxyfl", [mlp_spec] * K, mlp_spec, client_data,
                        test, cfg, eval_every=3)
    row = res["history"][-1]
    assert np.mean(row["private_acc"]) >= np.mean(row["proxy_acc"]) - 0.02


def test_heterogeneous_private_models(fed_data):
    """Model heterogeneity (paper Fig. 5b): every client may use a different
    private architecture; only the proxy architecture is shared."""
    client_data, test = fed_data
    specs = []
    for name in ("mlp", "lenet5", "cnn1", "cnn2"):
        vm = get_vision_model(name)
        specs.append(ModelSpec(name, lambda k, vm=vm: vm.init(k, SHAPE, N_CLASSES),
                               vm.apply))
    vm = get_vision_model("mlp")
    proxy = ModelSpec("mlp-proxy", lambda k: vm.init(k, SHAPE, N_CLASSES),
                      vm.apply)
    cfg = ProxyFLConfig(n_clients=K, rounds=1, batch_size=100,
                        dp=DPConfig(enabled=True))
    res = run_federated("proxyfl", specs, proxy, client_data, test, cfg)
    assert len(res["clients"]) == K
    # distinct architectures → distinct parameter tree structures
    t0 = jax.tree_util.tree_structure(res["clients"][0].private_params)
    t1 = jax.tree_util.tree_structure(res["clients"][1].private_params)
    assert t0 != t1


def test_epsilon_tracked_per_client(fed_data, mlp_spec):
    client_data, test = fed_data
    cfg = ProxyFLConfig(n_clients=K, rounds=2, batch_size=50,
                        dp=DPConfig(enabled=True, noise_multiplier=1.0))
    res = run_federated("proxyfl", [mlp_spec] * K, mlp_spec, client_data,
                        test, cfg)
    assert all(e is not None and e > 0 for e in res["epsilon"])
    # same data size + same settings → same guarantee
    assert len(set(round(e, 6) for e in res["epsilon"])) == 1


def test_dp_disabled_no_epsilon(fed_data, mlp_spec):
    client_data, test = fed_data
    cfg = ProxyFLConfig(n_clients=K, rounds=1, batch_size=100,
                        dp=DPConfig(enabled=False))
    res = run_federated("proxyfl", [mlp_spec] * K, mlp_spec, client_data,
                        test, cfg)
    assert all(e is None for e in res["epsilon"])


def test_joint_upper_bound(fed_data, mlp_spec):
    """Joint (pooled-data) training should be at least as good as Regular —
    the paper uses it as the upper bound."""
    client_data, test = fed_data
    cfg = ProxyFLConfig(n_clients=K, rounds=2, batch_size=100,
                        dp=DPConfig(enabled=True))
    joint = run_federated("joint", [mlp_spec] * K, mlp_spec, client_data,
                          test, cfg)
    reg = run_federated("regular", [mlp_spec] * K, mlp_spec, client_data,
                        test, cfg)
    assert final_mean_acc(joint) >= final_mean_acc(reg) - 0.02
