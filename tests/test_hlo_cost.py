"""The dry-run cost models: trip-count-corrected jaxpr FLOPs and the HLO
collective parser with while-loop multiplier propagation."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import collective_wire_bytes, step_cost


def test_dot_flops_exact():
    def f(a, b):
        return a @ b

    c = step_cost(f, jax.ShapeDtypeStruct((64, 128), jnp.float32),
                  jax.ShapeDtypeStruct((128, 32), jnp.float32))
    assert c["flops"] == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_batched_dot_flops():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)

    c = step_cost(f, jax.ShapeDtypeStruct((4, 8, 16), jnp.float32),
                  jax.ShapeDtypeStruct((4, 16, 8), jnp.float32))
    assert c["flops"] == pytest.approx(2 * 4 * 8 * 16 * 8, rel=0.01)


def test_scan_multiplies_body_cost():
    W = jax.ShapeDtypeStruct((10, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 32), jnp.float32)

    def f(ws, x):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    c = step_cost(f, W, x)
    one = 2 * 4 * 32 * 32
    assert c["flops"] == pytest.approx(10 * one, rel=0.05)


def test_nested_scan():
    W = jax.ShapeDtypeStruct((3, 5, 16, 16), jnp.float32)
    x = jax.ShapeDtypeStruct((2, 16), jnp.float32)

    def f(ws, x):
        def outer(c, wgroup):
            def inner(ci, w):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c, wgroup)
            return c2, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    c = step_cost(f, W, x)
    assert c["flops"] == pytest.approx(15 * 2 * 2 * 16 * 16, rel=0.05)


def test_grad_counts_backward():
    W = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(w):
        def loss(w):
            return jnp.sum((w @ w) ** 2)
        return jax.grad(loss)(w)

    c = step_cost(f, W)
    fwd = 2 * 32 ** 3
    # fwd + 2 matmuls in backward ≈ 3x forward
    assert c["flops"] >= 2.5 * fwd


def test_remat_recompute_counted():
    W = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 64), jnp.float32)

    def make(remat):
        def f(ws, x):
            def body(c, w):
                return jnp.tanh(c @ w), None
            b = jax.checkpoint(body) if remat else body
            def loss(ws, x):
                y, _ = jax.lax.scan(b, x, ws)
                return jnp.sum(y)
            return jax.grad(loss)(ws, x)
        return f

    base = step_cost(make(False), W, x)["flops"]
    rm = step_cost(make(True), W, x)["flops"]
    assert rm > base * 1.2  # recompute visible in the jaxpr cost


SYNTH_HLO = """\
HloModule test

%body.1 (p: (s32[], f32[16,16])) -> (s32[], f32[16,16]) {
  %ag = f32[16,16]{1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}
  ROOT %t = (s32[], f32[16,16]) tuple(%i, %ag)
}

%cond.1 (p: (s32[], f32[16,16])) -> pred[] {
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[16,16]) -> f32[16,16] {
  %ar = f32[32,8]{1,0} all-reduce(%a), replica_groups=[16,16]<=[256], to_apply=%add
  %w = (s32[], f32[16,16]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"7"}}
  %cp = f32[4,4]{1,0} collective-permute(%a), source_target_pairs={{0,1},{1,0}}
  ROOT %out = f32[16,16] get-tuple-element(%w), index=1
}
"""


def test_collective_parser_synthetic():
    res = collective_wire_bytes(SYNTH_HLO)
    wb = res["wire_bytes"]
    # all-reduce: result 32*8*4 = 1024B, g=16 → 2*(15/16)*1024 = 1920
    assert wb["all-reduce"] == pytest.approx(1920)
    # all-gather inside while ×7: result 16*16*4 = 1024B, g=16 → (15/16)*1024*7
    assert wb["all-gather"] == pytest.approx(7 * 960)
    # permute: result 4*4*4 = 64B
    assert wb["collective-permute"] == pytest.approx(64)
    assert res["total_wire_bytes"] == pytest.approx(1920 + 6720 + 64)


def test_memory_traffic_counts_major_ops():
    def f(a, b):
        return a @ b

    c = step_cost(f, jax.ShapeDtypeStruct((64, 128), jnp.float32),
                  jax.ShapeDtypeStruct((128, 32), jnp.float32))
    want = (64 * 128 + 128 * 32 + 64 * 32) * 4
    assert c["bytes"] == pytest.approx(want, rel=0.01)
