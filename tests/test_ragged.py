"""Ragged (size-skewed) cohorts on the compiled stacked path: padded
stacking semantics, masked sampling (padding never drawn), padded-
checkpoint bit-identity, the keyed stacked-data LRU, and the honest
``auto`` backend selector. The loop==vmap(==async-τ0) equivalence on
Dirichlet cohorts lives in the table-driven matrix of
tests/test_conformance.py. Partition property tests (disjointness,
bounds) ride along."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, st

import repro.core.engine as engine_mod
from repro.configs.base import DPConfig, ProxyFLConfig
from repro.core.baselines import _resolve_backend
from repro.core.engine import classifier_sampler, dml_engine
from repro.core.protocol import ModelSpec
from repro.data.partition import partition_dirichlet, partition_major
from repro.data.ragged import client_lengths, pad_compatible, pad_stack
from repro.data.synthetic import make_classification_data
from repro.nn.modules import tree_flatten_vector
from repro.nn.vision import get_vision_model

K, N_CLASSES, SHAPE = 4, 10, (14, 14, 1)


@pytest.fixture(scope="module")
def ragged_data():
    """Dirichlet(0.5)-partitioned synthetic cohort — genuinely ragged."""
    key = jax.random.PRNGKey(0)
    x, y = make_classification_data(key, 1200, SHAPE, N_CLASSES, sep=2.0)
    rng = np.random.default_rng(0)
    idxs = partition_dirichlet(rng, np.asarray(y), K, 0.5)
    data = [(x[i], y[i]) for i in idxs]
    sizes = {d[0].shape[0] for d in data}
    assert len(sizes) > 1, "fixture must be ragged"
    return data


@pytest.fixture(scope="module")
def mlp_spec():
    vm = get_vision_model("mlp")
    return ModelSpec("mlp", lambda k: vm.init(k, SHAPE, N_CLASSES), vm.apply)


def _flat(engine, state, role):
    if isinstance(state, list):
        return np.stack([np.asarray(tree_flatten_vector(s[role]["params"]))
                         for s in state])
    return np.asarray(jax.vmap(tree_flatten_vector)(state[role]["params"]))


# ---------------------------------------------------------------------------
# padded stacking layer


@pytest.mark.fast
def test_pad_stack_shapes_and_lengths(ragged_data):
    stacked, n_valid = pad_stack(ragged_data)
    sizes = [d[0].shape[0] for d in ragged_data]
    n_max = max(sizes)
    assert stacked[0].shape == (K, n_max) + SHAPE
    assert stacked[1].shape == (K, n_max)
    np.testing.assert_array_equal(np.asarray(n_valid), sizes)
    np.testing.assert_array_equal(client_lengths(ragged_data), sizes)
    # real rows survive unchanged; padding rows hold the fill value
    for k, (x, y) in enumerate(ragged_data):
        np.testing.assert_array_equal(np.asarray(stacked[0][k, :sizes[k]]),
                                      np.asarray(x))
        np.testing.assert_array_equal(
            np.asarray(stacked[0][k, sizes[k]:]), 0.0)


@pytest.mark.fast
def test_pad_stack_rejects_empty_client():
    x = jnp.ones((4, 3)), jnp.ones((4,))
    empty = jnp.ones((0, 3)), jnp.ones((0,))
    with pytest.raises(ValueError, match="zero examples"):
        pad_stack([x, empty])


@pytest.mark.fast
def test_pad_compatible_semantics():
    a = (jnp.ones((10, 3)), jnp.zeros((10,), jnp.int32))
    b = (jnp.ones((7, 3)), jnp.zeros((7,), jnp.int32))
    assert pad_compatible([a, b])                     # ragged leading: fine
    c = (jnp.ones((7, 4)), jnp.zeros((7,), jnp.int32))
    assert not pad_compatible([a, c])                 # trailing dim differs
    d = (jnp.ones((7, 3)), jnp.zeros((7,), jnp.float32))
    assert not pad_compatible([a, d])                 # dtype differs
    e = {"x": jnp.ones((7, 3))}
    assert not pad_compatible([a, e])                 # tree structure differs
    f = (jnp.ones((7, 3)), jnp.zeros((9,), jnp.int32))
    assert not pad_compatible([a, f])                 # inconsistent client
    assert not pad_compatible([])


@pytest.mark.fast
def test_masked_sampler_never_draws_padding():
    """Pad with NaN, sample many batches bounded by n_valid: a single drawn
    padding row would poison the batch with NaN."""
    n_valid = 37
    x = jnp.concatenate([jnp.ones((n_valid, 3)),
                         jnp.full((63, 3), jnp.nan)])
    y = jnp.concatenate([jnp.zeros((n_valid,)), jnp.full((63,), jnp.nan)])
    sample = classifier_sampler(16)
    for i in range(50):
        xb, yb = sample((x, y), jax.random.PRNGKey(i),
                        jnp.asarray(n_valid, jnp.int32))
        assert np.isfinite(np.asarray(xb)).all()
        assert np.isfinite(np.asarray(yb)).all()


def test_engine_round_never_touches_padding(ragged_data, mlp_spec,
                                            monkeypatch):
    """Engine-level proof: force NaN padding inside ``_stack_data`` — one
    sampled padding row or one unmasked step would make params non-finite."""
    monkeypatch.setattr(engine_mod, "pad_stack",
                        lambda data: pad_stack(data, fill=float("nan")))
    cfg = ProxyFLConfig(n_clients=K, rounds=1, batch_size=50, local_steps=0,
                        lr=1e-3, dp=DPConfig(enabled=False))
    eng = dml_engine((mlp_spec,) * K, mlp_spec, cfg, backend="vmap")
    eng._data_cache.clear()  # dml_engine is LRU-cached; force a re-stack
    key = jax.random.PRNGKey(3)
    state = eng.init_states(key)
    state, metrics = eng.run_round(state, ragged_data, 0, key)
    assert np.isfinite(_flat(eng, state, "proxy")).all()
    assert np.isfinite(_flat(eng, state, "private")).all()
    for v in metrics.values():
        assert np.isfinite(v).all()
    eng._data_cache.clear()  # drop the NaN-padded stack: engine is LRU-shared


# ---------------------------------------------------------------------------
# padded-state checkpointing


@pytest.mark.fast
def test_ragged_checkpoint_resume_bit_identity(tmp_path, ragged_data,
                                               mlp_spec):
    """Save after round 0 of a ragged vmap run, restore, replay round 1:
    bit-identical to the uninterrupted trajectory."""
    cfg = ProxyFLConfig(n_clients=K, rounds=2, batch_size=50, local_steps=0,
                        dp=DPConfig(enabled=True))
    key = jax.random.PRNGKey(0)
    eng = dml_engine((mlp_spec,) * K, mlp_spec, cfg, backend="vmap")
    state = eng.init_states(key)
    state, _ = eng.run_round(state, ragged_data, 0,
                             jax.random.fold_in(key, 10_000))
    path = os.path.join(str(tmp_path), "ragged_snap")
    eng.save_state(path, state, 0, base_key=key)
    cont, _ = eng.run_round(state, ragged_data, 1,
                            jax.random.fold_in(key, 10_001))
    restored, done = eng.restore_state(path, like=eng.init_states(key),
                                       base_key=key)
    assert done == 1
    resumed, _ = eng.run_round(restored, ragged_data, 1,
                               jax.random.fold_in(key, 10_001))
    for a, b in zip(jax.tree_util.tree_leaves(cont),
                    jax.tree_util.tree_leaves(resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# stacked-data LRU


@pytest.mark.fast
def test_stack_cache_keyed_lru_no_thrash(ragged_data, mlp_spec):
    """Two datasets alternating across rounds (train/finetune interleave)
    must each be padded+stacked exactly ONCE."""
    cfg = ProxyFLConfig(n_clients=K, rounds=4, batch_size=50, local_steps=1,
                        lr=2e-3, dp=DPConfig(enabled=False))
    eng = dml_engine((mlp_spec,) * K, mlp_spec, cfg, backend="vmap")
    eng._data_cache.clear()
    eng._stack_misses = 0
    other = [(x[: max(1, x.shape[0] // 2)], y[: max(1, y.shape[0] // 2)])
             for x, y in ragged_data]
    key = jax.random.PRNGKey(0)
    state = eng.init_states(key)
    for t, data in enumerate([ragged_data, other, ragged_data, other]):
        state, _ = eng.run_round(state, data, t, jax.random.fold_in(key, t))
    assert eng._stack_misses == 2, \
        f"alternating datasets re-stacked: {eng._stack_misses} misses"


# ---------------------------------------------------------------------------
# honest auto selector


@pytest.mark.fast
def test_auto_keeps_ragged_on_stacked_path(ragged_data):
    cfg = ProxyFLConfig(n_clients=K)
    assert _resolve_backend(None, cfg, ragged_data) == "auto"
    # genuinely incompatible trees (trailing dims differ) still fall back
    bad = list(ragged_data)
    x, y = bad[0]
    bad[0] = (x[:, :7], y)
    assert _resolve_backend(None, cfg, bad) == "loop"
    assert _resolve_backend("vmap", cfg, bad) == "vmap"  # explicit wins


@pytest.mark.fast
def test_async_backend_rejects_incompatible_trees(ragged_data):
    """backend='async' has no loop fallback — a silent switch to the
    synchronous loop would change the protocol's delivery semantics."""
    cfg = ProxyFLConfig(n_clients=K, staleness=2)
    assert _resolve_backend("async", cfg, ragged_data) == "async"
    bad = list(ragged_data)
    x, y = bad[0]
    bad[0] = (x[:, :7], y)
    with pytest.raises(ValueError, match="async"):
        _resolve_backend("async", cfg, bad)


def test_stacked_backend_rejects_unmasked_sampler_on_ragged(ragged_data,
                                                            mlp_spec):
    """A 2-arg sampler cannot bound its draw on padded data — the engine
    must refuse loudly instead of silently training on padding."""
    from repro.core.engine import FederationEngine
    cfg = ProxyFLConfig(n_clients=K, rounds=1, batch_size=50, local_steps=1,
                        dp=DPConfig(enabled=False))
    base = dml_engine((mlp_spec,) * K, mlp_spec, cfg, backend="vmap")

    def legacy_sample(data_k, kb):  # no n_valid parameter
        x, y = data_k
        idx = jax.random.randint(kb, (cfg.batch_size,), 0, x.shape[0])
        return (x[idx], y[idx])

    eng = FederationEngine(cfg, n_clients=K, step_fns=base.step_fns[0],
                           init_fns=base.init_fns[0],
                           sample_fn=legacy_sample, backend="vmap")
    state = eng.init_states(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="masked sampler"):
        eng.run_round(state, ragged_data, 0, jax.random.PRNGKey(1))


@pytest.mark.fast
def test_legacy_three_arg_sampler_not_treated_as_masked(ragged_data,
                                                        mlp_spec):
    """A pre-existing sampler whose third parameter is NOT named n_valid
    (e.g. a temperature knob) must never receive the dataset length."""
    from repro.core.engine import FederationEngine, _sampler_accepts_n_valid
    seen = []

    def legacy(data_k, kb, temperature=0.5):
        seen.append(temperature)
        x, y = data_k
        idx = jax.random.randint(kb, (50,), 0, x.shape[0])
        return (x[idx], y[idx])

    assert not _sampler_accepts_n_valid(legacy)
    assert _sampler_accepts_n_valid(lambda d, k, n_valid=None: d)
    assert _sampler_accepts_n_valid(lambda d, k, *, n_valid: d)
    cfg = ProxyFLConfig(n_clients=K, rounds=1, batch_size=50, local_steps=1,
                        dp=DPConfig(enabled=False))
    base = dml_engine((mlp_spec,) * K, mlp_spec, cfg, backend="vmap")
    eng = FederationEngine(cfg, n_clients=K, step_fns=base.step_fns[0],
                           init_fns=base.init_fns[0], sample_fn=legacy,
                           backend="loop")
    state = eng.init_states(jax.random.PRNGKey(0))
    eng.run_round(state, ragged_data, 0, jax.random.PRNGKey(1))
    assert seen and all(t == 0.5 for t in seen)  # default untouched


@pytest.mark.fast
def test_rectangular_tree_with_aux_leaves_still_stacks(mlp_spec):
    """Identical per-client trees whose leaves have DIFFERENT leading dims
    (e.g. an auxiliary prior alongside the examples) predate raggedness and
    must keep working on the stacked path — and because no single "example
    axis" exists, the engine must NOT guess an n_valid from the first leaf
    (dict order puts the 10-element prior first): the sampler keeps its
    own shape-derived bound over all 32 examples."""
    from repro.core.engine import FederationEngine
    cfg = ProxyFLConfig(n_clients=2, rounds=1, batch_size=8, local_steps=2,
                        dp=DPConfig(enabled=False))
    base = dml_engine((mlp_spec,) * 2, mlp_spec, cfg, backend="vmap")
    data = [{"xy": (jnp.ones((32,) + SHAPE), jnp.zeros((32,), jnp.int32)),
             "prior": jnp.full((10,), 0.1)} for _ in range(2)]
    seen_n_valid = []

    def sample(data_k, kb, n_valid=None):
        seen_n_valid.append(n_valid)
        x, y = data_k["xy"]
        hi = x.shape[0] if n_valid is None else n_valid
        idx = jax.random.randint(kb, (8,), 0, hi)
        return (x[idx], y[idx])

    eng = FederationEngine(cfg, n_clients=2, step_fns=base.step_fns[0],
                           init_fns=base.init_fns[0], sample_fn=sample,
                           backend="vmap")
    state = eng.init_states(jax.random.PRNGKey(0))
    state, metrics = eng.run_round(state, data, 0, jax.random.PRNGKey(1))
    for v in metrics.values():
        assert np.isfinite(v).all()
    assert seen_n_valid and all(nv is None for nv in seen_n_valid), \
        f"engine guessed n_valid from a non-example leaf: {seen_n_valid}"


@pytest.mark.fast
def test_required_n_valid_sampler_works_on_loop_backend(ragged_data,
                                                        mlp_spec):
    """A sampler whose ``n_valid`` parameter has NO default must run on
    the loop backend too (auto can silently fall back to it)."""
    from repro.core.engine import FederationEngine
    cfg = ProxyFLConfig(n_clients=K, rounds=1, batch_size=50, local_steps=1,
                        dp=DPConfig(enabled=False))
    base = dml_engine((mlp_spec,) * K, mlp_spec, cfg, backend="vmap")

    def strict_sample(data_k, kb, n_valid):  # required third argument
        x, y = data_k
        idx = jax.random.randint(kb, (cfg.batch_size,), 0, n_valid)
        return (x[idx], y[idx])

    eng = FederationEngine(cfg, n_clients=K, step_fns=base.step_fns[0],
                           init_fns=base.init_fns[0],
                           sample_fn=strict_sample, backend="loop")
    state = eng.init_states(jax.random.PRNGKey(0))
    state, metrics = eng.run_round(state, ragged_data, 0,
                                   jax.random.PRNGKey(1))
    assert np.isfinite(_flat(eng, state, "proxy")).all()
    for v in metrics.values():
        assert np.isfinite(v).all()


# ---------------------------------------------------------------------------
# partition property tests (hypothesis; skip cleanly when absent)


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 8),
       st.floats(0.1, 10.0), st.integers(40, 300))
def test_partition_dirichlet_disjoint_in_bounds(seed, n_clients, alpha, n):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 5, size=n)
    idxs = partition_dirichlet(np.random.default_rng(seed + 1), y,
                               n_clients, alpha)
    allv = np.concatenate(idxs) if idxs else np.array([], np.int64)
    assert len(allv) == len(set(allv.tolist())), "client index sets overlap"
    assert len(allv) == n, "every sample assigned exactly once"
    if len(allv):
        assert allv.min() >= 0 and allv.max() < n


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 6),
       st.floats(0.2, 0.9), st.integers(10, 60))
def test_partition_major_disjoint_in_bounds(seed, n_clients, p_major,
                                            per_client):
    n_classes = 5
    rng = np.random.default_rng(seed)
    n = per_client * n_clients * 2
    y = rng.integers(0, n_classes, size=n)
    idxs = partition_major(np.random.default_rng(seed + 1), y, n_clients,
                           per_client, p_major, n_classes)
    allv = np.concatenate(idxs)
    assert len(allv) == len(set(allv.tolist())), "client index sets overlap"
    assert allv.min() >= 0 and allv.max() < n
    for i in idxs:
        assert len(i) <= per_client
