"""Optional-`hypothesis` shim.

The container image does not guarantee `hypothesis` is installed. Test
modules import ``given``/``st`` from here instead of from `hypothesis`
directly: when the real library is present this is a pure re-export; when
it is absent, ``@given`` turns the property-based test into a cleanly
skipped test while the rest of the module keeps collecting and running.
"""
import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    HealthCheck = None
    settings = None

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped():
                pass

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return deco

    class _Strategy:
        """Placeholder: any `st.xyz(...)` call returns an inert object."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategy()
