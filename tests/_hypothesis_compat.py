"""Optional-`hypothesis` shim.

The container image does not guarantee `hypothesis` is installed. Test
modules import ``given``/``st`` from here instead of from `hypothesis`
directly: when the real library is present this is a pure re-export; when
it is absent, ``@given`` turns the property-based test into a cleanly
skipped test while the rest of the module keeps collecting and running.

Skipped property tests are NOT silent: the skip reason carries the
``PROPERTY_SKIP_REASON`` prefix, and ``scripts/ci.sh`` runs pytest with
``-rs`` plus an availability banner, so CI logs show exactly how many
property tests did not run (each one is expected to have a pinned
deterministic twin that still does)."""
import pytest

# one shared, greppable reason: `pytest -rs` aggregates identical reasons
# into a single counted summary line, so CI logs surface "N property tests
# skipped" instead of burying them in an anonymous skip count
PROPERTY_SKIP_REASON = ("property test skipped: hypothesis not installed "
                        "(deterministic twins still run)")

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    HealthCheck = None
    settings = None

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason=PROPERTY_SKIP_REASON)
            def skipped():
                pass

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return deco

    class _Strategy:
        """Placeholder: any `st.xyz(...)` call returns an inert object."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategy()
