"""Pallas kernel sweeps (interpret mode) against the pure-jnp oracles in
``repro.kernels.ref``: shapes, dtypes, masking variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.dp_clip import clip_accumulate, scale_accumulate, sumsq
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _qkv(key, B, H, S, D, dtype):
    ks = jax.random.split(key, 3)
    return [jax.random.normal(k, (B, H, S, D), dtype) for k in ks]


@pytest.mark.parametrize("S", [64, 128, 256, 384])
@pytest.mark.parametrize("D", [32, 64, 128])
def test_flash_attention_shapes(S, D):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 2, S, D, jnp.float32)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL[jnp.float32])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_dtypes_masks(dtype, causal):
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 4, 128, 64, dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_attention_sliding_window(window):
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 2, 256, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL[jnp.float32])


@pytest.mark.parametrize("blocks", [(64, 64), (128, 128), (64, 128)])
def test_flash_attention_block_shapes(blocks):
    bq, bk = blocks
    q, k, v = _qkv(jax.random.PRNGKey(3), 1, 2, 256, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL[jnp.float32])


@pytest.mark.parametrize("G", [1, 2, 4])
def test_gqa_wrapper(G):
    B, S, Hkv, D = 2, 128, 2, 64
    key = jax.random.PRNGKey(4)
    q = jax.random.normal(key, (B, S, Hkv * G, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D))
    out = ops.gqa_flash_attention(q, k, v, causal=True, interpret=True)
    kr = jnp.repeat(k, G, axis=2).transpose(0, 2, 1, 3)
    vr = jnp.repeat(v, G, axis=2).transpose(0, 2, 1, 3)
    want = ref.flash_attention_ref(q.transpose(0, 2, 1, 3), kr, vr,
                                   causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL[jnp.float32])


# ---------------------------------------------------------------------------
# mamba selective-scan kernel


@pytest.mark.parametrize("S,chunk", [(64, 16), (128, 32), (96, 32), (256, 128)])
def test_mamba_scan_chunks(S, chunk):
    B, di, ds = 2, 16, 8
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.fold_in(k, 0), (B, S, di))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (B, S, di)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (di, ds)))
    Bm = jax.random.normal(jax.random.fold_in(k, 3), (B, S, ds))
    C = jax.random.normal(jax.random.fold_in(k, 4), (B, S, ds))
    out = mamba_scan(dt, x, Bm, C, A, chunk=chunk, interpret=True)
    want = ref.mamba_scan_ref(dt, x, Bm, C, A)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("di,ds", [(8, 4), (32, 16), (64, 8)])
def test_mamba_scan_dims(di, ds):
    B, S = 1, 64
    k = jax.random.PRNGKey(5)
    x = jax.random.normal(jax.random.fold_in(k, 0), (B, S, di))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (B, S, di)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (di, ds)))
    Bm = jax.random.normal(jax.random.fold_in(k, 3), (B, S, ds))
    C = jax.random.normal(jax.random.fold_in(k, 4), (B, S, ds))
    out = mamba_scan(dt, x, Bm, C, A, chunk=16, interpret=True)
    want = ref.mamba_scan_ref(dt, x, Bm, C, A)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# fused DP clip-and-accumulate kernels


@pytest.mark.parametrize("n", [1024, 4096, 65536])
def test_sumsq(n):
    g = jax.random.normal(jax.random.PRNGKey(0), (n,))
    np.testing.assert_allclose(float(sumsq(g, interpret=True)),
                               float(ref.sumsq_ref(g)), rtol=1e-5)


@pytest.mark.parametrize("n,clip", [(1024, 0.5), (4096, 1.0), (65536, 3.0)])
def test_clip_accumulate(n, clip):
    k = jax.random.PRNGKey(1)
    g = jax.random.normal(k, (n,))
    acc = jax.random.normal(jax.random.fold_in(k, 1), (n,))
    out = clip_accumulate(acc, g, clip, interpret=True)
    want = ref.clip_accumulate_ref(acc, g, clip)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_scale_accumulate():
    k = jax.random.PRNGKey(2)
    g = jax.random.normal(k, (4096,))
    acc = jnp.zeros((4096,))
    out = scale_accumulate(acc, g, 0.37, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.scale_accumulate_ref(acc, g, 0.37)),
                               rtol=1e-6)


def test_tree_clip_accumulate_matches_global_norm():
    from repro.core.dp import clip_by_global_norm
    k = jax.random.PRNGKey(3)
    tree = {"a": jax.random.normal(k, (128, 8)),
            "b": {"c": jax.random.normal(jax.random.fold_in(k, 1), (64,))}}
    acc = jax.tree_util.tree_map(jnp.zeros_like, tree)
    got = ops.tree_clip_accumulate(acc, tree, 0.5, interpret=True)
    want, _ = clip_by_global_norm(tree, 0.5)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
