"""Pallas kernel sweeps (interpret mode) against the pure-jnp oracles in
``repro.kernels.ref``: shapes, dtypes, masking variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, st
from repro.kernels import ops, ref
from repro.kernels.dp_clip import clip_accumulate, scale_accumulate, sumsq
from repro.kernels.dp_step import noise_adam_step, noise_sgd_step
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.pushsum_mix import fused_pushsum_mix, fused_stale_mix

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _qkv(key, B, H, S, D, dtype):
    ks = jax.random.split(key, 3)
    return [jax.random.normal(k, (B, H, S, D), dtype) for k in ks]


@pytest.mark.parametrize("S", [64, 128, 256, 384])
@pytest.mark.parametrize("D", [32, 64, 128])
def test_flash_attention_shapes(S, D):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 2, S, D, jnp.float32)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL[jnp.float32])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_dtypes_masks(dtype, causal):
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 4, 128, 64, dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_attention_sliding_window(window):
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 2, 256, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL[jnp.float32])


@pytest.mark.parametrize("blocks", [(64, 64), (128, 128), (64, 128)])
def test_flash_attention_block_shapes(blocks):
    bq, bk = blocks
    q, k, v = _qkv(jax.random.PRNGKey(3), 1, 2, 256, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL[jnp.float32])


@pytest.mark.parametrize("G", [1, 2, 4])
def test_gqa_wrapper(G):
    B, S, Hkv, D = 2, 128, 2, 64
    key = jax.random.PRNGKey(4)
    q = jax.random.normal(key, (B, S, Hkv * G, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D))
    out = ops.gqa_flash_attention(q, k, v, causal=True, interpret=True)
    kr = jnp.repeat(k, G, axis=2).transpose(0, 2, 1, 3)
    vr = jnp.repeat(v, G, axis=2).transpose(0, 2, 1, 3)
    want = ref.flash_attention_ref(q.transpose(0, 2, 1, 3), kr, vr,
                                   causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL[jnp.float32])


# ---------------------------------------------------------------------------
# mamba selective-scan kernel


@pytest.mark.parametrize("S,chunk", [(64, 16), (128, 32), (96, 32), (256, 128)])
def test_mamba_scan_chunks(S, chunk):
    B, di, ds = 2, 16, 8
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.fold_in(k, 0), (B, S, di))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (B, S, di)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (di, ds)))
    Bm = jax.random.normal(jax.random.fold_in(k, 3), (B, S, ds))
    C = jax.random.normal(jax.random.fold_in(k, 4), (B, S, ds))
    out = mamba_scan(dt, x, Bm, C, A, chunk=chunk, interpret=True)
    want = ref.mamba_scan_ref(dt, x, Bm, C, A)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("di,ds", [(8, 4), (32, 16), (64, 8)])
def test_mamba_scan_dims(di, ds):
    B, S = 1, 64
    k = jax.random.PRNGKey(5)
    x = jax.random.normal(jax.random.fold_in(k, 0), (B, S, di))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (B, S, di)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (di, ds)))
    Bm = jax.random.normal(jax.random.fold_in(k, 3), (B, S, ds))
    C = jax.random.normal(jax.random.fold_in(k, 4), (B, S, ds))
    out = mamba_scan(dt, x, Bm, C, A, chunk=16, interpret=True)
    want = ref.mamba_scan_ref(dt, x, Bm, C, A)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# fused DP clip-and-accumulate kernels


@pytest.mark.parametrize("n", [1024, 4096, 65536])
def test_sumsq(n):
    g = jax.random.normal(jax.random.PRNGKey(0), (n,))
    np.testing.assert_allclose(float(sumsq(g, interpret=True)),
                               float(ref.sumsq_ref(g)), rtol=1e-5)


@pytest.mark.parametrize("n,clip", [(1024, 0.5), (4096, 1.0), (65536, 3.0)])
def test_clip_accumulate(n, clip):
    k = jax.random.PRNGKey(1)
    g = jax.random.normal(k, (n,))
    acc = jax.random.normal(jax.random.fold_in(k, 1), (n,))
    out = clip_accumulate(acc, g, clip, interpret=True)
    want = ref.clip_accumulate_ref(acc, g, clip)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_scale_accumulate():
    k = jax.random.PRNGKey(2)
    g = jax.random.normal(k, (4096,))
    acc = jnp.zeros((4096,))
    out = scale_accumulate(acc, g, 0.37, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.scale_accumulate_ref(acc, g, 0.37)),
                               rtol=1e-6)


def test_tree_clip_accumulate_matches_global_norm():
    from repro.core.dp import clip_by_global_norm
    k = jax.random.PRNGKey(3)
    tree = {"a": jax.random.normal(k, (128, 8)),
            "b": {"c": jax.random.normal(jax.random.fold_in(k, 1), (64,))}}
    acc = jax.tree_util.tree_map(jnp.zeros_like, tree)
    got = ops.tree_clip_accumulate(acc, tree, 0.5, interpret=True)
    want, _ = clip_by_global_norm(tree, 0.5)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# fused PushSum mix kernels (the round hot path's exchange)


def _mix_inputs(key, K, D, dtype):
    kf, kw, kp = jax.random.split(key, 3)
    flat = jax.random.normal(kf, (K, D), dtype)
    w = jax.random.uniform(kw, (K,), dtype, 0.3, 2.0)
    P = jax.random.uniform(kp, (K, K), jnp.float32, 0.1, 1.0)
    P = P / P.sum(axis=0, keepdims=True)  # column-stochastic
    return flat, w, P


@pytest.mark.fast
@pytest.mark.parametrize("K,D,block", [
    (1, 300, 8192),    # K=1 degenerate cohort
    (4, 300, 128),     # D not block-divisible, several blocks
    (4, 100, 8192),    # D smaller than one block
    (8, 1000, 256),
])
@pytest.mark.parametrize("debias", [True, False])
def test_fused_pushsum_mix_shapes(K, D, block, debias):
    flat, w, P = _mix_inputs(jax.random.PRNGKey(0), K, D, jnp.float32)
    got_z, got_w = fused_pushsum_mix(flat, w, P, debias=debias, block=block,
                                     interpret=True)
    want_z, want_w = ref.fused_pushsum_mix_ref(flat, w, P, debias=debias)
    np.testing.assert_allclose(np.asarray(got_z), np.asarray(want_z),
                               **TOL[jnp.float32])
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w),
                               **TOL[jnp.float32])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_pushsum_mix_dtypes(dtype):
    flat, w, P = _mix_inputs(jax.random.PRNGKey(1), 4, 777, dtype)
    got_z, got_w = fused_pushsum_mix(flat, w, P, block=256, interpret=True)
    want_z, want_w = ref.fused_pushsum_mix_ref(flat, w, P)
    assert got_z.dtype == dtype and got_w.dtype == dtype
    np.testing.assert_allclose(np.asarray(got_z, np.float32),
                               np.asarray(want_z, np.float32), **TOL[dtype])
    np.testing.assert_allclose(np.asarray(got_w, np.float32),
                               np.asarray(want_w, np.float32), **TOL[dtype])


def _stale_inputs(key, K, D, dtype):
    flat, w, P = _mix_inputs(key, K, D, dtype)
    kept = jnp.diag(P)
    sent = P - jnp.diag(kept)
    kb = jax.random.fold_in(key, 9)
    buf_t0 = jax.random.normal(kb, (K, D), dtype) * 0.1
    buf_w0 = jax.random.uniform(jax.random.fold_in(kb, 1), (K,), dtype,
                                0.0, 0.5)
    return flat, w, kept, sent, buf_t0, buf_w0


@pytest.mark.fast
@pytest.mark.parametrize("K,D,block,dtype", [
    (1, 300, 8192, jnp.float32),
    (4, 300, 128, jnp.float32),   # ragged: D % block != 0
    (4, 100, 8192, jnp.float32),  # D < one block
    (8, 777, 256, jnp.bfloat16),
])
def test_fused_stale_mix(K, D, block, dtype):
    args = _stale_inputs(jax.random.PRNGKey(2), K, D, dtype)
    got = fused_stale_mix(*args, block=block, interpret=True)
    want = ref.fused_stale_mix_ref(*args)
    for g, wnt in zip(got, want):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(wnt, np.float32), **TOL[dtype])


@given(st.integers(0, 40), st.integers(2, 9), st.integers(1, 40))
def test_fused_mix_conserves_mass_property(t, K, D):
    """Column-stochastic P conserves PushSum mass through the FUSED
    exchange: per coordinate Σ_k z'_k·w'_k == Σ_k z_k (the kernel mixes
    the stacked vectors directly), and Σ w' == Σ w — the fused-path twin
    of test_gossip's mass-conservation properties."""
    from repro.core.gossip import mix_matrix
    P = jnp.asarray(mix_matrix("pushsum", t, K, "exponential"), jnp.float32)
    flat, w, _ = _mix_inputs(jax.random.PRNGKey(t * 31 + K), K, D,
                             jnp.float32)
    z2, w2 = fused_pushsum_mix(flat, w, P, debias=True, block=16,
                               interpret=True)
    np.testing.assert_allclose(
        np.asarray(z2) * np.asarray(w2)[:, None], np.asarray(P @ flat),
        rtol=1e-5, atol=1e-6)  # de-bias is exactly the mixed mass / w'
    np.testing.assert_allclose(np.asarray(z2 * w2[:, None]).sum(0),
                               np.asarray(flat).sum(0), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(float(w2.sum()), float(w.sum()), rtol=1e-5)


@pytest.mark.fast
def test_fused_mix_conserves_mass_deterministic():
    """Pinned twin of the property above (runs even without hypothesis)."""
    from repro.core.gossip import mix_matrix
    K, D = 4, 33
    P = jnp.asarray(mix_matrix("pushsum", 3, K, "exponential"), jnp.float32)
    flat, w, _ = _mix_inputs(jax.random.PRNGKey(5), K, D, jnp.float32)
    z2, w2 = fused_pushsum_mix(flat, w, P, debias=True, block=16,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(z2 * w2[:, None]).sum(0),
                               np.asarray(flat).sum(0), rtol=1e-5)
    np.testing.assert_allclose(float(w2.sum()), float(w.sum()), rtol=1e-6)


# ---------------------------------------------------------------------------
# fused noise + optimizer step kernels (the DP hot path's tail)


@pytest.mark.fast
@pytest.mark.parametrize("n,block", [(100, 65536),   # n < one block
                                     (1000, 256),    # n % block != 0
                                     (4096, 1024)])
def test_noise_sgd_step(n, block):
    k = jax.random.PRNGKey(0)
    acc = jax.random.normal(k, (n,))
    noise = jax.random.normal(jax.random.fold_in(k, 1), (n,))
    p = jax.random.normal(jax.random.fold_in(k, 2), (n,))
    kw = dict(stddev=1.7, n_units=8, lr=1e-2, weight_decay=1e-4)
    got = noise_sgd_step(acc, noise, p, block=block, interpret=True, **kw)
    want = ref.noise_sgd_step_ref(acc, noise, p, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **TOL[jnp.float32])


@pytest.mark.fast
@pytest.mark.parametrize("n,block", [(100, 65536), (1000, 256)])
def test_noise_adam_step(n, block):
    k = jax.random.PRNGKey(1)
    acc = jax.random.normal(k, (n,))
    noise = jax.random.normal(jax.random.fold_in(k, 1), (n,))
    p = jax.random.normal(jax.random.fold_in(k, 2), (n,))
    m = jax.random.normal(jax.random.fold_in(k, 3), (n,)) * 0.1
    v = jax.random.uniform(jax.random.fold_in(k, 4), (n,), maxval=0.01)
    kw = dict(stddev=1.0, n_units=16, lr=1e-3, weight_decay=1e-4,
              b1=0.9, b2=0.999, eps=1e-8, c1=1.0 - 0.9 ** 3,
              c2=1.0 - 0.999 ** 3)
    got = noise_adam_step(acc, noise, p, m, v, block=block, interpret=True,
                          **kw)
    want = ref.noise_adam_step_ref(acc, noise, p, m, v, **kw)
    for g, wnt in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(wnt),
                                   **TOL[jnp.float32])


def test_dp_adam_update_matches_plain_chain():
    """End-to-end fused DP update (clip scan → _flat_gaussian_like noise →
    noise_adam_step) vs the reference dp_gradient + Adam.update chain on a
    real parameter tree: same key, same batch — the Gaussian draws are
    IDENTICAL by construction (same per-leaf split schedule), so the only
    difference is kernel arithmetic order. This is the kernel-level twin
    of the pallas-* conformance cases."""
    from repro.core.dp import dp_adam_update, dp_gradient
    from repro.optim import Adam

    k = jax.random.PRNGKey(7)
    params = {"w": jax.random.normal(k, (49, 10)) * 0.1,
              "b": jnp.zeros((10,))}
    opt = Adam(lr=1e-3, weight_decay=1e-4)
    opt_state = opt.init(params)
    x = jax.random.normal(jax.random.fold_in(k, 1), (8, 49))
    y = jax.random.randint(jax.random.fold_in(k, 2), (8,), 0, 10)

    def loss(p, batch):
        xb, yb = batch
        logits = xb @ p["w"] + p["b"]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        return jnp.mean(lse - logits[jnp.arange(xb.shape[0]), yb])

    key = jax.random.PRNGKey(42)
    p2, o2, m2 = dp_adam_update(loss, params, opt_state, (x, y), key,
                                opt=opt, clip_norm=1.0,
                                noise_multiplier=1.0, interpret=True)
    g, m_ref_ = dp_gradient(loss, params, (x, y), key, clip_norm=1.0,
                            noise_multiplier=1.0)
    p2_ref, o2_ref = opt.update(g, opt_state, params)
    for a, b in zip(jax.tree_util.tree_leaves(p2),
                    jax.tree_util.tree_leaves(p2_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(m2["loss"]), float(m_ref_["loss"]),
                               rtol=1e-5)
    assert int(o2.t) == int(o2_ref.t) == 1
