"""Poisson-subsampling DP path + the fused RMSNorm kernel sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dp import dp_gradient, dp_gradient_poisson
from repro.data.loader import poisson_batch
from repro.kernels import ref
from repro.kernels.rmsnorm import rmsnorm


def test_poisson_batch_shapes_and_mask():
    k = jax.random.PRNGKey(0)
    x = jnp.arange(100, dtype=jnp.float32)[:, None]
    y = jnp.arange(100, dtype=jnp.int32)
    xb, yb, mask = poisson_batch(k, x, y, q=0.2, max_batch=50)
    assert xb.shape == (50, 1) and mask.shape == (50,)
    n_sel = int(mask.sum())
    assert 5 <= n_sel <= 40  # ~Binomial(100, 0.2)
    # real slots come first and carry selected examples
    assert bool(jnp.all(mask[:n_sel] == 1.0))
    assert bool(jnp.all(mask[n_sel:] == 0.0))


def test_poisson_batch_selection_rate():
    k = jax.random.PRNGKey(1)
    x = jnp.zeros((1000, 1))
    y = jnp.zeros((1000,), jnp.int32)
    counts = []
    for i in range(20):
        _, _, mask = poisson_batch(jax.random.fold_in(k, i), x, y, q=0.1,
                                   max_batch=200)
        counts.append(float(mask.sum()))
    assert abs(np.mean(counts) - 100) < 15


def test_poisson_dp_gradient_masks_padding():
    """Padding slots must contribute exactly zero to the DP sum."""
    k = jax.random.PRNGKey(0)
    X = jax.random.normal(k, (8, 5))
    y = jax.random.normal(jax.random.fold_in(k, 1), (8,))
    params = {"w": jnp.zeros((5,))}

    def loss(p, batch):
        xb, yb = batch
        return jnp.mean((xb @ p["w"] - yb) ** 2)

    mask = jnp.asarray([1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)
    g_pad, _ = dp_gradient_poisson(loss, params, (X, y), mask,
                                   jax.random.PRNGKey(2), clip_norm=1.0,
                                   noise_multiplier=0.0, expected_batch=4.0)
    g_ref, _ = dp_gradient(loss, params, (X[:4], y[:4]),
                           jax.random.PRNGKey(2), clip_norm=1.0,
                           noise_multiplier=0.0)
    np.testing.assert_allclose(np.asarray(g_pad["w"]), np.asarray(g_ref["w"]),
                               rtol=1e-5, atol=1e-6)


def test_poisson_dp_sensitivity():
    """One extra selected example changes the (noise-free) output by at most
    C / E[B] — the sampled-Gaussian sensitivity."""
    k = jax.random.PRNGKey(0)
    X = jax.random.normal(k, (8, 5)) * 100  # big → everything clips to C
    y = jax.random.normal(jax.random.fold_in(k, 1), (8,))
    params = {"w": jnp.ones((5,))}

    def loss(p, batch):
        xb, yb = batch
        return jnp.mean((xb @ p["w"] - yb) ** 2)

    C, EB = 0.5, 4.0
    m1 = jnp.asarray([1, 1, 1, 0, 0, 0, 0, 0], jnp.float32)
    m2 = m1.at[3].set(1.0)  # one more member
    g1, _ = dp_gradient_poisson(loss, params, (X, y), m1, k, clip_norm=C,
                                noise_multiplier=0.0, expected_batch=EB)
    g2, _ = dp_gradient_poisson(loss, params, (X, y), m2, k, clip_norm=C,
                                noise_multiplier=0.0, expected_batch=EB)
    diff = jnp.sqrt(sum(jnp.sum((a - b) ** 2) for a, b in zip(
        jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2))))
    assert float(diff) <= C / EB + 1e-6


# ---------------------------------------------------------------------------
# fused RMSNorm kernel


@pytest.mark.parametrize("shape", [(4, 128), (2, 16, 256), (1, 512), (3, 1024)])
def test_rmsnorm_kernel_shapes(shape):
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, shape)
    g = jax.random.normal(jax.random.fold_in(k, 1), (shape[-1],))
    out = rmsnorm(x, g, interpret=True)
    want = ref.rmsnorm_ref(x, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel_dtypes(dtype):
    k = jax.random.PRNGKey(2)
    x = jax.random.normal(k, (64, 256), dtype)
    g = jnp.ones((256,), dtype)
    out = rmsnorm(x, g, interpret=True)
    want = ref.rmsnorm_ref(x, g)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 2e-5,
                               atol=2e-2 if dtype == jnp.bfloat16 else 2e-5)


def test_rmsnorm_kernel_block_boundaries():
    # rows not a multiple of block_rows exercises the pad/slice path
    k = jax.random.PRNGKey(3)
    x = jax.random.normal(k, (77, 64))
    g = jax.random.normal(jax.random.fold_in(k, 1), (64,))
    out = rmsnorm(x, g, block_rows=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.rmsnorm_ref(x, g)),
                               rtol=2e-5, atol=2e-5)


def test_rmsnorm_matches_module():
    from repro.nn.modules import rmsnorm as rmsnorm_mod
    k = jax.random.PRNGKey(4)
    x = jax.random.normal(k, (8, 128))
    g = jax.random.normal(jax.random.fold_in(k, 1), (128,))
    out = rmsnorm(x, g, interpret=True)
    want = rmsnorm_mod({"g": g}, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
