"""RDP accountant: known reference values, monotonicity properties, and the
paper's own privacy settings."""
import math

import pytest
from _hypothesis_compat import given, st

from repro.core.accountant import (PrivacyAccountant, epsilon_for,
                                   rdp_sampled_gaussian, rdp_to_eps)


def test_plain_gaussian_rdp():
    # q=1 reduces to the plain Gaussian mechanism: eps_RDP(alpha) = alpha/2sigma^2
    assert rdp_sampled_gaussian(1.0, 2.0, 8) == pytest.approx(8 / (2 * 4))


def test_zero_sampling_is_free():
    assert rdp_sampled_gaussian(0.0, 1.0, 4) == 0.0


def test_zero_noise_is_infinite():
    assert math.isinf(rdp_sampled_gaussian(0.5, 0.0, 4))


def test_reference_value_tf_privacy():
    """The canonical TF-Privacy MNIST example: n=60000, batch=256,
    sigma=1.1, 60 epochs, delta=1e-5 → eps ≈ 3.0 (RDP accountant).
    Integer-order restriction makes ours slightly looser, never tighter."""
    q = 256 / 60000
    steps = 60 * (60000 // 256)
    eps = epsilon_for(noise_multiplier=1.1, sample_rate=q, steps=steps,
                      delta=1e-5)
    assert 2.5 < eps < 3.6, eps


@given(st.floats(0.5, 3.0), st.floats(0.001, 0.5), st.integers(1, 500))
def test_eps_monotone_in_steps(sigma, q, steps):
    e1 = epsilon_for(noise_multiplier=sigma, sample_rate=q, steps=steps,
                     delta=1e-5)
    e2 = epsilon_for(noise_multiplier=sigma, sample_rate=q, steps=steps + 50,
                     delta=1e-5)
    assert e2 >= e1 - 1e-9


@given(st.floats(0.001, 0.5), st.integers(1, 200))
def test_eps_decreases_with_noise(q, steps):
    e_lo = epsilon_for(noise_multiplier=0.8, sample_rate=q, steps=steps,
                       delta=1e-5)
    e_hi = epsilon_for(noise_multiplier=2.0, sample_rate=q, steps=steps,
                       delta=1e-5)
    assert e_hi <= e_lo + 1e-9


@given(st.floats(0.5, 3.0), st.integers(1, 200))
def test_eps_increases_with_sampling(sigma, steps):
    e_lo = epsilon_for(noise_multiplier=sigma, sample_rate=0.01, steps=steps,
                       delta=1e-5)
    e_hi = epsilon_for(noise_multiplier=sigma, sample_rate=0.3, steps=steps,
                       delta=1e-5)
    assert e_hi >= e_lo - 1e-9


def test_smaller_batch_stronger_guarantee():
    """Paper Fig. 11: smaller batch sizes (lower q) dramatically improve the
    privacy guarantee at fixed epochs-equivalent steps."""
    n, epochs = 1000, 30
    eps = {}
    for b in (25, 50, 125, 250):
        steps = epochs * (n // b)
        eps[b] = epsilon_for(noise_multiplier=1.0, sample_rate=b / n,
                             steps=steps, delta=1e-5)
    assert eps[25] < eps[50] < eps[125] < eps[250]


def test_paper_histopathology_epsilons():
    """Paper Table 2: sigma=1.4, C=0.7, delta=1e-5, batch 32, 30 epochs over
    the four clients' training-set sizes gives eps ≈ 2.1–2.4 per client and
    eps ≈ 1.0 for Joint training."""
    sizes = {"C1": 2338, "C2": 2726, "C3": 2937, "C4": 2841}
    paper = {"C1": 2.36, "C2": 2.17, "C3": 2.08, "C4": 2.12}
    for c, n in sizes.items():
        steps = 30 * (n // 32)
        eps = epsilon_for(noise_multiplier=1.4, sample_rate=32 / n,
                          steps=steps, delta=1e-5)
        assert abs(eps - paper[c]) / paper[c] < 0.12, (c, eps, paper[c])
    n_joint = sum(sizes.values())
    eps_joint = epsilon_for(noise_multiplier=1.4, sample_rate=32 / n_joint,
                            steps=30 * (n_joint // 32), delta=1e-5)
    assert abs(eps_joint - 1.00) < 0.15, eps_joint


def test_budget_exceeds():
    acc = PrivacyAccountant(1.0, 0.25, 1e-5)
    assert not acc.exceeds(1.0)
    acc.step(2000)
    assert acc.exceeds(1.0)


def test_rdp_to_eps_picks_best_order():
    alphas = [2, 4, 8]
    rdp = [10.0, 1.0, 5.0]
    eps_all = rdp_to_eps(rdp, alphas, 1e-5)
    eps_single = rdp_to_eps([1.0], [4], 1e-5)
    assert eps_all <= eps_single + 1e-12
