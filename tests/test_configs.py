"""Assigned-architecture configs match the task table exactly; smoke
variants respect the reduction bounds; layout machinery is consistent."""
import pytest

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.configs.registry import proxy_of, smoke_variant

pytestmark = pytest.mark.fast  # pure-config checks, no compilation

ASSIGNED = {
    # arch: (layers, d_model, heads, kv_heads, d_ff, vocab)
    "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
    "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
    "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
    "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
    "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
    "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
    "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
    "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
    "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
}


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_assigned_config_numbers(arch):
    L, d, H, KV, ff, V = ASSIGNED[arch]
    cfg = get_config(arch)
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == KV
    assert cfg.vocab_size == V
    if ff:
        # MoE archs quote the per-expert hidden width in the assignment table
        ok = {cfg.d_ff} | ({cfg.moe.d_ff_expert} if cfg.moe else set())
        assert ff in ok, (ff, ok)
    assert cfg.source, "every config must cite its source"


def test_all_ten_assigned_present():
    assert set(ASSIGNED) <= set(list_archs())


def test_moe_details():
    ds = get_config("deepseek-v2-236b")
    assert ds.moe.n_experts == 160 and ds.moe.top_k == 6
    assert ds.moe.n_shared_experts == 2
    assert ds.attn_impl == "mla" and ds.mla.kv_lora_rank == 512
    arc = get_config("arctic-480b")
    assert arc.moe.n_experts == 128 and arc.moe.top_k == 2
    assert arc.moe.dense_residual_d_ff > 0
    jam = get_config("jamba-1.5-large-398b")
    assert jam.moe.n_experts == 16 and jam.moe.top_k == 2


def test_jamba_interleave():
    jam = get_config("jamba-1.5-large-398b")
    layout = jam.layout()
    kinds = [s.kind for s in layout]
    # 1:7 attention:mamba ratio
    assert kinds.count("attn") == len(layout) // 8
    assert kinds.count("mamba") == len(layout) - len(layout) // 8
    # MoE every other layer
    ffns = [s.ffn for s in layout]
    assert ffns.count("moe") == len(layout) // 2


def test_gemma_window_pattern():
    g = get_config("gemma3-4b")
    layout = g.layout()
    local = [s for s in layout if s.window]
    glob = [s for s in layout if not s.window]
    assert len(local) > 0 and len(glob) > 0
    assert abs(len(local) / max(len(glob), 1) - 5.0) < 1.1  # ~5:1


def test_input_shapes_assigned():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["long_500k"].global_batch == 1


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_smoke_variant_bounds(arch):
    sm = smoke_variant(get_config(arch))
    assert sm.d_model <= 512
    assert sm.n_layers <= 10
    if sm.moe:
        assert sm.moe.n_experts <= 4


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_layout_length(arch):
    cfg = get_config(arch)
    assert len(cfg.layout()) == cfg.n_layers
    R, rem = cfg.pattern_plan()
    assert len(cfg.prefix) + R * len(cfg.pattern) + rem == cfg.n_layers


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_param_counts_positive(arch):
    c = get_config(arch).param_counts()
    assert c["total"] >= c["active"] > 0


def test_param_counts_match_scale():
    # analytic totals should land near the nameplate parameter counts
    approx = {
        "deepseek-v2-236b": 236e9, "arctic-480b": 480e9,
        "jamba-1.5-large-398b": 398e9, "qwen1.5-110b": 110e9,
        "qwen2-7b": 7e9, "falcon-mamba-7b": 7e9, "qwen1.5-4b": 4e9,
        "gemma3-4b": 4e9, "phi-3-vision-4.2b": 4.2e9,
    }
    for arch, target in approx.items():
        total = get_config(arch).param_counts()["total"]
        assert 0.5 * target < total < 1.7 * target, (arch, total, target)


def test_proxy_spec_compat():
    for arch in sorted(ASSIGNED):
        cfg = get_config(arch)
        px = proxy_of(cfg)
        assert px.vocab_size == cfg.vocab_size
        assert px.modality == cfg.modality
        assert px.n_codebooks == cfg.n_codebooks
        assert px.param_counts()["total"] < cfg.param_counts()["total"]
