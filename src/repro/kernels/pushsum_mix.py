"""Pallas TPU fused PushSum gossip kernels (Algorithm 1 lines 7-11).

The stacked backends hold the cohort's flattened proxies as one [K, D]
array. Plain XLA runs the exchange as separate matmuls plus a de-bias
divide — each walking the full K·D parameter set through HBM. These
kernels block over D and keep the tiny [K, K] exchange matrix and the [K]
weight vectors resident in VMEM, so every parameter chunk is streamed
HBM→VMEM exactly once per round:

* :func:`fused_pushsum_mix` — the SYNCHRONOUS exchange on de-biased
  values z (what ``FederationEngine._round_core`` mixes):
  out = P·z (optionally fused-de-biased by w' = P·w), w' = P·w.
* :func:`fused_stale_mix` — the async τ>0 exchange of
  ``repro.core.gossip.stale_gossip_reference``: re-bias θ = z·w, emit the
  off-diagonal send ``sent @ θ``, merge ``kept·θ`` with the delayed
  delivery, and de-bias by the identically-delayed weights — two outputs
  (z', send) per chunk, one pass.

Accumulation is f32 (``preferred_element_type``) regardless of the input
dtype; the [K]-sized weight reductions are computed outside the kernel
(they are O(K), not O(K·D)). Numeric contract: allclose to the plain-XLA
chain (same math, different reduction order) — pinned by the ``use_pallas``
columns of tests/test_conformance.py and the ``ref.py`` oracle sweeps.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import resolve_interpret


def _mix_kernel(debias: bool, P_ref, w2_ref, x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)                       # [K, b]
    mixed = jnp.dot(P_ref[...], x, preferred_element_type=jnp.float32)
    if debias:
        mixed = mixed / w2_ref[...][:, None]
    o_ref[...] = mixed.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("debias", "block", "interpret"))
def fused_pushsum_mix(flat: jnp.ndarray, w: jnp.ndarray, P: jnp.ndarray, *,
                      debias: bool = True, block: int = 8192,
                      interpret: Optional[bool] = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One synchronous PushSum exchange over stacked [K, D] client vectors.

    Returns ``(P·flat / (P·w)[:, None], P·w)`` with ``debias=True`` (the
    engine's stacked round) or ``(P·flat, P·w)`` with ``debias=False``
    (the raw :func:`repro.core.gossip.pushsum_mix` contract). ``P`` stays
    resident in VMEM across the D-grid; w' is O(K) and computed outside."""
    K, D = flat.shape
    Pf = jnp.asarray(P, jnp.float32)
    w2 = Pf @ w.astype(jnp.float32)
    b = min(block, max(D, 1))
    n_blocks = -(-D // b)
    pad = n_blocks * b - D
    x = jnp.pad(flat, ((0, 0), (0, pad))) if pad else flat
    out = pl.pallas_call(
        functools.partial(_mix_kernel, debias),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((K, K), lambda i: (0, 0)),  # P resident
            pl.BlockSpec((K,), lambda i: (0,)),      # w' resident
            pl.BlockSpec((K, b), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((K, b), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((K, n_blocks * b), flat.dtype),
        interpret=resolve_interpret(interpret),
    )(Pf, w2, x)
    return out[:, :D], w2.astype(w.dtype)


def _stale_kernel(w_ref, kept_ref, sent_ref, w2_ref, x_ref, buf_ref,
                  z_ref, send_ref):
    theta = x_ref[...].astype(jnp.float32) * w_ref[...][:, None]  # re-bias
    send = jnp.dot(sent_ref[...], theta,
                   preferred_element_type=jnp.float32)
    mixed = kept_ref[...][:, None] * theta + buf_ref[...].astype(jnp.float32)
    z_ref[...] = (mixed / w2_ref[...][:, None]).astype(z_ref.dtype)
    send_ref[...] = send.astype(send_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_stale_mix(flat: jnp.ndarray, w: jnp.ndarray, kept: jnp.ndarray,
                    sent: jnp.ndarray, buf_t0: jnp.ndarray,
                    buf_w0: jnp.ndarray, *, block: int = 8192,
                    interpret: Optional[bool] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                               jnp.ndarray]:
    """One stale (async τ>0) exchange: returns ``(z', send_t, w', send_w)``.

    ``flat``/``w`` are the [K, D] de-biased proxies and weights; ``kept``
    [K] / ``sent`` [K, K] the diag/off-diag split of P^(t)
    (:func:`repro.core.gossip.stale_mix_split`); ``buf_t0``/``buf_w0`` the
    delivery rotating out of the τ-deep in-flight buffer. The caller owns
    the buffer rotation (``send_t``/``send_w`` are pushed in). Per chunk
    the kernel re-biases θ = z·w, computes both the kept-merge and the
    send matmul, and de-biases — one HBM→VMEM pass for two outputs."""
    K, D = flat.shape
    wf = w.astype(jnp.float32)
    keptf = kept.astype(jnp.float32)
    sentf = sent.astype(jnp.float32)
    w2 = keptf * wf + buf_w0.astype(jnp.float32)
    send_w = sentf @ wf
    b = min(block, max(D, 1))
    n_blocks = -(-D // b)
    pad = n_blocks * b - D
    x, buf = flat, buf_t0
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        buf = jnp.pad(buf, ((0, 0), (0, pad)))
    z2, send_t = pl.pallas_call(
        _stale_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((K,), lambda i: (0,)),      # w resident
            pl.BlockSpec((K,), lambda i: (0,)),      # kept resident
            pl.BlockSpec((K, K), lambda i: (0, 0)),  # sent resident
            pl.BlockSpec((K,), lambda i: (0,)),      # w' resident
            pl.BlockSpec((K, b), lambda i: (0, i)),
            pl.BlockSpec((K, b), lambda i: (0, i)),
        ],
        out_specs=(pl.BlockSpec((K, b), lambda i: (0, i)),
                   pl.BlockSpec((K, b), lambda i: (0, i))),
        out_shape=(jax.ShapeDtypeStruct((K, n_blocks * b), flat.dtype),
                   jax.ShapeDtypeStruct((K, n_blocks * b), flat.dtype)),
        interpret=resolve_interpret(interpret),
    )(wf, keptf, sentf, w2, x, buf)
    return (z2[:, :D], send_t[:, :D], w2.astype(w.dtype),
            send_w.astype(w.dtype))
