"""Pallas TPU chunked selective-scan kernel (Mamba-1 recurrence).

TPU adaptation of the CUDA selective-scan: instead of one thread-block per
channel with warp shuffles, the grid is ``(batch, d_inner_blocks, chunks)``
with the chunk dimension innermost/sequential; the [block_d, d_state]
recurrent state lives in VMEM scratch and flows across chunk steps. Inside
a chunk the recurrence is a ``fori_loop`` over timesteps on [block_d,
d_state] tiles (VPU element-wise work + one [block_d]·[d_state] contraction
per step for y = C·h).

Computes: h_t = exp(dt_t ⊙ A) h_{t-1} + (dt_t x_t) B_t ;  y_t = C_t · h_t.
(The D·x skip term and gating are applied by the caller.)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import resolve_interpret


def _scan_kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, y_ref, h_scr, *, chunk: int):
    cj = pl.program_id(2)

    @pl.when(cj == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    A = a_ref[...].astype(jnp.float32)  # [bd, ds]

    def body(t, h):
        dt_t = dt_ref[0, t, :].astype(jnp.float32)  # [bd]
        x_t = x_ref[0, t, :].astype(jnp.float32)  # [bd]
        b_t = b_ref[0, t, :].astype(jnp.float32)  # [ds]
        c_t = c_ref[0, t, :].astype(jnp.float32)  # [ds]
        a = jnp.exp(dt_t[:, None] * A)  # [bd, ds]
        h = a * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[0, t, :] = jnp.sum(h * c_t[None, :], axis=1).astype(y_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, chunk, body, h_scr[...])


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def mamba_scan(
    dt: jnp.ndarray,  # [B, S, di] f32 (already softplus'ed)
    x: jnp.ndarray,  # [B, S, di]
    B_in: jnp.ndarray,  # [B, S, ds]
    C_in: jnp.ndarray,  # [B, S, ds]
    A: jnp.ndarray,  # [di, ds] (negative)
    *,
    chunk: int = 128,
    block_d: int = 512,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    B, S, di = x.shape
    ds = A.shape[1]
    bd = min(block_d, di)
    assert di % bd == 0, (di, bd)
    n_d = di // bd
    n_c = -(-S // chunk)
    S_pad = n_c * chunk
    if S_pad != S:
        padder = lambda t: jnp.pad(t, ((0, 0), (0, S_pad - S), (0, 0)))
        dt, x, B_in, C_in = padder(dt), padder(x), padder(B_in), padder(C_in)
        # dt = 0 on padding -> exp(0·A) = 1, input term 0 => state unchanged

    kernel = functools.partial(_scan_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(B, n_d, n_c),
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b, d, c: (b, c, d)),  # dt
            pl.BlockSpec((1, chunk, bd), lambda b, d, c: (b, c, d)),  # x
            pl.BlockSpec((1, chunk, ds), lambda b, d, c: (b, c, 0)),  # B
            pl.BlockSpec((1, chunk, ds), lambda b, d, c: (b, c, 0)),  # C
            pl.BlockSpec((bd, ds), lambda b, d, c: (d, 0)),  # A
        ],
        out_specs=pl.BlockSpec((1, chunk, bd), lambda b, d, c: (b, c, d)),
        out_shape=jax.ShapeDtypeStruct((B, S_pad, di), x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, ds), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(dt, x, B_in, C_in, A)
    return y[:, :S]
