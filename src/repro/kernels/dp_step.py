"""Pallas TPU fused DP noise-add + optimizer step kernels (tail of the
Eq. 7 chain).

After the per-example clip+accumulate scan (``dp_clip``), the plain-XLA
path still walks the full gradient/parameter set through HBM several more
times: noise add, clipped-mean divide, weight decay, moment updates and
the parameter step each run as separate ``tree_map`` passes. These kernels
fuse that tail so each gradient chunk is streamed HBM→VMEM once:

* :func:`noise_sgd_step`  — p' = p − lr·((acc + σ·noise)/n + wd·p)
* :func:`noise_adam_step` — the same fused chain through Adam's moment
  updates and bias-corrected step; returns (p', m', v').

The Gaussian noise vector is generated OUTSIDE (``jax.random.normal`` is
already a fused XLA kernel, and drawing it per parameter leaf with the
same key-split schedule as ``repro.core.dp.add_gaussian_noise`` keeps the
noise values identical to the unfused path — see
``repro.core.dp._flat_gaussian_like``); the kernels fuse all arithmetic
after the draw. Scalars ride in SMEM; b1/b2/eps are trace-time constants
(optimizer hyperparameters, fixed per compiled step). All math is f32 —
the fused path is gated to f32 params/moments by the caller
(``repro.core.dp.dp_adam_update``), matching Adam's f32 update path
exactly, so parity with the unfused chain is elementwise.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import resolve_interpret


def _pad1(x, pad):
    return jnp.pad(x, (0, pad)) if pad else x


def _sgd_kernel(sc_ref, acc_ref, noise_ref, p_ref, p2_ref):
    stddev, n_units, lr, wd = (sc_ref[0], sc_ref[1], sc_ref[2], sc_ref[3])
    g = (acc_ref[...] + stddev * noise_ref[...]) / n_units
    p = p_ref[...].astype(jnp.float32)
    g = g + wd * p
    p2_ref[...] = (p - lr * g).astype(p2_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def noise_sgd_step(acc: jnp.ndarray, noise: jnp.ndarray, p: jnp.ndarray, *,
                   stddev, n_units, lr, weight_decay=0.0, block: int = 65536,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused noise-add + clipped-mean + SGD step over 1-D flat vectors:
    ``p − lr·((acc + stddev·noise)/n_units + weight_decay·p)``."""
    n = acc.shape[0]
    b = min(block, max(n, 1))
    n_blocks = -(-n // b)
    pad = n_blocks * b - n
    sc = jnp.stack([jnp.asarray(s, jnp.float32)
                    for s in (stddev, n_units, lr, weight_decay)])
    out = pl.pallas_call(
        _sgd_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # scalars
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * b,), p.dtype),
        interpret=resolve_interpret(interpret),
    )(sc, _pad1(acc, pad), _pad1(noise, pad), _pad1(p, pad))
    return out[:n]


def _adam_kernel(b1, b2, eps, sc_ref, acc_ref, noise_ref, p_ref, m_ref,
                 v_ref, p2_ref, m2_ref, v2_ref):
    stddev, n_units, lr = sc_ref[0], sc_ref[1], sc_ref[2]
    wd, c1, c2 = sc_ref[3], sc_ref[4], sc_ref[5]
    g = (acc_ref[...] + stddev * noise_ref[...]) / n_units
    p = p_ref[...].astype(jnp.float32)
    g = g + wd * p
    m2 = b1 * m_ref[...].astype(jnp.float32) + (1.0 - b1) * g
    v2 = b2 * v_ref[...].astype(jnp.float32) + (1.0 - b2) * g * g
    step = lr * (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
    p2_ref[...] = (p - step).astype(p2_ref.dtype)
    m2_ref[...] = m2.astype(m2_ref.dtype)
    v2_ref[...] = v2.astype(v2_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("b1", "b2", "eps", "block", "interpret"))
def noise_adam_step(acc: jnp.ndarray, noise: jnp.ndarray, p: jnp.ndarray,
                    m: jnp.ndarray, v: jnp.ndarray, *, stddev, n_units, lr,
                    weight_decay=0.0, b1: float = 0.9, b2: float = 0.999,
                    eps: float = 1e-8, c1=None, c2=None, block: int = 65536,
                    interpret: Optional[bool] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused noise-add + clipped-mean + Adam step over 1-D flat vectors.

    ``c1``/``c2`` are the bias corrections ``1 − b1**t`` / ``1 − b2**t``
    for the POST-update step count t (runtime scalars — they depend on the
    traced step counter). Returns ``(p', m', v')`` with the exact update
    chain of :class:`repro.optim.optimizers.Adam` on the noisy clipped
    mean gradient ``(acc + stddev·noise)/n_units (+ weight_decay·p)``."""
    assert c1 is not None and c2 is not None, "pass bias corrections c1/c2"
    n = acc.shape[0]
    b = min(block, max(n, 1))
    n_blocks = -(-n // b)
    pad = n_blocks * b - n
    sc = jnp.stack([jnp.asarray(s, jnp.float32)
                    for s in (stddev, n_units, lr, weight_decay, c1, c2)])
    p2, m2, v2 = pl.pallas_call(
        functools.partial(_adam_kernel, float(b1), float(b2), float(eps)),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # scalars
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_specs=(pl.BlockSpec((b,), lambda i: (i,)),
                   pl.BlockSpec((b,), lambda i: (i,)),
                   pl.BlockSpec((b,), lambda i: (i,))),
        out_shape=(jax.ShapeDtypeStruct((n_blocks * b,), p.dtype),
                   jax.ShapeDtypeStruct((n_blocks * b,), m.dtype),
                   jax.ShapeDtypeStruct((n_blocks * b,), v.dtype)),
        interpret=resolve_interpret(interpret),
    )(sc, _pad1(acc, pad), _pad1(noise, pad), _pad1(p, pad), _pad1(m, pad),
      _pad1(v, pad))
    return p2[:n], m2[:n], v2[:n]
