"""Pallas TPU kernels for the round hot path and the LLM compute hot
spots, each validated against a pure-jnp oracle in ``ref.py``.

Dispatch policy
---------------
Every kernel takes ``interpret: Optional[bool] = None``. ``None`` resolves
via :func:`default_interpret`: REAL Mosaic kernels on TPU, ``interpret=True``
everywhere else (CPU CI, GPU). Interpret mode traces the kernel body into
ordinary XLA ops, so the fallback is just another jittable program — the
same numerics run on every platform and the conformance matrix
(tests/test_conformance.py) pins the fused paths allclose to plain XLA.
Callers never hardcode ``interpret=True``; pass an explicit bool only to
force a mode (the kernel sweeps in tests/test_kernels.py do).

Kernel → engine-path map
------------------------
- ``pushsum_mix.fused_pushsum_mix`` — θ'/w' PushSum exchange over the
  stacked [K, D] proxies with fused de-bias (Algorithm 1 lines 7-11).
  Serves ``FederationEngine`` vmap/async-τ0 round-blocks and the loop
  backend's host-side gossip, behind ``ProxyFLConfig.use_pallas`` via
  :func:`repro.core.gossip.pushsum_mix_debiased`.
- ``pushsum_mix.fused_stale_mix`` — the async backend's stale (τ>0)
  exchange: re-bias θ = z·w, keep the diagonal, emit the off-diagonal
  send, merge the delayed delivery and de-bias — one pass per chunk.
  Serves ``_stale_round_core`` via :func:`repro.core.gossip.stale_mix_apply`.
- ``dp_clip.sumsq`` / ``dp_clip.scale_accumulate`` — per-example clip +
  accumulate of DP-SGD (Eq. 7). Serve ``repro.core.dp.dp_gradient``'s
  scan path when ``use_pallas`` is on, and ``ops.tree_clip_accumulate``.
- ``dp_step.noise_adam_step`` / ``dp_step.noise_sgd_step`` — the tail of
  the DP chain fused: noise-add, clipped-mean divide, weight decay and
  the optimizer update touch each gradient chunk once. Serve
  ``repro.core.dp.dp_adam_update`` (wired into the ProxyFL/CE step fns).
- ``flash_attention`` / ``mamba_scan`` / ``rmsnorm`` — LLM-scale forward
  hot spots (prefill attention, selective scan, norm), used by
  ``repro.nn`` transformer/SSM blocks.
"""
import jax


def default_interpret() -> bool:
    """Platform autodetect for the ``interpret=None`` kernel default:
    compile real Mosaic kernels only on TPU; interpret elsewhere."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret) -> bool:
    """``None`` -> platform default; explicit bools pass through."""
    return default_interpret() if interpret is None else bool(interpret)


from . import ref  # noqa: E402  (helpers above must exist before submodules)
from .dp_step import noise_adam_step, noise_sgd_step  # noqa: E402
from .ops import (  # noqa: E402
    clip_accumulate,
    flash_attention,
    gqa_flash_attention,
    mamba_scan,
    scale_accumulate,
    sumsq,
    tree_clip_accumulate,
)
from .pushsum_mix import fused_pushsum_mix, fused_stale_mix  # noqa: E402

__all__ = [
    "ref",
    "default_interpret",
    "resolve_interpret",
    "clip_accumulate",
    "flash_attention",
    "fused_pushsum_mix",
    "fused_stale_mix",
    "gqa_flash_attention",
    "mamba_scan",
    "noise_adam_step",
    "noise_sgd_step",
    "scale_accumulate",
    "sumsq",
    "tree_clip_accumulate",
]
