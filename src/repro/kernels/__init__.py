"""Pallas TPU kernels for the compute hot spots, each validated in
interpret mode against a pure-jnp oracle in ``ref.py``:

- ``flash_attention`` — causal/sliding-window attention (prefill hot spot)
- ``mamba_scan``      — chunked selective scan (SSM/hybrid archs)
- ``dp_clip``         — fused per-example clip+accumulate (DP-SGD, Eq. 7)
"""
from . import ref
from .ops import (
    clip_accumulate,
    flash_attention,
    gqa_flash_attention,
    mamba_scan,
    scale_accumulate,
    sumsq,
    tree_clip_accumulate,
)

__all__ = [
    "ref",
    "clip_accumulate",
    "flash_attention",
    "gqa_flash_attention",
    "mamba_scan",
    "scale_accumulate",
    "sumsq",
    "tree_clip_accumulate",
]
