"""Pallas TPU fused DP-SGD clip-and-accumulate kernels (paper Eq. 7 inner
loop). Per-example gradients are flattened to 1-D; two kernels cover the
hot path:

* ``sumsq``           — blockwise partial sum-of-squares (norm computation),
* ``scale_accumulate``— acc += g * scale with the scalar scale in SMEM,

so one DP microbatch step streams each gradient chunk HBM→VMEM exactly once
per pass instead of materializing clipped copies (the fusion GPU DP-SGD
gets from apex-style multi-tensor kernels; here it is explicit VMEM
blocking on the VPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import resolve_interpret


def _sumsq_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    o_ref[0] = jnp.sum(x * x)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def sumsq(x: jnp.ndarray, *, block: int = 65536,
          interpret: Optional[bool] = None) -> jnp.ndarray:
    """Sum of squares of a 1-D vector (f32 accumulation)."""
    n = x.shape[0]
    b = min(block, max(n, 1))
    n_blocks = -(-n // b)
    if n_blocks * b != n:
        x = jnp.pad(x, (0, n_blocks * b - n))
    partial_sums = pl.pallas_call(
        _sumsq_kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((b,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_blocks,), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(x)
    return jnp.sum(partial_sums)


def _scale_acc_kernel(scale_ref, acc_ref, g_ref, o_ref):
    s = scale_ref[0]
    o_ref[...] = acc_ref[...] + g_ref[...].astype(jnp.float32) * s


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def scale_accumulate(acc: jnp.ndarray, g: jnp.ndarray, scale: jnp.ndarray,
                     *, block: int = 65536,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """acc + g * scale for 1-D f32 acc / any-dtype g, blockwise."""
    n = acc.shape[0]
    b = min(block, max(n, 1))
    n_blocks = -(-n // b)
    pad = n_blocks * b - n
    if pad:
        acc = jnp.pad(acc, (0, pad))
        g = jnp.pad(g, (0, pad))
    out = pl.pallas_call(
        _scale_acc_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # scalar scale
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * b,), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(scale.reshape(1).astype(jnp.float32), acc, g)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("clip_norm", "block", "interpret"))
def clip_accumulate(acc: jnp.ndarray, g: jnp.ndarray, clip_norm: float,
                    *, block: int = 65536,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """One per-example DP-SGD update of the gradient accumulator:
    acc += g / max(1, ||g||/C)  — Eq. (7) clip + sum, fused."""
    norm = jnp.sqrt(sumsq(g, block=block, interpret=interpret))
    scale = 1.0 / jnp.maximum(1.0, norm / clip_norm)
    return scale_accumulate(acc, g, scale, block=block, interpret=interpret)
