"""Pallas TPU flash-attention kernel (causal, optional sliding window).

Targets the MXU with explicit VMEM tiling: the grid is
``(batch*heads, q_blocks, kv_blocks)`` with the KV dimension innermost
(sequential on TPU), carrying the online-softmax state (m, l, acc) in VMEM
scratch across KV blocks. Block shapes should keep the contraction dims at
multiples of 128 for MXU alignment.

Validated against ``ref.flash_attention_ref`` in interpret mode (this
container has no TPU); the pure-JAX chunked equivalent used by the model
stack lives in ``repro.nn.attention.attend``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import resolve_interpret

NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int,
                  seq_len: int, window: Optional[int], causal: bool):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # [bq, d]
    k = k_ref[0].astype(jnp.float32)  # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    ok = k_pos < seq_len  # padding
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.where(ok, jnp.exp(s - m_safe[:, None]), 0.0)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    v = v_ref[0].astype(jnp.float32)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # [B, H, S, D]
    k: jnp.ndarray,  # [B, H, S, D]  (kv heads pre-broadcast for GQA)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    B, H, S, D = q.shape
    scale = float(scale if scale is not None else D ** -0.5)
    bq, bk = min(block_q, S), min(block_k, S)
    n_q = -(-S // bq)
    n_k = -(-S // bk)
    Sq_pad, Sk_pad = n_q * bq, n_k * bk
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, S, D)
    vf = v.reshape(B * H, S, D)
    if Sq_pad != S:
        qf = jnp.pad(qf, ((0, 0), (0, Sq_pad - S), (0, 0)))
    if Sk_pad != S:
        kf = jnp.pad(kf, ((0, 0), (0, Sk_pad - S), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, Sk_pad - S), (0, 0)))

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=bq, block_k=bk,
        seq_len=S, window=window, causal=causal)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq_pad, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(qf, kf, vf)
    return out[:, :S].reshape(B, H, S, D)
