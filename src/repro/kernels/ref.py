"""Pure-jnp oracles for every Pallas kernel (the correctness references the
kernel sweeps assert against)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """Naive materialized-scores attention. q/k/v: [B, H, S, D]."""
    B, H, S, D = q.shape
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= (qp - kp) < window
    s = jnp.where(ok, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def mamba_scan_ref(dt, x, B_in, C_in, A) -> jnp.ndarray:
    """Sequential selective scan. dt/x: [B,S,di]; B/C: [B,S,ds]; A: [di,ds]."""
    Bsz, S, di = x.shape

    def step(h, t):
        dt_t, x_t, b_t, c_t = t
        a = jnp.exp(dt_t[..., None] * A)  # [B, di, ds]
        h = a * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    h0 = jnp.zeros((Bsz, di, A.shape[1]), jnp.float32)
    xs = (dt.swapaxes(0, 1).astype(jnp.float32), x.swapaxes(0, 1).astype(jnp.float32),
          B_in.swapaxes(0, 1).astype(jnp.float32), C_in.swapaxes(0, 1).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype)


def sumsq_ref(x) -> jnp.ndarray:
    return jnp.sum(jnp.square(x.astype(jnp.float32)))


def scale_accumulate_ref(acc, g, scale) -> jnp.ndarray:
    return acc + g.astype(jnp.float32) * scale


def clip_accumulate_ref(acc, g, clip_norm: float) -> jnp.ndarray:
    norm = jnp.sqrt(sumsq_ref(g))
    return acc + g.astype(jnp.float32) / jnp.maximum(1.0, norm / clip_norm)


def rmsnorm_ref(x, g, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * g.astype(jnp.float32)).astype(x.dtype)


def fused_pushsum_mix_ref(flat, w, P, *, debias: bool = True):
    """Synchronous PushSum exchange, f32 accumulation: (P·z [/ P·w], P·w)."""
    Pf = jnp.asarray(P, jnp.float32)
    mixed = Pf @ flat.astype(jnp.float32)
    w2 = Pf @ w.astype(jnp.float32)
    if debias:
        mixed = mixed / w2[:, None]
    return mixed.astype(flat.dtype), w2.astype(w.dtype)


def fused_stale_mix_ref(flat, w, kept, sent, buf_t0, buf_w0):
    """Stale (async τ>0) exchange: re-bias θ = z·w, split kept/sent, merge
    the delayed delivery, de-bias — returns (z', send_t, w', send_w)."""
    wf = w.astype(jnp.float32)
    theta = flat.astype(jnp.float32) * wf[:, None]
    send_t = sent.astype(jnp.float32) @ theta
    send_w = sent.astype(jnp.float32) @ wf
    mixed = kept.astype(jnp.float32)[:, None] * theta \
        + buf_t0.astype(jnp.float32)
    w2 = kept.astype(jnp.float32) * wf + buf_w0.astype(jnp.float32)
    z2 = mixed / w2[:, None]
    return (z2.astype(flat.dtype), send_t.astype(flat.dtype),
            w2.astype(w.dtype), send_w.astype(w.dtype))


def noise_sgd_step_ref(acc, noise, p, *, stddev, n_units, lr,
                       weight_decay=0.0):
    g = (acc.astype(jnp.float32) + stddev * noise.astype(jnp.float32)) \
        / n_units
    pf = p.astype(jnp.float32)
    g = g + weight_decay * pf
    return (pf - lr * g).astype(p.dtype)


def noise_adam_step_ref(acc, noise, p, m, v, *, stddev, n_units, lr,
                        weight_decay=0.0, b1=0.9, b2=0.999, eps=1e-8,
                        c1=None, c2=None):
    g = (acc.astype(jnp.float32) + stddev * noise.astype(jnp.float32)) \
        / n_units
    pf = p.astype(jnp.float32)
    g = g + weight_decay * pf
    m2 = b1 * m.astype(jnp.float32) + (1.0 - b1) * g
    v2 = b2 * v.astype(jnp.float32) + (1.0 - b2) * g * g
    step = lr * (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
    return ((pf - step).astype(p.dtype), m2.astype(m.dtype),
            v2.astype(v.dtype))
