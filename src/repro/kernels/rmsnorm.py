"""Pallas TPU fused RMSNorm kernel.

RMSNorm is applied 2×/layer × every token — at train_4k that is ~0.5 TB of
HBM traffic per step if the mean-square reduction, rsqrt and scale run as
separate XLA ops. The fused kernel streams each [block_rows, d] tile
HBM→VMEM once, does the f32 reduction + normalize + gain on the VPU, and
writes the tile back once.

Tiling: rows (flattened batch×seq) × full d_model. d_model of the assigned
archs is ≤ 8192 (32 KiB/row in f32), so a [rows_block, d] tile with
rows_block=256 sits comfortably in the ~16 MiB VMEM budget.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import resolve_interpret


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps", "interpret"))
def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, *, eps: float = 1e-6,
            block_rows: int = 256,
            interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused RMSNorm. x: [..., d]; g: [d]. Returns x.dtype."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    b = min(block_rows, max(rows, 1))
    n_blocks = -(-rows // b)
    pad = n_blocks * b - rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((b, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * b, d), x.dtype),
        interpret=resolve_interpret(interpret),
    )(xf, g)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
