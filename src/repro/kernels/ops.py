"""Jitted public wrappers around the Pallas kernels, with GQA head
broadcasting and pytree-level DP clipping built on the flat kernels."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..nn.modules import tree_flatten_vector, tree_unflatten_vector
from .dp_clip import clip_accumulate, scale_accumulate, sumsq
from .flash_attention import flash_attention
from .mamba_scan import mamba_scan
from .rmsnorm import rmsnorm


def gqa_flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                        block_q=128, block_k=128,
                        interpret: Optional[bool] = None):
    """q: [B, Sq, Hq, D]; k/v: [B, Skv, Hkv, D] (model-stack layout).
    Broadcasts KV heads for grouped queries and calls the Pallas kernel."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    assert Hq % Hkv == 0
    if Hkv != Hq:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    args = [t.transpose(0, 2, 1, 3) for t in (q, k, v)]  # -> [B, H, S, D]
    out = flash_attention(*args, causal=causal, window=window, scale=scale,
                          block_q=block_q, block_k=block_k, interpret=interpret)
    return out.transpose(0, 2, 1, 3)


def tree_clip_accumulate(acc_tree, grad_tree, clip_norm: float, *,
                         interpret: Optional[bool] = None):
    """Eq. (7) clip+accumulate on whole parameter pytrees via the fused
    flat kernels (norm over ALL leaves jointly, as DP-SGD requires)."""
    flat_g = tree_flatten_vector(grad_tree)
    flat_a = tree_flatten_vector(acc_tree).astype(jnp.float32)
    out = clip_accumulate(flat_a, flat_g, float(clip_norm), interpret=interpret)
    return tree_unflatten_vector(out, acc_tree)


__all__ = [
    "flash_attention",
    "gqa_flash_attention",
    "mamba_scan",
    "rmsnorm",
    "sumsq",
    "scale_accumulate",
    "clip_accumulate",
    "tree_clip_accumulate",
]
