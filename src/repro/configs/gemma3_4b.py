"""gemma3-4b [dense] — [hf:google/gemma-3-1b-pt family]

34L d_model=2560 8H (GQA kv=4, head_dim=256) d_ff=10240 vocab=262144.
5:1 local:global attention — 5 sliding-window (1024) layers per 1 global
layer; local layers use rope_theta=10k, global layers 1M (128k context).
"""
from .base import LayerSpec, ModelConfig
from .registry import register

_LOCAL = LayerSpec(kind="attn", ffn="dense", window=1024, rope_theta=10000.0)
_GLOBAL = LayerSpec(kind="attn", ffn="dense", rope_theta=1000000.0)


@register("gemma3-4b")
def gemma3_4b() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        arch_type="dense",
        vocab_size=262144,
        d_model=2560,
        n_layers=34,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
        rope_theta=1000000.0,
        tie_embeddings=True,
        dtype="bfloat16",
        source="hf:google/gemma-3-1b-pt",
    )
