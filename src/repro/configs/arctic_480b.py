"""arctic-480b [moe] — [hf:Snowflake/snowflake-arctic-base]

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2,
dense-MoE hybrid: every layer has a (residual) dense MLP in parallel with
the 128-expert top-2 MoE.
"""
from .base import LayerSpec, ModelConfig, MoEConfig
from .registry import register


@register("arctic-480b")
def arctic_480b() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        arch_type="moe",
        vocab_size=32000,
        d_model=7168,
        n_layers=35,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        moe=MoEConfig(
            n_experts=128,
            top_k=2,
            d_ff_expert=4864,
            dense_residual_d_ff=4864,  # arctic's parallel dense residual MLP
            capacity_factor=1.25,
        ),
        pattern=(LayerSpec(kind="attn", ffn="moe"),),
        rope_theta=10000.0,
        dtype="bfloat16",
        source="hf:Snowflake/snowflake-arctic-base",
    )
