"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``. Layer
heterogeneity (sliding-window patterns, hybrid attn/mamba interleave,
MoE-every-k) is expressed with ``LayerSpec`` patterns: the model stack is
``prefix`` (unrolled) followed by ``pattern`` repeated until ``n_layers``
is reached (a trailing partial pattern is allowed). Layers at the same
pattern position share stacked parameters and are executed with
``lax.scan`` so that HLO size stays O(pattern length), not O(n_layers) —
essential for fast ``.lower().compile()`` at 512 devices.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer specification


@dataclass(frozen=True)
class LayerSpec:
    """One decoder layer: token mixer + channel mixer."""

    kind: str = "attn"  # "attn" | "mamba"
    ffn: str = "dense"  # "dense" | "moe" | "none"
    window: Optional[int] = None  # sliding-window size for kind=="attn"
    rope_theta: Optional[float] = None  # per-layer RoPE base override


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 0  # per-expert hidden dim
    n_shared_experts: int = 0  # deepseek-style always-on experts
    dense_residual_d_ff: int = 0  # arctic-style parallel dense MLP
    capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    arch_type: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio
    vocab_size: int = 32000
    d_model: int = 512
    n_layers: int = 4
    # attention
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    attn_impl: str = "gqa"  # "gqa" | "mla"
    mla: Optional[MLAConfig] = None
    # ffn
    d_ff: int = 2048
    moe: Optional[MoEConfig] = None
    # ssm
    mamba: Optional[MambaConfig] = None
    # stack layout
    prefix: Tuple[LayerSpec, ...] = ()
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    # modality
    modality: str = "text"  # text | vlm | audio
    n_codebooks: int = 1  # audio: parallel codebooks
    n_image_tokens: int = 0  # vlm: stub patch-embedding count
    frontend_dim: int = 1024  # vlm: dim of (stubbed) vision-encoder outputs
    # misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "float32"
    # provenance
    source: str = ""

    # -- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        m = self.mamba or MambaConfig()
        return m.expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        m = self.mamba or MambaConfig()
        return m.dt_rank or int(math.ceil(self.d_model / 16))

    def layout(self) -> Tuple[LayerSpec, ...]:
        """Full per-layer spec list of length n_layers."""
        specs = list(self.prefix)
        i = 0
        while len(specs) < self.n_layers:
            specs.append(self.pattern[i % len(self.pattern)])
            i += 1
        return tuple(specs[: self.n_layers])

    def pattern_plan(self) -> Tuple[int, int]:
        """(full pattern repeats, remainder positions) after the prefix."""
        n = self.n_layers - len(self.prefix)
        assert n >= 0
        return n // len(self.pattern), n % len(self.pattern)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter count (analytic, for roofline MODEL_FLOPS) ---------------
    def param_counts(self) -> dict:
        """Analytic parameter counts: total and active-per-token."""
        d = self.d_model
        hd = self.resolved_head_dim
        total = 0
        active = 0
        emb = self.vocab_size * d * self.n_codebooks
        head = 0 if self.tie_embeddings else self.vocab_size * d * self.n_codebooks
        total += emb + head
        active += emb + head
        for spec in self.layout():
            t = a = 0
            if spec.kind == "attn":
                if self.attn_impl == "mla":
                    m = self.mla or MLAConfig()
                    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
                    t += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk_head
                    t += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    t += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    t += self.n_heads * m.v_head_dim * d
                else:
                    t += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                    t += self.n_heads * hd * d
                a += t
            elif spec.kind == "mamba":
                di, ds, dt = self.d_inner, (self.mamba or MambaConfig()).d_state, self.resolved_dt_rank
                t += d * 2 * di  # in_proj
                t += di * (self.mamba or MambaConfig()).d_conv  # conv
                t += di * (dt + 2 * ds)  # x_proj
                t += dt * di + di  # dt_proj
                t += di * ds + di  # A_log, D
                t += di * d  # out_proj
                a += t
            if spec.ffn == "dense":
                f = 3 * d * self.d_ff
                t += f
                a += f
            elif spec.ffn == "moe":
                mo = self.moe or MoEConfig()
                per_exp = 3 * d * mo.d_ff_expert
                t += mo.n_experts * per_exp + d * mo.n_experts
                a += mo.top_k * per_exp + d * mo.n_experts
                if mo.n_shared_experts:
                    sh = mo.n_shared_experts * per_exp
                    t += sh
                    a += sh
                if mo.dense_residual_d_ff:
                    r = 3 * d * mo.dense_residual_d_ff
                    t += r
                    a += r
            total += t
            active += a
        return {"total": total, "active": active}


# ---------------------------------------------------------------------------
# Input shapes (assigned)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# ProxyFL protocol configuration (the paper's knobs)


@dataclass(frozen=True)
class DPConfig:
    enabled: bool = True
    clip_norm: float = 1.0  # C
    noise_multiplier: float = 1.0  # sigma
    delta: float = 1e-5
    sample_rate: float = 0.0  # q; 0 -> batch/dataset size at runtime
    vectorized: bool = False  # vmap per-example grads instead of scan (same
    # result; scan is O(1)-memory and measured faster on 1-core CPU)


@dataclass(frozen=True)
class ProxyFLConfig:
    alpha: float = 0.5  # private-model DML weight (Eq. 4)
    beta: float = 0.5  # proxy-model DML weight (Eq. 5)
    n_clients: int = 8
    rounds: int = 10
    local_steps: int = 0  # 0 -> one epoch over local data
    lr: float = 1e-3
    weight_decay: float = 1e-4
    batch_size: int = 250
    dp: DPConfig = field(default_factory=DPConfig)
    topology: str = "exponential"  # exponential | ring | full
    seed: int = 0
    # §3.4 dropout/join: per-round probability a client sits the round out
    # (no local steps, no gossip; the time-varying graph adapts around it).
    dropout_rate: float = 0.0
    min_active: int = 1  # floor on participating clients per round
    # Federation execution backend:
    # "auto" | "loop" | "vmap" | "shard_map" | "async" | "hier"
    # (see repro.core.engine.FederationEngine for the selection guide).
    backend: str = "auto"
    # Gossip staleness τ for backend="async": the round-t exchange delivers
    # neighbor proxy mass captured τ rounds earlier (in-flight until then),
    # modeling communication overlapped with the local scan (Assran et al.
    # 2019). 0 = synchronous delivery — bit-identical to the vmap backend.
    # For backend="hier" τ delays the CROSS-SHARD edges only (intra-shard
    # exchange stays synchronous).
    staleness: int = 0
    # Two-level cohort layout for backend="hier": n_shards shards of
    # n_clients/n_shards clients each (must divide evenly). Intra-shard
    # exchange is the on-device matmul mix; the at-most-one cross-shard
    # edge per client per round is the ppermute-shaped collective.
    # n_shards=1 keeps every edge intra-shard — the engine then runs the
    # vmap round programs verbatim (bit-identical). Ignored by the other
    # backends.
    n_shards: int = 1
    # Pallas-fused round hot path: run the PushSum exchange and the DP
    # clip→noise→step chain as blocked HBM→VMEM kernels (repro.kernels) —
    # real Mosaic kernels on TPU, interpret mode elsewhere. Numerics are
    # allclose to the plain-XLA path (same math, different reduction
    # order), pinned by the use_pallas columns of tests/test_conformance.py.
    # Off by default: plain XLA remains the reference semantics.
    use_pallas: bool = False
    # Compressed proxy exchange (repro.core.compress): what each client's
    # transmitted proxy looks like on the wire. "none" keeps the exchange
    # byte-for-byte the full-precision protocol; "topk" keeps the
    # compress_ratio·D largest-magnitude entries (bf16 values + position
    # bitmap on the wire, ~6.4x fewer bytes at ratio 0.25); "int8" ships
    # stochastically-rounded 8-bit values with one f32 scale per client
    # (~4x). What goes on the wire is a compressed DELTA against a
    # public copy of the proxy every receiver holds (carried per client
    # in the engine state; receivers mix the dense updated copy), so
    # truncated mass stays in the implicit residual and is re-sent later
    # — compression delays information instead of destroying it. Composes
    # with loop/vmap/blocked/async-τ>0; shard_map rejects it, and
    # use_pallas falls back to the plain-XLA exchange while compressing.
    compress: str = "none"  # "none" | "topk" | "int8"
    compress_ratio: float = 0.25  # top-k kept fraction of D
    # Verifiable federation (repro.core.commit): verify proxy commitments.
    # On the loop backend every received proxy's chunked-leaf digest is
    # recomputed and checked against the sender's declared commitment
    # BEFORE mixing (a tampered in-flight proxy refuses with a
    # CommitmentError naming client and round), and checkpoint restores
    # run in strict mode — snapshots without commitment records or a
    # recorded config fingerprint are refused instead of warned about.
    # Chain/digest MISMATCHES on restore are refused regardless of this
    # flag. Off by default: verification observes state but never changes
    # it (the verified trajectory is bit-identical), so the flag is
    # excluded from the config fingerprint.
    verify_commitments: bool = False
