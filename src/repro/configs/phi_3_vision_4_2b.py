"""phi-3-vision-4.2b [vlm] — [hf:microsoft/Phi-3-vision-128k-instruct]

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.
phi3-mini text backbone + CLIP ViT frontend. The vision encoder is a STUB
per the task carve-out: ``input_specs()`` feeds precomputed patch
embeddings of shape [B, n_image_tokens, d_model].
"""
from .base import LayerSpec, ModelConfig
from .registry import register


@register("phi-3-vision-4.2b")
def phi_3_vision_4_2b() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b",
        arch_type="vlm",
        modality="vlm",
        vocab_size=32064,
        d_model=3072,
        n_layers=32,
        n_heads=32,
        n_kv_heads=32,
        head_dim=96,
        d_ff=8192,
        n_image_tokens=576,  # CLIP ViT-L/14 @336px -> 24x24 patches
        pattern=(LayerSpec(kind="attn", ffn="dense"),),
        rope_theta=10000.0,
        dtype="bfloat16",
        source="hf:microsoft/Phi-3-vision-128k-instruct",
    )
