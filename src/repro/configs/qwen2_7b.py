"""qwen2-7b [dense] — [arXiv:2407.10671]

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064, QKV bias.
"""
from .base import LayerSpec, ModelConfig
from .registry import register


@register("qwen2-7b")
def qwen2_7b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b",
        arch_type="dense",
        vocab_size=152064,
        d_model=3584,
        n_layers=28,
        n_heads=28,
        n_kv_heads=4,
        head_dim=128,
        qkv_bias=True,
        d_ff=18944,
        pattern=(LayerSpec(kind="attn", ffn="dense"),),
        rope_theta=1000000.0,
        dtype="bfloat16",
        source="arXiv:2407.10671",
    )


@register("qwen2-7b-swa")
def qwen2_7b_swa() -> ModelConfig:
    """Beyond-paper variant: sliding-window (4096) attention on 27/28 layers
    so the dense family can exercise the long_500k decode shape."""
    base = qwen2_7b()
    return base.with_(
        name="qwen2-7b-swa",
        prefix=(LayerSpec(kind="attn", ffn="dense"),),  # one global layer
        pattern=(LayerSpec(kind="attn", ffn="dense", window=4096),),
        source="arXiv:2407.10671 (+SWA override, ours)",
    )
