"""falcon-mamba-7b [ssm] — [arXiv:2410.05355]

64L d_model=4096, attention-free Mamba-1 blocks (no separate FFN; the Mamba
block is the whole layer), vocab=65024, ssm_state=16, d_inner=2*d_model,
dt_rank=ceil(d_model/16)=256, d_conv=4.
"""
from .base import LayerSpec, MambaConfig, ModelConfig
from .registry import register


@register("falcon-mamba-7b")
def falcon_mamba_7b() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        arch_type="ssm",
        vocab_size=65024,
        d_model=4096,
        n_layers=64,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2, dt_rank=0),
        pattern=(LayerSpec(kind="mamba", ffn="none"),),
        dtype="bfloat16",
        source="arXiv:2410.05355",
    )
