"""Architecture registry.

Every assigned architecture registers a ``ModelConfig`` factory here, keyed
by its public id (``--arch <id>``). ``proxy_of`` derives the common proxy
architecture all ProxyFL clients agree on (paper §3.1: "all clients agree on
a common proxy model architecture"; "the proxy model is generally smaller
than the private model").
"""
from __future__ import annotations

from typing import Callable, Dict, List

from .base import LayerSpec, ModelConfig

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> List[str]:
    return sorted(_REGISTRY)


def proxy_of(private: ModelConfig, *, n_layers: int = 4, d_model: int = 512) -> ModelConfig:
    """The common proxy architecture for a federation whose task matches
    ``private``'s input/output spaces (same vocab / modality / codebooks)."""
    return ModelConfig(
        name=f"proxy-of-{private.name}",
        arch_type="dense",
        vocab_size=private.vocab_size,
        d_model=d_model,
        n_layers=n_layers,
        n_heads=8,
        n_kv_heads=8,
        head_dim=d_model // 8,
        d_ff=4 * d_model,
        pattern=(LayerSpec(kind="attn", ffn="dense"),),
        modality=private.modality,
        n_codebooks=private.n_codebooks,
        n_image_tokens=private.n_image_tokens,
        tie_embeddings=True,
        dtype=private.dtype,
        source="ProxyFL common proxy spec",
    )


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts."""
    kw: dict = dict(
        n_layers=max(2, len(cfg.prefix) + (1 if len(cfg.prefix) < 2 else 0)),
        d_model=256,
        n_heads=4,
        n_kv_heads=min(4, cfg.n_kv_heads) if cfg.n_kv_heads else 0,
        head_dim=64,
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=512,
    )
    # keep the pattern structure but cover >=1 full pattern when cheap
    kw["n_layers"] = max(2, len(cfg.prefix) + len(cfg.pattern))
    if kw["n_layers"] > 10:  # long patterns (jamba): truncate to 2 pattern slots
        kw["n_layers"] = len(cfg.prefix) + 2
    if cfg.moe is not None:
        kw["moe"] = cfg.moe.__class__(
            n_experts=4,
            top_k=min(2, cfg.moe.top_k),
            d_ff_expert=256,
            n_shared_experts=min(1, cfg.moe.n_shared_experts),
            dense_residual_d_ff=256 if cfg.moe.dense_residual_d_ff else 0,
        )
    if cfg.mla is not None:
        kw["mla"] = cfg.mla.__class__(
            kv_lora_rank=64, q_lora_rank=96, qk_nope_head_dim=32,
            qk_rope_head_dim=16, v_head_dim=32,
        )
    if cfg.mamba is not None:
        kw["mamba"] = cfg.mamba.__class__(d_state=8, d_conv=4, expand=2, dt_rank=16)
    if cfg.n_image_tokens:
        kw["n_image_tokens"] = 16
    # shrink sliding windows so they are exercised at smoke seq lens
    def shrink(spec: LayerSpec) -> LayerSpec:
        if spec.window:
            return LayerSpec(kind=spec.kind, ffn=spec.ffn, window=8, rope_theta=spec.rope_theta)
        return spec

    kw["prefix"] = tuple(shrink(s) for s in cfg.prefix)
    kw["pattern"] = tuple(shrink(s) for s in cfg.pattern)
    return cfg.with_(name=cfg.name + "-smoke", **kw)
