"""qwen1.5-110b [dense] — [hf:Qwen/Qwen1.5-0.5B family]

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064, QKV bias.
"""
from .base import LayerSpec, ModelConfig
from .registry import register


@register("qwen1.5-110b")
def qwen1_5_110b() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b",
        arch_type="dense",
        vocab_size=152064,
        d_model=8192,
        n_layers=80,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        qkv_bias=True,
        d_ff=49152,
        pattern=(LayerSpec(kind="attn", ffn="dense"),),
        rope_theta=1000000.0,
        dtype="bfloat16",
        source="hf:Qwen/Qwen1.5-0.5B",
    )
