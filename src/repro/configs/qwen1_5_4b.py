"""qwen1.5-4b [dense] — [hf:Qwen/Qwen1.5-0.5B family]

40L d_model=2560 20H (MHA kv=20) d_ff=6912 vocab=151936, QKV bias.
"""
from .base import LayerSpec, ModelConfig
from .registry import register


@register("qwen1.5-4b")
def qwen1_5_4b() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b",
        arch_type="dense",
        vocab_size=151936,
        d_model=2560,
        n_layers=40,
        n_heads=20,
        n_kv_heads=20,
        head_dim=128,
        qkv_bias=True,
        d_ff=6912,
        pattern=(LayerSpec(kind="attn", ffn="dense"),),
        rope_theta=1000000.0,
        dtype="bfloat16",
        source="hf:Qwen/Qwen1.5-0.5B",
    )
