"""The paper's own experimental configurations (Section 4 / Appendix A).

Image-classification models (MLP / LeNet5 / CNN1 / CNN2 / VGG-small /
ResNet18-GN) live in ``repro.nn.vision``; this module holds their hyper-
parameter descriptions plus the federated-experiment settings used by the
per-figure benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

from .base import DPConfig, ProxyFLConfig


@dataclass(frozen=True)
class VisionDataConfig:
    name: str
    image_shape: Tuple[int, int, int]  # H, W, C
    n_classes: int
    train_per_client: int
    p_major: float  # non-IID majority-class fraction (0.1 == IID for 10 classes)
    partition: str = "major"  # "major" | "dirichlet"
    dirichlet_alpha: float = 0.5


# paper §4.1 dataset settings (synthetic stand-ins keep the same structure)
MNIST = VisionDataConfig("mnist", (28, 28, 1), 10, 1000, 0.8)
FAMNIST = VisionDataConfig("famnist", (28, 28, 1), 10, 1000, 0.8)
CIFAR10 = VisionDataConfig("cifar10", (32, 32, 3), 10, 3000, 0.3)
KVASIR = VisionDataConfig("kvasir", (80, 100, 3), 8, 750, 0.0, partition="dirichlet")
CAMELYON = VisionDataConfig("camelyon", (64, 64, 3), 2, 2700, 0.0, partition="dirichlet")

DATASETS = {c.name: c for c in (MNIST, FAMNIST, CIFAR10, KVASIR, CAMELYON)}


def paper_benchmark_protocol(**overrides) -> ProxyFLConfig:
    """§4.1 settings: Adam lr 1e-3, wd 1e-4, B=250, C=1.0, sigma=1.0,
    alpha=beta=0.5, 8 clients."""
    kw = dict(
        alpha=0.5,
        beta=0.5,
        n_clients=8,
        rounds=10,
        lr=1e-3,
        weight_decay=1e-4,
        batch_size=250,
        dp=DPConfig(enabled=True, clip_norm=1.0, noise_multiplier=1.0, delta=1e-5),
    )
    kw.update(overrides)
    return ProxyFLConfig(**kw)


def paper_histo_protocol(**overrides) -> ProxyFLConfig:
    """§4.4 settings: 4 clients, B=32, sigma=1.4, C=0.7, alpha=beta=0.3."""
    kw = dict(
        alpha=0.3,
        beta=0.3,
        n_clients=4,
        rounds=30,
        lr=1e-3,
        weight_decay=1e-4,
        batch_size=32,
        dp=DPConfig(enabled=True, clip_norm=0.7, noise_multiplier=1.4, delta=1e-5),
    )
    kw.update(overrides)
    return ProxyFLConfig(**kw)
