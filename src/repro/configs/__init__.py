from .base import (
    DPConfig,
    InputShape,
    INPUT_SHAPES,
    LayerSpec,
    MambaConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ProxyFLConfig,
)
from .registry import get_config, list_archs, proxy_of, smoke_variant

# importing the arch modules populates the registry
from . import (  # noqa: F401
    arctic_480b,
    deepseek_v2_236b,
    falcon_mamba_7b,
    gemma3_4b,
    jamba_1_5_large_398b,
    musicgen_medium,
    phi_3_vision_4_2b,
    qwen1_5_110b,
    qwen1_5_4b,
    qwen2_7b,
)
from . import paper_small  # noqa: F401

ASSIGNED_ARCHS = [
    "deepseek-v2-236b",
    "qwen2-7b",
    "phi-3-vision-4.2b",
    "arctic-480b",
    "musicgen-medium",
    "falcon-mamba-7b",
    "gemma3-4b",
    "jamba-1.5-large-398b",
    "qwen1.5-110b",
    "qwen1.5-4b",
]

__all__ = [
    "DPConfig",
    "InputShape",
    "INPUT_SHAPES",
    "LayerSpec",
    "MambaConfig",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "ProxyFLConfig",
    "get_config",
    "list_archs",
    "proxy_of",
    "smoke_variant",
    "ASSIGNED_ARCHS",
]
