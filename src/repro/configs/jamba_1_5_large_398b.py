"""jamba-1.5-large-398b [hybrid] — [arXiv:2403.19887]

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Jamba block structure: period of 8 layers with 1 attention : 7 Mamba
(attention at in-block index 4), and MoE replacing the dense MLP on every
second layer (odd in-block indices).
"""
from .base import LayerSpec, MambaConfig, ModelConfig, MoEConfig
from .registry import register


def _slot(i: int) -> LayerSpec:
    kind = "attn" if i == 4 else "mamba"
    ffn = "moe" if i % 2 == 1 else "dense"
    return LayerSpec(kind=kind, ffn=ffn)


@register("jamba-1.5-large-398b")
def jamba_1_5_large_398b() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        arch_type="hybrid",
        vocab_size=65536,
        d_model=8192,
        n_layers=72,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576, capacity_factor=1.25),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2, dt_rank=0),
        pattern=tuple(_slot(i) for i in range(8)),
        rope_theta=10000.0,
        dtype="bfloat16",
        source="arXiv:2403.19887",
    )
