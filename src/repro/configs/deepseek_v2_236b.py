"""deepseek-v2-236b [moe] — [arXiv:2405.04434]

60L d_model=5120 128H d_ff(expert)=1536 vocab=102400, MoE 160e top-6,
MLA kv_lora=512, 2 shared + 160 routed experts. First layer uses a dense
FFN (intermediate 12288) per the paper; all subsequent layers are MoE.
"""
from .base import LayerSpec, MLAConfig, ModelConfig, MoEConfig
from .registry import register


@register("deepseek-v2-236b")
def deepseek_v2_236b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        arch_type="moe",
        vocab_size=102400,
        d_model=5120,
        n_layers=60,
        n_heads=128,
        n_kv_heads=128,
        attn_impl="mla",
        mla=MLAConfig(
            kv_lora_rank=512,
            q_lora_rank=1536,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        d_ff=12288,  # layer-0 dense MLP (paper table 1)
        moe=MoEConfig(
            n_experts=160,
            top_k=6,
            d_ff_expert=1536,
            n_shared_experts=2,
            capacity_factor=1.25,
        ),
        prefix=(LayerSpec(kind="attn", ffn="dense"),),
        pattern=(LayerSpec(kind="attn", ffn="moe"),),
        rope_theta=10000.0,
        dtype="bfloat16",
        source="arXiv:2405.04434",
    )
