"""musicgen-medium [audio] — [arXiv:2306.05284]

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048. Decoder-only
transformer over EnCodec RVQ tokens: 4 parallel codebooks (delay pattern),
embeddings summed at the input, 4 parallel LM heads at the output.
The EnCodec conv codec frontend is a STUB per the task carve-out.
"""
from .base import LayerSpec, ModelConfig
from .registry import register


@register("musicgen-medium")
def musicgen_medium() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        arch_type="audio",
        modality="audio",
        vocab_size=2048,
        d_model=1536,
        n_layers=48,
        n_heads=24,
        n_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        n_codebooks=4,
        pattern=(LayerSpec(kind="attn", ffn="dense"),),
        rope_theta=10000.0,
        dtype="bfloat16",
        source="arXiv:2306.05284",
    )
