"""PushSum gossip on time-varying directed graphs (paper §3.4).

The communication graph P^(t) is column-stochastic; every round each client
sends (P_{k',k} θ_k, P_{k',k} w_k) to out-neighbours, sums what it receives,
and de-biases by θ/w (Kempe et al. 2003; Nedić et al. 2018). With the
exponential protocol of Assran et al. (2019) each client has exactly ONE
out-neighbour per round — 2^(t mod ⌈log2 K⌉) hops away — so per-round
communication is O(1) in the number of clients (the paper's Fig. 4 claim).

Two execution backends:

* **simulation** — stacked client parameters, one matmul Θ ← P Θ per round
  (runs anywhere, used by the paper-reproduction benchmarks);
* **distributed** — inside ``shard_map`` over a mesh axis holding one
  client per device/pod, the same exchange is a single
  ``jax.lax.ppermute`` (the TPU-native realization of the MPI send/recv).
"""
from __future__ import annotations

import math
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def exponential_offsets(n_clients: int) -> List[int]:
    """Peer offsets 2^0, 2^1, ..., 2^⌊log2(K-1)⌋ (Assran et al. 2019)."""
    if n_clients <= 1:
        return [0]
    return [2 ** p for p in range(int(math.floor(math.log2(n_clients - 1))) + 1)]


def gossip_shift(t: int, n_clients: int, topology: str = "exponential") -> int:
    if n_clients <= 1:
        return 0
    if topology == "exponential":
        offs = exponential_offsets(n_clients)
        return offs[t % len(offs)]
    if topology == "ring":
        return 1
    if topology == "full":
        return -1  # sentinel: dense averaging
    raise ValueError(topology)


def adjacency_matrix(t: int, n_clients: int, topology: str = "exponential",
                     self_weight: float = 0.5, active=None) -> np.ndarray:
    """Column-stochastic P^(t): column k holds the weights client k SENDS.

    ``active`` (bool mask, len K) drops clients out of the round (paper
    §3.4: the time-varying graph "can adapt to clients joining or dropping
    out"): inactive clients keep their own state (P_kk = 1) and neither
    send nor receive; the exponential/ring shift is applied on the ACTIVE
    subset so the graph stays connected. Column-stochasticity — and
    therefore PushSum's mass conservation and de-biased convergence to the
    average of the ACTIVE participants — is preserved.
    """
    K = n_clients
    if K == 1:
        return np.ones((1, 1))
    if active is None:
        active_idx = np.arange(K)
    else:
        active = np.asarray(active, bool)
        assert active.shape == (K,)
        active_idx = np.where(active)[0]
    A = len(active_idx)
    P = np.eye(K)  # inactive clients: identity column
    if A <= 1:
        return P
    shift = gossip_shift(t, A, topology)
    if shift == -1:  # dense uniform mixing among active
        for a_pos, k in enumerate(active_idx):
            P[k, k] = 0.0
            for b_pos, j in enumerate(active_idx):
                P[j, k] = 1.0 / A
    else:
        for a_pos, k in enumerate(active_idx):
            P[k, k] = self_weight
            peer = active_idx[(a_pos + shift) % A]
            P[peer, k] += 1.0 - self_weight
    assert np.allclose(P.sum(axis=0), 1.0)
    return P


# ---------------------------------------------------------------------------
# simulation backend: Θ^(t+1) = P^(t) Θ^(t)


def pushsum_mix(thetas: jnp.ndarray, weights: jnp.ndarray, P: jnp.ndarray,
                *, use_pallas: bool = False, interpret=None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """thetas: [K, D] stacked client vectors; weights: [K] de-bias weights.
    Returns mixed (thetas, weights) — NOT yet de-biased.

    ``use_pallas=True`` routes through the fused blocked kernel
    (:func:`repro.kernels.pushsum_mix.fused_pushsum_mix`, f32 accumulation,
    one HBM→VMEM pass per parameter chunk); allclose to the plain matmuls."""
    if use_pallas:
        from ..kernels.pushsum_mix import fused_pushsum_mix
        return fused_pushsum_mix(thetas, weights, P, debias=False,
                                 interpret=interpret)
    P = jnp.asarray(P, thetas.dtype)
    return P @ thetas, P.astype(weights.dtype) @ weights


def pushsum_mix_debiased(thetas: jnp.ndarray, weights: jnp.ndarray,
                         P: jnp.ndarray, *, use_pallas: bool = False,
                         interpret=None, compress=None, ef_state=None,
                         key=None):
    """The engine's whole stacked exchange (Algorithm 1 lines 7-11):
    ``z' = (P·z) / (P·w)[:, None]``, ``w' = P·w`` — mix AND de-bias.

    This is the single dispatch point the ``FederationEngine`` sync
    backends call: plain XLA (two matmuls + divide, the reference
    semantics) or the Pallas-fused kernel with the de-bias fused into the
    same pass (``use_pallas``, per ``ProxyFLConfig.use_pallas``).

    ``compress`` (a :class:`repro.core.compress.CompressionSpec`) routes
    the exchange through the compressed protocol instead: each sender
    transmits a compressed DELTA against its public copy ``ef_state``
    [K, D] (``key`` feeds int8's stochastic rounding), receivers mix the
    updated dense copies, and the call returns a THREE-tuple
    ``(z', w', ef_state')``. The Pallas kernels
    implement the uncompressed chain only, so the compressed branch always
    takes the plain-XLA path and ``use_pallas`` is ignored (documented in
    ``core.compress``). ``compress=None`` keeps this function — and its
    compiled program — byte-for-byte the uncompressed exchange."""
    if compress is not None:
        from .compress import compressed_pushsum_mix
        return compressed_pushsum_mix(thetas, weights, P, ef_state, key,
                                      compress)
    if use_pallas:
        from ..kernels.pushsum_mix import fused_pushsum_mix
        return fused_pushsum_mix(thetas, weights, P, debias=True,
                                 interpret=interpret)
    mixed = jnp.asarray(P, thetas.dtype) @ thetas
    w2 = jnp.asarray(P, weights.dtype) @ weights
    return mixed / w2[:, None], w2


def stale_mix_apply(flat: jnp.ndarray, w: jnp.ndarray, kept: jnp.ndarray,
                    sent: jnp.ndarray, buf_t0: jnp.ndarray,
                    buf_w0: jnp.ndarray, *, use_pallas: bool = False,
                    interpret=None, compress=None, ef_state=None, key=None):
    """One stale (async τ>0) exchange on the stacked proxies — the
    delayed-delivery counterpart of :func:`pushsum_mix_debiased` and the
    on-device application of :func:`stale_gossip_reference`'s round body:
    re-bias θ = z·w, emit ``send = sent @ θ``, merge ``kept·θ`` with the
    delivery ``buf_t0``/``buf_w0`` rotating out of the in-flight buffer,
    de-bias by the identically-delayed weights. Returns ``(z', send_t,
    w', send_w)``; the caller owns the buffer rotation. ``use_pallas``
    fuses the whole chain into one blocked pass per parameter chunk
    (:func:`repro.kernels.pushsum_mix.fused_stale_mix`).

    ``compress``/``ef_state``/``key`` route the in-flight transmission
    (public-copy delta coding on the numerator θ)
    through the codec with error feedback exactly as in
    :func:`pushsum_mix_debiased` — the return grows a trailing ``ef_state'``
    (five-tuple) and ``use_pallas`` is ignored (the fused kernel is
    uncompressed-only; see ``core.compress``)."""
    if compress is not None:
        from .compress import compressed_stale_mix
        return compressed_stale_mix(flat, w, kept, sent, buf_t0, buf_w0,
                                    ef_state, key, compress)
    if use_pallas:
        from ..kernels.pushsum_mix import fused_stale_mix
        return fused_stale_mix(flat, w, kept, sent, buf_t0, buf_w0,
                               interpret=interpret)
    theta = flat * w[:, None]                  # raw PushSum numerator
    send_t = sent.astype(flat.dtype) @ theta
    send_w = sent.astype(w.dtype) @ w
    mixed = kept.astype(flat.dtype)[:, None] * theta + buf_t0
    w2 = kept.astype(w.dtype) * w + buf_w0
    return mixed / w2[:, None], send_t, w2, send_w


def mix_matrix(mix: str, t: int, n_clients: int, topology: str = "exponential",
               active=None, self_weight: float = 0.5) -> np.ndarray:
    """Column-stochastic mixing matrix for ONE federated exchange.

    Every aggregation rule in the METHODS table is a K×K column-stochastic
    matrix applied to the stacked client vectors (plus PushSum de-biasing,
    which is the identity whenever the matrix keeps w at 1):

    * ``"pushsum"`` — the paper's §3.4 time-varying graph P^(t) (ProxyFL,
      AvgPush);
    * ``"mean"``    — uniform averaging among active clients (FedAvg, FML's
      central proxy server);
    * ``"ring"``    — cyclical weight transfer: a pure permutation, client k
      receives client k-1's model (CWT);
    * ``"none"``    — no exchange (Regular / Joint).

    ``active`` masks out dropped clients exactly as in
    :func:`adjacency_matrix`: they keep their own state (identity column)
    and neither send nor receive.
    """
    if mix == "none":
        return np.eye(n_clients)
    if mix == "pushsum":
        return adjacency_matrix(t, n_clients, topology, self_weight, active)
    if mix == "mean":
        return adjacency_matrix(t, n_clients, "full", self_weight, active)
    if mix == "ring":
        return adjacency_matrix(t, n_clients, "ring", 0.0, active)
    raise ValueError(mix)


def debias(thetas: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """θ_k / w_k (Algorithm 1 line 11)."""
    return thetas / weights[:, None]


# ---------------------------------------------------------------------------
# block schedules: P^(t0), ..., P^(t0+T-1) precomputed for a round-block


def shift_schedule(t0: int, T: int, n_active: int,
                   topology: str = "exponential") -> np.ndarray:
    """int[T] gossip shifts for rounds t0..t0+T-1 over ``n_active`` peers
    (-1 is the dense sentinel, matching :func:`gossip_shift`)."""
    ts = np.arange(t0, t0 + T)
    if n_active <= 1:
        return np.zeros(T, np.int64)
    if topology == "exponential":
        offs = np.asarray(exponential_offsets(n_active))
        return offs[ts % len(offs)]
    if topology == "ring":
        return np.ones(T, np.int64)
    if topology == "full":
        return -np.ones(T, np.int64)
    raise ValueError(topology)


def adjacency_schedule(t0: int, T: int, n_clients: int,
                       topology: str = "exponential",
                       self_weight: float = 0.5, active=None) -> np.ndarray:
    """Stacked column-stochastic P^(t0..t0+T-1): float64[T, K, K], with
    ``P[i] == adjacency_matrix(t0 + i, ...)`` exactly.

    ``active`` is None (everyone, every round) or bool[T, K] — one §3.4
    membership row per round. Construction is vectorized: rounds sharing a
    membership pattern are built together with batched scatters (no
    per-client Python loops), so a round-block's whole schedule costs a
    handful of numpy ops instead of T × K loop iterations.
    """
    K = n_clients
    P = np.broadcast_to(np.eye(K), (T, K, K)).copy()
    if K == 1 or T == 0:
        return P
    ts = np.arange(t0, t0 + T)
    if active is None:
        groups = [(np.arange(K), np.arange(T))]
    else:
        active = np.asarray(active, bool)
        assert active.shape == (T, K), (active.shape, (T, K))
        patterns, inverse = np.unique(active, axis=0, return_inverse=True)
        groups = [(np.where(patterns[g])[0], np.where(inverse == g)[0])
                  for g in range(len(patterns))]
    for idx, rows in groups:
        A = len(idx)
        if A <= 1:
            continue  # inactive-heavy round: identity (already in place)
        if topology == "exponential":
            offs = np.asarray(exponential_offsets(A))
            shifts = offs[ts[rows] % len(offs)]
        elif topology == "ring":
            shifts = np.ones(len(rows), np.int64)
        elif topology == "full":
            shifts = -np.ones(len(rows), np.int64)
        else:
            raise ValueError(topology)
        dense = shifts == -1
        if dense.any():
            P[np.ix_(rows[dense], idx, idx)] = 1.0 / A
        sparse = np.where(~dense)[0]
        if len(sparse):
            r = np.repeat(rows[sparse], A)
            col = np.tile(idx, len(sparse))
            P[r, col, col] = self_weight
            pos = np.arange(A)
            peers = idx[(pos[None, :] + shifts[sparse, None]) % A]
            np.add.at(P, (r, peers.reshape(-1), col), 1.0 - self_weight)
    assert np.allclose(P.sum(axis=1), 1.0)  # column-stochastic, every round
    return P


def mix_schedule(mix: str, t0: int, T: int, n_clients: int,
                 topology: str = "exponential", active=None,
                 self_weight: float = 0.5) -> np.ndarray:
    """Stacked mixing matrices for one round-block: float64[T, K, K] with
    ``out[i] == mix_matrix(mix, t0 + i, ...)`` exactly (same mix -> graph
    mapping as :func:`mix_matrix`; ``active`` is None or bool[T, K]).

    This is the host-side half of the engine's fused round-block execution:
    instead of re-entering Python every round to build P^(t), a block's
    whole schedule is precomputed once and fed to the compiled scan as one
    [T, K, K] runtime argument."""
    if mix == "none":
        return np.broadcast_to(np.eye(n_clients), (T, n_clients, n_clients)).copy()
    if mix == "pushsum":
        return adjacency_schedule(t0, T, n_clients, topology, self_weight,
                                  active)
    if mix == "mean":
        return adjacency_schedule(t0, T, n_clients, "full", self_weight,
                                  active)
    if mix == "ring":
        return adjacency_schedule(t0, T, n_clients, "ring", 0.0, active)
    raise ValueError(mix)


# ---------------------------------------------------------------------------
# stale gossip: the async backend's diag/off-diag split of P^(t)
#
# The synchronous exchange applies the whole column-stochastic P^(t) at
# once. The staleness-τ variant (Assran et al. 2019's overlap trick) splits
# every column into the mass a client KEEPS (the diagonal) and the mass it
# SENDS (the off-diagonal rest): sends computed at round t stay in flight —
# communication overlapped with the next local scans — and are delivered at
# round t+τ. Crucially the split operates on the RAW PushSum numerators
# θ = z·w (not the de-biased z): the de-bias weights w then account for the
# in-flight mass exactly, so θ/w stays a proper weighted average of client
# parameters at every staleness, and total θ- and w-mass (clients + buffer)
# is conserved round by round (column-stochasticity is preserved by the
# split: kept_k + Σ_j sent_{jk} = Σ_j P_{jk} = 1).


def stale_mix_split(P):
    """Diag/off-diag split of column-stochastic matrices (batched over any
    leading dims): returns ``(kept[..., K], sent[..., K, K])`` with
    ``P == sent + diag_embed(kept)`` exactly — ``kept[k]`` is the mass
    client k retains this round, column ``sent[:, k]`` the mass it puts in
    flight."""
    P = np.asarray(P)
    K = P.shape[-1]
    idx = np.arange(K)
    kept = P[..., idx, idx].copy()
    sent = P.copy()
    sent[..., idx, idx] = 0.0
    return kept, sent


def stale_mix_schedule(mix: str, t0: int, T: int, n_clients: int,
                       topology: str = "exponential", active=None,
                       self_weight: float = 0.5):
    """Stacked stale-mix split for one round-block: ``(kept[T, K],
    sent[T, K, K])`` with ``sent[i] + diag(kept[i]) == mix_matrix(mix,
    t0 + i, ...)`` exactly (same mix -> graph mapping, ``active`` is None
    or bool[T, K]). The host-side half of the async backend's fused
    round-block execution."""
    return stale_mix_split(mix_schedule(mix, t0, T, n_clients, topology,
                                        active=active,
                                        self_weight=self_weight))


def stale_gossip_reference(z0, w0, Ps, staleness: int):
    """Numpy reference of the staleness-τ PushSum exchange — the executable
    spec the async engine backend and its property tests are held to.

    ``z0``: [K, D] de-biased client vectors; ``w0``: [K] de-bias weights;
    ``Ps``: iterable of [K, K] column-stochastic matrices (one per round,
    §3.4 active masking already applied). Per round t:

    1. re-bias:  θ(t) = z(t) · w(t)  (raw PushSum numerators);
    2. send:     ``sent(t) @ θ(t)`` and ``sent(t) @ w(t)`` enter a τ-deep
       in-flight buffer (delivered at round t+τ; the buffer starts empty —
       for the first τ rounds nothing arrives and the de-bias weights
       shrink to account for the mass in flight);
    3. deliver:  the round-(t−τ) sends leave the buffer and merge into
       ``mixed = kept(t)·θ(t) + recv`` and ``w' = kept(t)·w(t) + recv_w``;
    4. de-bias:  z(t+1) = mixed / w'.

    τ=0 degenerates to the synchronous exchange ``P @ θ`` / ``P @ w``.
    Returns ``(z, w, buf_theta[τ, K, D], buf_w[τ, K])`` after ``len(Ps)``
    rounds; buffer row 0 is the next delivery. Invariants (property-tested
    in tests/test_gossip.py): Σ w + Σ buf_w == Σ w0 and
    Σ z·w + Σ buf_theta == Σ z0·w0 after every round, for ANY τ and any
    §3.4 dropout trajectory; a send entered at round t leaves the buffer
    at exactly round t+τ."""
    z = np.asarray(z0, np.float64)
    w = np.asarray(w0, np.float64)
    K, D = z.shape
    tau = int(staleness)
    buf_t = np.zeros((tau, K, D))
    buf_w = np.zeros((tau, K))
    for P in Ps:
        kept, sent = stale_mix_split(np.asarray(P, np.float64))
        theta = z * w[:, None]
        if tau == 0:
            mixed = (sent + np.diag(kept)) @ theta
            w = (sent + np.diag(kept)) @ w
        else:
            send_t, send_w = sent @ theta, sent @ w
            mixed = kept[:, None] * theta + buf_t[0]
            w = kept * w + buf_w[0]
            buf_t = np.concatenate([buf_t[1:], send_t[None]])
            buf_w = np.concatenate([buf_w[1:], send_w[None]])
        z = mixed / w[:, None]
    return z, w, buf_t, buf_w


# ---------------------------------------------------------------------------
# hierarchical gossip: the hier backend's two-level factoring of P^(t)
#
# A two-level cohort of S shards × L clients-per-shard executes the SAME
# flat column-stochastic schedule P^(t), factored by edge locality instead
# of applied as one dense [K, K] matmul: the entries whose sender and
# receiver share a shard form a block-diagonal [S, L, L] part (applied as S
# independent [L, L] × [L, D] matmuls — the on-device mix, O(K·L·D) instead
# of O(K²·D)), and the cross-shard entries form a sparse scaled permutation
# (each client sends to at most ONE peer per round under the exponential/
# ring protocols — exactly the structure a `ppermute` collective realizes
# on a device mesh, and exactly the per-client O(1) bytes-on-wire claim).
# The split is a SUM decomposition, P = blockdiag + cross, so rebuilding is
# exact (disjoint supports): the factored application moves the same mass
# as the flat matmul, and mass conservation / column-stochasticity are
# inherited from P. Staleness applies to the cross part only: delayed
# cross-shard deliveries ride the same τ-deep in-flight buffer algebra as
# :func:`stale_gossip_reference` while the intra-shard exchange stays
# synchronous (the "inter-pod latency absorbed by the async τ-buffer"
# deployment of ROADMAP's thousand-client item).


def hier_layout(n_clients: int, n_shards: int) -> Tuple[int, int]:
    """Validated two-level cohort layout ``(S, L)``: client k lives in
    shard ``k // L`` at local index ``k % L``. ``n_shards`` must divide the
    cohort evenly — ragged shard sizes would need per-shard block shapes
    and break the single batched intra-shard matmul."""
    S = 1 if n_shards is None else int(n_shards)
    if S < 1 or S > n_clients or n_clients % S:
        raise ValueError(
            f"n_shards={n_shards} must evenly divide n_clients="
            f"{n_clients} (two-level [shards × clients-per-shard] cohort)")
    return S, n_clients // S


def hier_mix_split(P, n_shards: int):
    """Factor one flat column-stochastic ``P`` [K, K] by edge locality.

    Returns ``(blocks[S, L, L], src[K], scale[K])``:

    * ``blocks[s]`` — P restricted to shard s's intra-shard edges
      (diagonal included);
    * ``src[i]`` / ``scale[i]`` — the one cross-shard in-edge of client i
      (``P[i, src[i]] == scale[i]``), or ``src[i] == i, scale[i] == 0``
      when none. Cross-shard deliveries are therefore a gather + scale —
      the simulation form of a ``ppermute`` + scale on a real mesh.

    The decomposition is EXACT (disjoint supports):
    ``blockdiag(blocks) + scatter(src, scale) == P`` bitwise — proven by
    tests/test_gossip.py against :func:`mix_schedule`. Raises when the
    cross-shard part is not a scaled partial permutation (≥2 cross
    in/out-edges per client — e.g. dense "full"/mean mixing), which is not
    hier-factorable: there is no O(1) collective schedule for it."""
    P = np.asarray(P)
    K = P.shape[-1]
    S, L = hier_layout(K, n_shards)
    shard = np.arange(K) // L
    intra = shard[:, None] == shard[None, :]
    cross = np.where(intra, 0.0, P)
    if (np.count_nonzero(cross, axis=1) > 1).any() or \
            (np.count_nonzero(cross, axis=0) > 1).any():
        raise ValueError(
            "hier factoring needs at most one cross-shard edge per client "
            "per round (a scaled partial permutation); dense mixing "
            "(topology='full' / mix='mean') is not hier-factorable")
    blocks = np.where(intra, P, 0.0).reshape(S, L, S, L)
    blocks = blocks[np.arange(S), :, np.arange(S), :]          # [S, L, L]
    src = np.argmax(cross != 0.0, axis=1)
    has = cross[np.arange(K), src] != 0.0
    src = np.where(has, src, np.arange(K))
    scale = np.where(has, cross[np.arange(K), src], 0.0)
    return blocks, src.astype(np.int64), scale


def hier_mix_schedule(mix: str, t0: int, T: int, n_clients: int,
                      n_shards: int, topology: str = "exponential",
                      active=None, self_weight: float = 0.5):
    """Stacked two-level factoring of one round-block's flat schedule:
    ``(blocks[T, S, L, L], src[T, K], scale[T, K])`` with each round's
    rebuilt ``blockdiag(blocks[i]) + scatter(src[i], scale[i])`` equal —
    bitwise — to ``mix_schedule(mix, t0, T, ...)[i]``. Same mix -> graph
    mapping and §3.4 ``active`` handling (None or bool[T, K]) as
    :func:`mix_schedule`; the host-side half of the hier backend's fused
    round-block execution."""
    Ps = mix_schedule(mix, t0, T, n_clients, topology, active=active,
                      self_weight=self_weight)
    parts = [hier_mix_split(Ps[i], n_shards) for i in range(T)]
    blocks = np.stack([p[0] for p in parts])
    src = np.stack([p[1] for p in parts])
    scale = np.stack([p[2] for p in parts])
    return blocks, src, scale


def _hier_intra(x, w, blocks, use_pallas, interpret):
    """Block-diagonal half of one factored exchange: S independent
    [L, L] × [L, D] shard-local matmuls over the stacked vectors (plus the
    matching w mix) — ``use_pallas`` routes each shard's matmul through the
    fused blocked kernel (the [L, L] block resident in VMEM, vmapped over
    the shard axis)."""
    S, L, _ = blocks.shape
    xs = x.reshape(S, L, -1)
    ws = w.reshape(S, L)
    if use_pallas:
        from ..kernels.pushsum_mix import fused_pushsum_mix
        mixed, wm = jax.vmap(lambda f, ww, p: fused_pushsum_mix(
            f, ww, p, debias=False, interpret=interpret))(xs, ws, blocks)
    else:
        Pb = jnp.asarray(blocks, x.dtype)
        mixed = jnp.einsum("sij,sjd->sid", Pb, xs)
        wm = jnp.einsum("sij,sj->si", Pb.astype(w.dtype), ws)
    return mixed.reshape(x.shape), wm.reshape(w.shape)


def hier_mix_debiased(flat, w, blocks, src, scale, *, use_pallas=False,
                      interpret=None):
    """One SYNCHRONOUS factored exchange on the stacked proxies — the
    two-level application of :func:`pushsum_mix_debiased`'s
    ``z' = (P·z) / (P·w)``: shard-local block matmuls plus the scaled
    cross-shard gather (the simulation form of a ``ppermute`` delivery).
    Because every client has at most one cross-shard in-edge and the
    rebuilt P is exact, the result is BITWISE equal to the flat dense
    exchange on the same P (each output row performs the same ≤2 real
    additions; zero terms add exactly) — enforced by
    tests/test_conformance.py's hier-τ0 == vmap columns."""
    mixed, wm = _hier_intra(flat, w, blocks, use_pallas, interpret)
    s = jnp.asarray(scale, flat.dtype)
    mixed = mixed + s[:, None] * flat[src]
    w2 = wm + s.astype(w.dtype) * w[src]
    return mixed / w2[:, None], w2


def hier_stale_mix_apply(flat, w, blocks, src, scale, buf_t0, buf_w0, *,
                         use_pallas=False, interpret=None):
    """One STALE (τ>0) factored exchange: the on-device application of
    :func:`hier_gossip_reference`'s round body. Re-bias θ = z·w, mix the
    intra-shard part synchronously, emit the cross-shard send
    ``scale·θ[src]`` (the caller pushes it into the τ-deep buffer and owns
    the rotation, exactly as with :func:`stale_mix_apply`), merge the
    round-(t−τ) delivery ``buf_t0``/``buf_w0``, and de-bias by the
    identically-delayed weights. Returns ``(z', send_t, w', send_w)``.
    Only cross-shard mass is ever stale — the intra-shard matmul reads the
    CURRENT θ."""
    theta = flat * w[:, None]                  # raw PushSum numerator
    mixed, wm = _hier_intra(theta, w, blocks, use_pallas, interpret)
    s = jnp.asarray(scale, flat.dtype)
    send_t = s[:, None] * theta[src]
    send_w = s.astype(w.dtype) * w[src]
    w2 = wm + buf_w0
    return (mixed + buf_t0) / w2[:, None], send_t, w2, send_w


def hier_gossip_reference(z0, w0, Ps, n_shards: int, staleness: int = 0):
    """Numpy reference of the two-level (hier) PushSum exchange — the
    executable spec the hier engine backend and its property tests are
    held to, mirroring :func:`stale_gossip_reference`. Per round t, with
    ``blocks/src/scale = hier_mix_split(P(t), n_shards)``:

    1. re-bias:   θ(t) = z(t) · w(t);
    2. intra mix: ``mixed = blockdiag(blocks) @ θ`` — S independent
       [L, L] × [L, D] shard-local matmuls, always synchronous;
    3. cross send: client i's one cross-shard in-edge delivers
       ``scale[i] · θ[src[i]]`` — immediately at τ=0, or through a τ-deep
       in-flight buffer at τ>0 (ONLY the cross-shard mass is ever stale);
    4. merge + de-bias: z(t+1) = (mixed + delivery) / (w-mixed + w-delivery).

    Invariants (tested in tests/test_gossip.py): Σ w + Σ buf_w == Σ w0 and
    Σ z·w + Σ buf == Σ z0·w0 after every round for any τ, n_shards and
    §3.4 dropout trajectory; at τ=0 the trajectory equals the flat
    synchronous :func:`stale_gossip_reference` (staleness 0) bit-for-bit —
    the factored application of P moves identical mass because every
    client has at most one cross-shard in-edge (a single extra addition
    against the shard-local partial row sum). Returns ``(z, w,
    buf_theta[τ, K, D], buf_w[τ, K])``; buffer row 0 is the next
    delivery."""
    z = np.asarray(z0, np.float64)
    w = np.asarray(w0, np.float64)
    K, D = z.shape
    S, L = hier_layout(K, n_shards)
    tau = int(staleness)
    buf_t = np.zeros((tau, K, D))
    buf_w = np.zeros((tau, K))
    for P in Ps:
        blocks, src, scale = hier_mix_split(np.asarray(P, np.float64),
                                            n_shards)
        theta = z * w[:, None]
        mixed = np.einsum("sij,sjd->sid", blocks,
                          theta.reshape(S, L, D)).reshape(K, D)
        wm = np.einsum("sij,sj->si", blocks, w.reshape(S, L)).reshape(K)
        send_t = scale[:, None] * theta[src]
        send_w = scale * w[src]
        if tau == 0:
            arrive_t, arrive_w = send_t, send_w
        else:
            arrive_t, arrive_w = buf_t[0], buf_w[0]
            buf_t = np.concatenate([buf_t[1:], send_t[None]])
            buf_w = np.concatenate([buf_w[1:], send_w[None]])
        w = wm + arrive_w
        z = (mixed + arrive_t) / w[:, None]
    return z, w, buf_t, buf_w


# ---------------------------------------------------------------------------
# distributed backend: one client per mesh-axis index, ppermute exchange


def shard_map_fn(f, mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` (jax>=0.5 exposes ``jax.shard_map``;
    0.4.x only has the experimental entry point with ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def pushsum_gossip_shard(theta_local: jnp.ndarray, w_local: jnp.ndarray,
                         t: int, axis: str, n_clients: int,
                         topology: str = "exponential",
                         self_weight: float = 0.5,
                         active=None):
    """Inside shard_map: one PushSum round along mesh axis ``axis``.

    Sends (1-self_weight)·(θ, w) to the peer ``shift`` ahead; keeps
    self_weight·(θ, w). Exactly Algorithm 1 lines 7-10 with P^(t) from
    :func:`adjacency_matrix`, realized as a collective-permute (cost
    independent of K — the O(1) communication claim).

    ``active`` (static bool sequence, len K) is the §3.4 dropout/join mask:
    inactive clients keep their state untouched, the permutation runs over
    the ACTIVE subset only (so the graph stays connected), and dense
    ("full") mixing becomes a masked psum over active participants. The
    mask is trace-time static — each distinct pattern is its own compiled
    collective schedule, matching how a real deployment would re-plan its
    communication graph on membership changes."""
    if active is None:
        active_idx = list(range(n_clients))
    else:
        assert len(active) == n_clients
        active_idx = [i for i in range(n_clients) if active[i]]
    A = len(active_idx)
    if A <= 1:
        return theta_local, w_local
    shift = gossip_shift(t, A, topology)
    if shift == 0:
        return theta_local, w_local
    amask = np.zeros((n_clients,), np.float32)
    amask[active_idx] = 1.0
    idx = jax.lax.axis_index(axis)
    m = jnp.asarray(amask)[idx].astype(theta_local.dtype)
    if shift == -1:  # dense averaging among active (AvgPush-full / FedAvg)
        sum_t = jax.lax.psum(m * theta_local, axis)
        sum_w = jax.lax.psum(m * w_local, axis)
        return (m * sum_t / A + (1.0 - m) * theta_local,
                m * sum_w / A + (1.0 - m) * w_local)
    perm = [(active_idx[p], active_idx[(p + shift) % A]) for p in range(A)]
    keep = 1.0 - m * (1.0 - self_weight)  # self_weight if active else 1
    send_t = (1.0 - self_weight) * theta_local
    send_w = (1.0 - self_weight) * w_local
    recv_t = jax.lax.ppermute(send_t, axis, perm)  # zeros at non-receivers
    recv_w = jax.lax.ppermute(send_w, axis, perm)
    return keep * theta_local + recv_t, keep * w_local + recv_w


# ---------------------------------------------------------------------------
# communication-cost model (paper Fig. 4 / Fig. 13)


def comm_cost_per_round(method: str, n_clients: int, model_bytes: int,
                        proxy_bytes: int, link_bandwidth: float = 50e9) -> float:
    """Analytic wall-clock communication time of ONE round (seconds).

    Centralized schemes serialize at the server: it receives K models and
    sends K back over one link (the bottleneck the paper measures).
    Decentralized schemes send/receive exactly one model per client in
    parallel. CWT passes one model around but rounds are serialized."""
    if method in ("fedavg",):
        return 2 * n_clients * model_bytes / link_bandwidth
    if method in ("fml",):
        return 2 * n_clients * proxy_bytes / link_bandwidth
    if method in ("avgpush", "cwt"):
        return 2 * model_bytes / link_bandwidth
    if method in ("proxyfl",):
        return 2 * proxy_bytes / link_bandwidth
    if method in ("regular", "joint"):
        return 0.0
    raise ValueError(method)
