"""ProxyFL — Algorithm 1 of the paper, plus the generic client machinery
shared with the baselines.

A *ModelSpec* abstracts any classifier (vision CNN, LLM, ...) as
``init(key) -> params`` / ``apply(params, x) -> logits``; ProxyFL only ever
touches models through this interface, which is what gives the protocol its
model-heterogeneity (paper challenge (i)).

Each client holds a private model (trained WITHOUT DP, Eq. 4) and a proxy
model (trained WITH DP-SGD, Eq. 5/7). Per round: ``local_steps`` joint DML
steps, then one PushSum gossip exchange of the proxies (§3.4).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ProxyFLConfig
from ..nn.losses import cross_entropy, dml_loss
from ..nn.modules import tree_flatten_vector, tree_unflatten_vector
from ..optim import Adam
from .accountant import PrivacyAccountant
from .dp import dp_adam_update, dp_gradient, non_dp_gradient
from .gossip import debias, pushsum_mix

Params = Any


@dataclass(frozen=True)
class ModelSpec:
    name: str
    init: Callable[[Any], Params]
    apply: Callable[[Params, jnp.ndarray], jnp.ndarray]


@dataclass
class ClientState:
    private_params: Params
    private_opt: Any
    proxy_params: Params
    proxy_opt: Any
    w: float = 1.0  # PushSum de-bias weight (Algorithm 1)
    accountant: Optional[PrivacyAccountant] = None


# ---------------------------------------------------------------------------
# step builders (cached per (spec, cfg) so federations reuse XLA code).
# ``*_step_fn`` returns the raw traceable function — the FederationEngine
# composes it under its own jit/vmap/scan; ``make_*_step`` wraps it in
# jax.jit for direct per-step callers.


@functools.lru_cache(maxsize=None)
def dml_step_fn(private_spec: ModelSpec, proxy_spec: ModelSpec,
                cfg: ProxyFLConfig):
    """One joint DML step (Algorithm 1 lines 3-5): private non-DP update of
    Eq. (4), proxy DP-SGD update of Eq. (5)/(7), both at round-start params."""
    opt = Adam(lr=cfg.lr, weight_decay=cfg.weight_decay)

    def private_loss(phi, batch, theta):
        x, y = batch
        peer = proxy_spec.apply(theta, x)
        return dml_loss(private_spec.apply(phi, x), peer, y, cfg.alpha)

    def proxy_loss(theta, batch, phi):
        x, y = batch
        peer = private_spec.apply(phi, x)
        return dml_loss(proxy_spec.apply(theta, x), peer, y, cfg.beta)

    def step(phi, opt_phi, theta, opt_theta, batch, key):
        # proxy first in code order, but both use round-start params
        if cfg.dp.enabled and cfg.use_pallas:
            # fused clip→noise→Adam hot path (repro.kernels); allclose to
            # the dp_gradient + opt.update chain below, never bit-exact
            theta2, opt_theta2, m_theta = dp_adam_update(
                lambda t, b: proxy_loss(t, b, phi), theta, opt_theta,
                batch, key, opt=opt, clip_norm=cfg.dp.clip_norm,
                noise_multiplier=cfg.dp.noise_multiplier)
        elif cfg.dp.enabled:
            g_theta, m_theta = dp_gradient(
                lambda t, b: proxy_loss(t, b, phi), theta, batch, key,
                clip_norm=cfg.dp.clip_norm,
                noise_multiplier=cfg.dp.noise_multiplier,
                vectorized=cfg.dp.vectorized)
            theta2, opt_theta2 = opt.update(g_theta, opt_theta, theta)
        else:
            g_theta, m_theta = non_dp_gradient(
                lambda t, b: proxy_loss(t, b, phi), theta, batch)
            theta2, opt_theta2 = opt.update(g_theta, opt_theta, theta)
        g_phi, m_phi = non_dp_gradient(
            lambda p, b: private_loss(p, b, theta), phi, batch)
        phi2, opt_phi2 = opt.update(g_phi, opt_phi, phi)
        return phi2, opt_phi2, theta2, opt_theta2, {
            "private_loss": m_phi["loss"], "proxy_loss": m_theta["loss"]}

    return step


@functools.lru_cache(maxsize=None)
def make_dml_step(private_spec: ModelSpec, proxy_spec: ModelSpec,
                  cfg: ProxyFLConfig):
    return jax.jit(dml_step_fn(private_spec, proxy_spec, cfg))


@functools.lru_cache(maxsize=None)
def ce_step_fn(spec: ModelSpec, cfg: ProxyFLConfig, dp: bool):
    """Plain CE step for single-model methods (FedAvg/AvgPush/CWT/...)."""
    opt = Adam(lr=cfg.lr, weight_decay=cfg.weight_decay)

    def loss(params, batch):
        x, y = batch
        return cross_entropy(spec.apply(params, x), y)

    def step(params, opt_state, batch, key):
        if dp and cfg.use_pallas:
            params2, opt_state2, m = dp_adam_update(
                loss, params, opt_state, batch, key, opt=opt,
                clip_norm=cfg.dp.clip_norm,
                noise_multiplier=cfg.dp.noise_multiplier)
        elif dp:
            g, m = dp_gradient(loss, params, batch, key,
                               clip_norm=cfg.dp.clip_norm,
                               noise_multiplier=cfg.dp.noise_multiplier,
                               vectorized=cfg.dp.vectorized)
            params2, opt_state2 = opt.update(g, opt_state, params)
        else:
            g, m = non_dp_gradient(loss, params, batch)
            params2, opt_state2 = opt.update(g, opt_state, params)
        return params2, opt_state2, m["loss"]

    return step


@functools.lru_cache(maxsize=None)
def make_ce_step(spec: ModelSpec, cfg: ProxyFLConfig, dp: bool):
    return jax.jit(ce_step_fn(spec, cfg, dp))


# ---------------------------------------------------------------------------
# gossip over heterogeneous client states (thin wrapper over the engine's
# mixing rule — see repro.core.engine for the on-device backends)


def gossip_proxies(clients: List[ClientState], t: int, cfg: ProxyFLConfig,
                   active=None) -> None:
    """Algorithm 1 lines 7-11 (in place). Proxies share one architecture, so
    they stack into Θ ∈ R^{K×d} and one matmul applies P^(t). ``active``
    drops clients out of the exchange (§3.4)."""
    from .gossip import mix_matrix

    K = len(clients)
    if K <= 1:
        return
    like = clients[0].proxy_params
    thetas = jnp.stack([tree_flatten_vector(c.proxy_params) for c in clients])
    ws = jnp.asarray([c.w for c in clients], thetas.dtype)
    P = mix_matrix("pushsum", t, K, cfg.topology, active)
    mixed_t, mixed_w = pushsum_mix(thetas, ws, P)
    unbiased = debias(mixed_t, mixed_w)
    for k, c in enumerate(clients):
        c.proxy_params = tree_unflatten_vector(unbiased[k], like)
        c.w = float(mixed_w[k])


# ---------------------------------------------------------------------------
# federation driver


def init_client(key, private_spec: ModelSpec, proxy_spec: ModelSpec,
                cfg: ProxyFLConfig, n_local: int) -> ClientState:
    kf, kh = jax.random.split(key)
    opt = Adam(lr=cfg.lr, weight_decay=cfg.weight_decay)
    phi = private_spec.init(kf)
    theta = proxy_spec.init(kh)
    acc = None
    if cfg.dp.enabled:
        q = cfg.dp.sample_rate or min(1.0, cfg.batch_size / max(n_local, 1))
        acc = PrivacyAccountant(cfg.dp.noise_multiplier, q, cfg.dp.delta)
    return ClientState(phi, opt.init(phi), theta, opt.init(theta), 1.0, acc)


def local_round(client: ClientState, spec_pair, data, key, cfg: ProxyFLConfig
                ) -> Dict[str, float]:
    """One client's local optimization for one round (Algorithm 1 lines 2-5)."""
    private_spec, proxy_spec = spec_pair
    x, y = data
    step = make_dml_step(private_spec, proxy_spec, cfg)
    n_steps = cfg.local_steps or max(1, x.shape[0] // cfg.batch_size)
    phi, opt_phi = client.private_params, client.private_opt
    theta, opt_theta = client.proxy_params, client.proxy_opt
    last = {}
    for s in range(n_steps):
        key, kb, kn = jax.random.split(key, 3)
        idx = jax.random.randint(kb, (cfg.batch_size,), 0, x.shape[0])
        batch = (x[idx], y[idx])
        phi, opt_phi, theta, opt_theta, last = step(
            phi, opt_phi, theta, opt_theta, batch, kn)
        if client.accountant is not None:
            client.accountant.step()
    client.private_params, client.private_opt = phi, opt_phi
    client.proxy_params, client.proxy_opt = theta, opt_theta
    return {k: float(v) for k, v in last.items()}


def proxyfl_round(clients, spec_pairs, datasets, t, key, cfg: ProxyFLConfig,
                  active=None):
    """One full ProxyFL round across all clients: local DML then gossip.

    Thin wrapper over :class:`repro.core.engine.FederationEngine` (loop
    backend — the one that supports heterogeneous private architectures);
    mutates the ClientState list in place like the historical driver."""
    from .engine import dml_engine

    engine = dml_engine(tuple(p for p, _ in spec_pairs), spec_pairs[0][1],
                        cfg, backend="loop")
    states = [
        {"private": {"params": c.private_params, "opt": c.private_opt},
         "proxy": {"params": c.proxy_params, "opt": c.proxy_opt},
         "w": jnp.asarray(c.w, jnp.float32)}
        for c in clients
    ]
    engine.attach_accountants([c.accountant for c in clients])
    states, metrics = engine.run_round(states, list(datasets), t, key,
                                       active=active)
    for c, s in zip(clients, states):
        c.private_params, c.private_opt = s["private"]["params"], s["private"]["opt"]
        c.proxy_params, c.proxy_opt = s["proxy"]["params"], s["proxy"]["opt"]
        c.w = float(s["w"])
    return [{m: float(v[k]) for m, v in metrics.items()}
            for k in range(len(clients))]


@functools.lru_cache(maxsize=None)
def _eval_apply(spec: ModelSpec):
    """Jitted ``spec.apply``, hoisted out of the evaluation batch loop (a
    fresh ``jax.jit`` per batch would re-hash params every call)."""
    return jax.jit(spec.apply)


def evaluate(spec: ModelSpec, params, x, y, batch: int = 512) -> float:
    apply = _eval_apply(spec)
    correct = 0
    for i in range(0, x.shape[0], batch):
        logits = apply(params, x[i : i + batch])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y[i : i + batch]))
    return correct / x.shape[0]


@functools.lru_cache(maxsize=None)
def _eval_apply_batched(spec: ModelSpec):
    """Jitted cohort-batched ``spec.apply``: params carry a leading client
    dim, the eval batch is shared — the whole cohort's correct-counts come
    back as ONE [K] array instead of K sequential device->host pulls."""
    def batched(stacked_params, x, y):
        logits = jax.vmap(spec.apply, in_axes=(0, None))(stacked_params, x)
        return jnp.sum(jnp.argmax(logits, -1) == y[None, :], axis=1)

    return jax.jit(batched)


def evaluate_batched(spec: ModelSpec, stacked_params, x, y,
                     batch: int = 512) -> List[float]:
    """Test accuracy of every client at once (stacked [K, ...] params,
    shared test set). Per eval batch the correct-counts accumulate ON
    DEVICE; the single [K] host pull happens once at the end — the
    round-block counterpart of :func:`evaluate` (which pulls a float per
    client per batch)."""
    apply = _eval_apply_batched(spec)
    correct = None
    for i in range(0, x.shape[0], batch):
        c = apply(stacked_params, x[i : i + batch], y[i : i + batch])
        correct = c if correct is None else correct + c
    counts = np.asarray(correct)
    return [float(c) / x.shape[0] for c in counts]
