"""All comparison methods from the paper (§4.1 Baselines), sharing the
client machinery in ``protocol.py``:

* **FedAvg** (McMahan et al. 2017)    — centralized mean of client models.
* **FML** (Shen et al. 2020)          — private+proxy DML, proxies averaged
                                        at a central server.
* **AvgPush**                         — decentralized FedAvg: PushSum
                                        aggregation of the single model.
* **CWT** (Chang et al. 2018)         — cyclical weight transfer around the
                                        ring (models hop one client/round).
* **Regular**                         — local training only.
* **Joint**                           — pooled-data upper bound.

Per the paper: Regular, Joint, FedAvg, AvgPush and CWT train their (single)
models with DP-SGD; ProxyFL and FML apply DP-SGD to proxies only, which is
why their private models retain much higher utility.

``run_federated`` is the single driver used by every per-figure benchmark;
it returns a per-round history of each client's test accuracy.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ProxyFLConfig
from ..nn.losses import macro_accuracy
from ..nn.modules import tree_flatten_vector, tree_unflatten_vector
from ..optim import Adam
from .accountant import PrivacyAccountant
from .gossip import adjacency_matrix, comm_cost_per_round, debias, pushsum_mix
from .protocol import (
    ClientState,
    ModelSpec,
    evaluate,
    gossip_proxies,
    init_client,
    local_round,
    make_ce_step,
)

METHODS = ("proxyfl", "fml", "fedavg", "avgpush", "cwt", "regular", "joint")


@dataclass
class SingleModelClient:
    params: object
    opt: object
    accountant: Optional[PrivacyAccountant] = None


def _ce_local_round(client: SingleModelClient, spec: ModelSpec, data, key,
                    cfg: ProxyFLConfig, dp: bool) -> float:
    x, y = data
    step = make_ce_step(spec, cfg, dp)
    n_steps = cfg.local_steps or max(1, x.shape[0] // cfg.batch_size)
    loss = 0.0
    for s in range(n_steps):
        key, kb, kn = jax.random.split(key, 3)
        idx = jax.random.randint(kb, (cfg.batch_size,), 0, x.shape[0])
        client.params, client.opt, loss = step(client.params, client.opt,
                                               (x[idx], y[idx]), kn)
        if client.accountant is not None:
            client.accountant.step()
    return float(loss)


def _mean_params(params_list):
    stacked = jnp.stack([tree_flatten_vector(p) for p in params_list])
    mean = jnp.mean(stacked, axis=0)
    return [tree_unflatten_vector(mean, params_list[0]) for _ in params_list]


def _pushsum_params(params_list, ws, t, cfg):
    stacked = jnp.stack([tree_flatten_vector(p) for p in params_list])
    P = adjacency_matrix(t, len(params_list), cfg.topology)
    mixed, w2 = pushsum_mix(stacked, jnp.asarray(ws, stacked.dtype), P)
    unb = debias(mixed, w2)
    return ([tree_unflatten_vector(unb[k], params_list[0]) for k in range(len(params_list))],
            [float(v) for v in w2])


def run_federated(
    method: str,
    private_specs: Sequence[ModelSpec],
    proxy_spec: ModelSpec,
    client_data: Sequence[Tuple[jnp.ndarray, jnp.ndarray]],
    test_data: Tuple[jnp.ndarray, jnp.ndarray],
    cfg: ProxyFLConfig,
    *,
    seed: int = 0,
    eval_every: int = 1,
    n_classes: Optional[int] = None,
    eval_proxy: bool = False,
) -> Dict:
    """Run ``cfg.rounds`` rounds of ``method``; return history + final state.

    For FedAvg/AvgPush/CWT/Regular the client model is ``proxy_spec`` (all
    must share one architecture — the constraint ProxyFL removes). Joint
    pools all client data into one model.
    """
    assert method in METHODS, method
    K = len(client_data)
    key = jax.random.PRNGKey(seed)
    xt, yt = test_data
    history: List[Dict] = []

    if method in ("proxyfl", "fml"):
        clients = [
            init_client(jax.random.fold_in(key, k), private_specs[k], proxy_spec,
                        cfg, client_data[k][0].shape[0])
            for k in range(K)
        ]
        pairs = [(private_specs[k], proxy_spec) for k in range(K)]
        for t in range(cfg.rounds):
            rk = jax.random.fold_in(key, 10_000 + t)
            for k in range(K):
                local_round(clients[k], pairs[k], client_data[k],
                            jax.random.fold_in(rk, k), cfg)
            if method == "proxyfl":
                gossip_proxies(clients, t, cfg)
            else:  # FML: centralized proxy averaging
                mean = _mean_params([c.proxy_params for c in clients])
                for c, m in zip(clients, mean):
                    c.proxy_params = m
            if (t + 1) % eval_every == 0 or t == cfg.rounds - 1:
                row = {"round": t + 1,
                       "private_acc": [evaluate(private_specs[k], clients[k].private_params, xt, yt) for k in range(K)],
                       "proxy_acc": [evaluate(proxy_spec, clients[k].proxy_params, xt, yt) for k in range(K)]}
                history.append(row)
        eps = [c.accountant.epsilon() if c.accountant else None for c in clients]
        return {"history": history, "epsilon": eps, "clients": clients}

    # ----- single-model methods -----
    opt = Adam(lr=cfg.lr, weight_decay=cfg.weight_decay)
    dp = cfg.dp.enabled

    if method == "joint":
        x = jnp.concatenate([d[0] for d in client_data])
        y = jnp.concatenate([d[1] for d in client_data])
        params = proxy_spec.init(key)
        acc = PrivacyAccountant(cfg.dp.noise_multiplier,
                                min(1.0, cfg.batch_size / x.shape[0]),
                                cfg.dp.delta) if dp else None
        client = SingleModelClient(params, opt.init(params), acc)
        import dataclasses as _dc
        jcfg = _dc.replace(cfg, local_steps=cfg.local_steps * K) if cfg.local_steps else cfg
        for t in range(cfg.rounds):
            _ce_local_round(client, proxy_spec, (x, y),
                            jax.random.fold_in(key, 10_000 + t), jcfg, dp)
            if (t + 1) % eval_every == 0 or t == cfg.rounds - 1:
                history.append({"round": t + 1,
                                "acc": [evaluate(proxy_spec, client.params, xt, yt)]})
        return {"history": history,
                "epsilon": [client.accountant.epsilon() if client.accountant else None],
                "clients": [client]}

    clients = []
    for k in range(K):
        p = proxy_spec.init(jax.random.fold_in(key, k))
        acc = PrivacyAccountant(cfg.dp.noise_multiplier,
                                min(1.0, cfg.batch_size / client_data[k][0].shape[0]),
                                cfg.dp.delta) if dp else None
        clients.append(SingleModelClient(p, opt.init(p), acc))
    ws = [1.0] * K

    for t in range(cfg.rounds):
        rk = jax.random.fold_in(key, 10_000 + t)
        for k in range(K):
            _ce_local_round(clients[k], proxy_spec, client_data[k],
                            jax.random.fold_in(rk, k), cfg, dp)
        if method == "fedavg":
            mean = _mean_params([c.params for c in clients])
            for c, m in zip(clients, mean):
                c.params = m
        elif method == "avgpush":
            mixed, ws = _pushsum_params([c.params for c in clients], ws, t, cfg)
            for c, m in zip(clients, mixed):
                c.params = m
        elif method == "cwt":  # ring hop
            last = clients[-1].params
            for k in range(K - 1, 0, -1):
                clients[k].params = clients[k - 1].params
            clients[0].params = last
        # regular: no exchange
        if (t + 1) % eval_every == 0 or t == cfg.rounds - 1:
            history.append({"round": t + 1,
                            "acc": [evaluate(proxy_spec, c.params, xt, yt) for c in clients]})

    eps = [c.accountant.epsilon() if c.accountant else None for c in clients]
    return {"history": history, "epsilon": eps, "clients": clients}


def final_mean_acc(result: Dict, which: str = "auto") -> float:
    row = result["history"][-1]
    if which == "auto":
        which = "private_acc" if "private_acc" in row else "acc"
    return float(np.mean(row[which]))
