"""All comparison methods from the paper (§4.1 Baselines), executed by the
shared :class:`repro.core.engine.FederationEngine`:

* **FedAvg** (McMahan et al. 2017)    — centralized mean of client models
                                        (engine mix="mean").
* **FML** (Shen et al. 2020)          — private+proxy DML, proxies averaged
                                        at a central server (mix="mean").
* **AvgPush**                         — decentralized FedAvg: PushSum
                                        aggregation of the single model
                                        (mix="pushsum").
* **CWT** (Chang et al. 2018)         — cyclical weight transfer around the
                                        ring (mix="ring").
* **Regular**                         — local training only (mix="none").
* **Joint**                           — pooled-data upper bound.

Per the paper: Regular, Joint, FedAvg, AvgPush and CWT train their (single)
models with DP-SGD; ProxyFL and FML apply DP-SGD to proxies only, which is
why their private models retain much higher utility.

``run_federated`` is the single driver used by every per-figure benchmark;
it returns a per-round history of each client's test accuracy. Rounds are
executed in engine-owned ROUND-BLOCKS (``_drive_blocks``: up to
``rounds_per_block`` rounds fused into one compiled program, host re-
entered only at block edges, eval/checkpoint cadences cut to block edges
— bit-identical to per-round execution at any block size). The engine
``backend`` ("loop" | "vmap" | "shard_map" | "async" | "hier") is
selectable per call or via ``ProxyFLConfig.backend``; "auto" compiles the
whole round into one XLA program (vmap) whenever the cohort is homogeneous
— ragged (size-skewed, e.g. Dirichlet-partitioned) datasets included, via
padding + masked sampling — and falls back to the per-client loop only for
heterogeneous architectures or genuinely incompatible data trees.
``backend="async"`` swaps the synchronous exchange for staleness-τ gossip
(``ProxyFLConfig.staleness``; τ=0 is bit-identical to vmap, τ>0 delivers
neighbor proxies τ rounds late — see the async section of
``repro.core.engine``). ``backend="hier"`` runs the two-level
[``ProxyFLConfig.n_shards`` × clients-per-shard] factored exchange (same
flat P^(t), executed block-diagonally; τ delays cross-shard edges only —
see the hier section of ``repro.core.engine``).
``ProxyFLConfig.dropout_rate`` makes clients drop in/out per round (§3.4)
on every backend.
"""
from __future__ import annotations

import dataclasses
import os
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.federation import FederationCheckpointer, config_fingerprint
from ..configs.base import ProxyFLConfig
from ..data.ragged import pad_compatible
from .accountant import PrivacyAccountant
from .engine import block_spans, dml_engine, single_model_engine
from .protocol import (ClientState, ModelSpec, evaluate, evaluate_batched)

METHODS = ("proxyfl", "fml", "fedavg", "avgpush", "cwt", "regular", "joint")

# engine exchange rule per single-model method
_SINGLE_MIX = {"fedavg": "mean", "avgpush": "pushsum", "cwt": "ring",
               "regular": "none", "joint": "none"}


@dataclass
class SingleModelClient:
    params: object
    opt: object
    accountant: Optional[PrivacyAccountant] = None


def _resolve_backend(backend, cfg: ProxyFLConfig, client_data) -> str:
    """Honest ``auto``: ragged (size-skewed) cohorts stay on the compiled
    stacked path — the engine pads and mask-samples them — and only
    *genuinely incompatible* per-client trees (different structure, dtypes
    or trailing dims) fall back to the Python loop. ``async`` (stale
    gossip, ``cfg.staleness``) is explicit opt-in — ``auto`` never changes
    the protocol's delivery semantics — and, being a stacked backend, has
    no loop fallback: incompatible trees are an error, not a silent
    switch to synchronous execution."""
    backend = backend or cfg.backend or "auto"
    if backend == "auto" and not pad_compatible(client_data):
        return "loop"
    if backend in ("async", "hier") and not pad_compatible(client_data):
        raise ValueError(
            f"backend='{backend}' runs on the stacked path and needs "
            "identical or pad-compatible per-client data trees; genuinely "
            "incompatible trees have no "
            f"{'two-level' if backend == 'hier' else 'stale-gossip'} "
            "execution (backend='loop' would silently change the exchange "
            "semantics)")
    return backend


def _accountants(cfg: ProxyFLConfig, sizes: Sequence[int]
                 ) -> List[Optional[PrivacyAccountant]]:
    if not cfg.dp.enabled:
        return [None] * len(sizes)
    return [PrivacyAccountant(
        cfg.dp.noise_multiplier,
        cfg.dp.sample_rate or min(1.0, cfg.batch_size / max(n, 1)),
        cfg.dp.delta) for n in sizes]


def _checkpointer(checkpoint_dir, checkpoint_every, method: str,
                  cfg: ProxyFLConfig, seed: int,
                  private_specs: Sequence[ModelSpec], proxy_spec: ModelSpec,
                  K: int) -> Optional[FederationCheckpointer]:
    """Per-(method, seed) checkpoint directory under ``checkpoint_dir``,
    fingerprinted (config + model identities) so a resume under a different
    configuration or architecture refuses."""
    if not checkpoint_dir:
        return None
    fp = config_fingerprint(cfg, method=method, seed=seed, n_clients=K,
                            private=[s.name for s in private_specs[:K]],
                            proxy=proxy_spec.name)
    return FederationCheckpointer(
        os.path.join(checkpoint_dir, f"{method}_s{seed}"),
        every=checkpoint_every or 1, fingerprint=fp,
        verify=cfg.verify_commitments)


def _eval_clients(engine, state, specs, role: str, xt, yt) -> List[float]:
    """Test accuracy of every client's ``role`` model. Homogeneous cohorts
    evaluate BATCHED — stacked params, one jitted vmapped apply, a single
    [K] device->host pull — instead of K sequential per-client loops;
    heterogeneous architectures fall back per client."""
    specs = (list(specs) if isinstance(specs, (list, tuple))
             else [specs] * engine.K)
    if all(s == specs[0] for s in specs):
        stacked = engine.stacked_params(state, role)
        if stacked is not None:
            return evaluate_batched(specs[0], stacked, xt, yt)
    return [evaluate(specs[k], engine.client_params(state, k, role), xt, yt)
            for k in range(engine.K)]


def _eval_row(engine, state, round_no: int, roles, xt, yt) -> Dict:
    """One history row: ``roles`` is a list of (history key, spec(s),
    engine role) triples — the single shape behind the previous four
    copy-pasted eval/history blocks."""
    row: Dict = {"round": round_no}
    for hist_key, specs, role in roles:
        row[hist_key] = _eval_clients(engine, state, specs, role, xt, yt)
    return row


def _drive_blocks(engine, state, data, start: int, rounds: int, base_key,
                  ckpt, eval_every: int, rounds_per_block: int, eval_cb):
    """ONE driver loop for every method: execute ``rounds - start`` rounds
    in engine-owned round-blocks of (at most) ``rounds_per_block`` rounds,
    re-entering the host only at block edges.

    Blocks are cut (``engine.block_spans``) so that every checkpoint-
    cadence round and every eval-cadence round lands ON a block edge — the
    snapshot set and the history rows are exactly those of the historical
    per-round loop, and a killed run resumes from a block edge
    bit-identically. ``rounds_per_block=1`` IS the per-round loop
    (run_rounds degenerates to run_round per round)."""
    for t, n in block_spans(start, rounds, rounds_per_block,
                            ckpt.every if ckpt is not None else 0,
                            eval_every):
        state, _ = engine.run_rounds(state, data, t, n, base_key)
        done = t + n
        if ckpt is not None:
            ckpt.maybe_save(engine, state, done - 1, base_key=base_key)
        if (eval_every > 0 and done % eval_every == 0) or done == rounds:
            eval_cb(state, done)
    return state


def run_federated(
    method: str,
    private_specs: Sequence[ModelSpec],
    proxy_spec: ModelSpec,
    client_data: Sequence[Tuple[jnp.ndarray, jnp.ndarray]],
    test_data: Tuple[jnp.ndarray, jnp.ndarray],
    cfg: ProxyFLConfig,
    *,
    seed: int = 0,
    eval_every: int = 1,
    n_classes: Optional[int] = None,
    eval_proxy: bool = False,
    backend: Optional[str] = None,
    rounds_per_block: int = 1,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 0,
    resume: bool = False,
    use_pallas: Optional[bool] = None,
    compress: Optional[str] = None,
    compress_ratio: Optional[float] = None,
    n_shards: Optional[int] = None,
    verify_commitments: Optional[bool] = None,
    transmit_tamper=None,
) -> Dict:
    """Run ``cfg.rounds`` rounds of ``method``; return history + final state.

    For FedAvg/AvgPush/CWT/Regular the client model is ``proxy_spec`` (all
    must share one architecture — the constraint ProxyFL removes). Joint
    pools all client data into one model.

    ``rounds_per_block`` fuses that many consecutive rounds into one
    compiled engine round-block (vmap/shard_map backends; the loop backend
    keeps per-round execution). Any value replays the identical trajectory
    bit-for-bit — blocks only remove per-round host synchronization —
    and eval/checkpoint cadences still land on block edges; ``1`` (the
    default) is exactly the historical per-round loop.

    ``checkpoint_dir`` snapshots complete federation state (client states,
    de-bias weights, round counter, accountant steps) every
    ``checkpoint_every`` rounds under ``<dir>/<method>_s<seed>``;
    ``resume=True`` restarts from the newest snapshot and replays the
    remaining rounds bit-identically to an uninterrupted run (``history``
    then only covers the resumed rounds).

    ``use_pallas`` overrides ``cfg.use_pallas`` (None keeps the config):
    the Pallas-fused round hot path — fused PushSum exchange + fused DP
    clip→noise→step; allclose to the plain-XLA reference, see
    ``repro.core.engine`` ("Fused hot path").

    ``compress``/``compress_ratio`` override ``cfg.compress``/
    ``cfg.compress_ratio`` (None keeps the config): the compressed proxy
    exchange with error feedback — ``"none"`` | ``"topk"`` | ``"int8"``,
    see ``repro.core.compress`` and the "Compressed proxy exchange"
    section of ``repro.core.engine``. Applies to whatever the method
    gossips (proxies for ProxyFL/FML, the full model for FedAvg/AvgPush/
    CWT); no-exchange methods (Regular/Joint) ignore it.

    ``n_shards`` overrides ``cfg.n_shards`` (None keeps the config): the
    two-level cohort layout of ``backend="hier"`` — the shard count of
    the [n_shards × clients-per-shard] factored exchange; the other
    backends ignore it.

    ``verify_commitments`` overrides ``cfg.verify_commitments`` (None
    keeps the config): verifiable federation (``repro.core.commit``) —
    received proxies are checked against their senders' declared
    commitments before mixing (loop backend) and checkpoint restores run
    in strict commitment mode. ``transmit_tamper`` injects a byzantine
    wire adversary (``(flat [K, D] numpy, t) -> flat``, e.g.
    ``repro.core.attacks.bitflip_proxy``) into the loop backend's
    exchange — the hook the tamper-detection tests drive.
    """
    assert method in METHODS, method
    if use_pallas is not None:
        cfg = dataclasses.replace(cfg, use_pallas=use_pallas)
    if verify_commitments is not None:
        cfg = dataclasses.replace(cfg,
                                  verify_commitments=bool(verify_commitments))
    if compress is not None:
        cfg = dataclasses.replace(cfg, compress=compress)
    if compress_ratio is not None:
        cfg = dataclasses.replace(cfg, compress_ratio=float(compress_ratio))
    if n_shards is not None:
        cfg = dataclasses.replace(cfg, n_shards=int(n_shards))
    K = len(client_data)
    key = jax.random.PRNGKey(seed)
    xt, yt = test_data
    history: List[Dict] = []
    backend = _resolve_backend(backend, cfg, client_data)
    ckpt = _checkpointer(checkpoint_dir, checkpoint_every, method, cfg,
                         seed, private_specs, proxy_spec, K)

    if method in ("proxyfl", "fml"):
        mix = "pushsum" if method == "proxyfl" else "mean"
        engine = dml_engine(tuple(private_specs[:K]), proxy_spec, cfg,
                            backend=backend, mix=mix)
        accs = _accountants(cfg, [d[0].shape[0] for d in client_data])
        engine.attach_accountants(accs)
        state = engine.init_states(key)
        start = 0
        if ckpt is not None and resume:
            restored = ckpt.restore_latest(engine, like=state, base_key=key)
            if restored is not None:
                state, start = restored
        roles = [("private_acc", list(private_specs[:K]), "private"),
                 ("proxy_acc", proxy_spec, "proxy")]
        rounds_done = cfg.rounds
    else:
        # ----- single-model methods -----
        dp = cfg.dp.enabled
        if method == "joint":
            x = jnp.concatenate([d[0] for d in client_data])
            y = jnp.concatenate([d[1] for d in client_data])
            jcfg = (dataclasses.replace(cfg, local_steps=cfg.local_steps * K)
                    if cfg.local_steps else cfg)
            client_data = [(x, y)]
            engine_cfg = jcfg
        else:
            engine_cfg = cfg
        engine = single_model_engine(proxy_spec, engine_cfg, dp,
                                     mix=_SINGLE_MIX[method], backend=backend,
                                     n_clients=len(client_data))
        accs = _accountants(engine_cfg, [d[0].shape[0] for d in client_data])
        engine.attach_accountants(accs)
        state = engine.init_states(key)
        start = 0
        if ckpt is not None and resume:
            restored = ckpt.restore_latest(engine, like=state, base_key=key)
            if restored is not None:
                state, start = restored
        roles = [("acc", proxy_spec, "proxy")]
        rounds_done = engine_cfg.rounds

    # engines are LRU-cached by config and the hook is not part of the
    # cache key — assign unconditionally so a previous run's adversary
    # cannot leak into this run's (clean) exchange
    engine.transmit_tamper = transmit_tamper
    state = _drive_blocks(
        engine, state, list(client_data), start, rounds_done, key, ckpt,
        eval_every, rounds_per_block,
        lambda st, t_done: history.append(
            _eval_row(engine, st, t_done, roles, xt, yt)))
    if not history:
        # resume landed at (or past) the configured horizon: no rounds
        # ran, but callers still expect a final evaluation row
        history.append(_eval_row(engine, state, start, roles, xt, yt))

    eps = [a.epsilon() if a else None for a in accs]
    if method in ("proxyfl", "fml"):
        clients: List = [
            ClientState(s["private"]["params"], s["private"]["opt"],
                        s["proxy"]["params"], s["proxy"]["opt"],
                        float(s["w"]), accs[k])
            for k, s in enumerate(engine.export_states(state))]
    else:
        clients = [SingleModelClient(s["proxy"]["params"], s["proxy"]["opt"],
                                     accs[k])
                   for k, s in enumerate(engine.export_states(state))]
    return {"history": history, "epsilon": eps, "clients": clients}


def final_mean_acc(result: Dict, which: str = "auto") -> float:
    row = result["history"][-1]
    if which == "auto":
        which = "private_acc" if "private_acc" in row else "acc"
    return float(np.mean(row[which]))
