"""FederationEngine — ONE executor for every federated round in the repo.

The paper's round structure (Algorithm 1: ``local_steps`` local updates per
client, then one exchange over a column-stochastic graph) is shared by every
method in the METHODS table — ProxyFL, FML, FedAvg, AvgPush, CWT, Regular —
and by the LLM-scale driver in ``launch/train.py``. This module owns that
round once, behind three selectable backends:

``loop``
    One Python iteration per client per step, each client's step jitted
    individually. The only backend that supports *heterogeneous private
    architectures* (paper Fig. 5b — every client may bring a different
    model; tree structures differ, so clients cannot be stacked). Gossip
    stacks the (shared-architecture) proxies host-side and applies P^(t)
    as one matmul — the original simulation semantics.

``vmap`` (default for homogeneous cohorts)
    Client states are stacked into one pytree with a leading K dim; the
    whole round is ONE compiled XLA program: ``jax.lax.scan`` fuses the
    ``local_steps`` loop, ``jax.vmap`` batches the K clients, and the
    PushSum exchange runs on-device as a [K,K]×[K,D] matmul on the stacked
    flattened proxies — no per-round ``tree_flatten_vector`` host
    round-trips and no O(K·steps) Python dispatch. P^(t), the active
    mask, per-client valid lengths and per-client step counts are runtime
    *arguments*, so all rounds reuse a single compilation.

    RAGGED cohorts (size-skewed non-IID partitions, e.g. Dirichlet —
    paper §4.3/4.4) run natively on this path: per-client datasets are
    padded to the cohort max and stacked (:func:`repro.data.ragged.pad_stack`),
    the sampler draws batch indices via ``randint(0, n_valid[k])`` so
    padding is never sampled, and in epoch mode (``local_steps == 0``)
    each client runs its OWN ``n_k // B`` steps: a per-step mask (composed
    with the §3.4 ``active`` mask) freezes a client's state and RNG chain
    once it has exhausted its local epoch, so it sits out the remaining
    scan iterations bit-exactly.

``shard_map``
    Same stacked round, but with one client per device of a mesh axis and
    the exchange realized as a ``jax.lax.ppermute`` collective
    (:func:`repro.core.gossip.pushsum_gossip_shard`) — the TPU-native
    O(1)-per-round communication path used at LLM scale. Requires a mesh
    whose ``axis`` has exactly ``n_clients`` devices. The round-t shift and
    the active pattern are trace-time static (each distinct membership
    pattern compiles its own collective schedule).

``async`` (stale gossip, Assran et al. 2019)
    The overlap-friendly fourth backend: instead of blocking on the
    in-neighbor's CURRENT proxy, round t's exchange delivers the proxy
    mass neighbors put in flight τ rounds earlier (``cfg.staleness``),
    modeling gossip overlapped with the next τ local scans — the
    synchronous protocol's straggler stall removed. Mechanically it is the
    vmap backend with the exchange split by
    :func:`repro.core.gossip.stale_mix_split`: each client KEEPS the
    diagonal of P^(t) applied to its raw PushSum numerator θ = z·w, SENDS
    the off-diagonal part into a τ-deep in-flight buffer, and MERGES the
    round-(t−τ) deliveries; de-biasing by the identically-delayed weights
    w keeps z a proper weighted average at every staleness, and total
    θ/w mass (clients + buffer) is conserved under arbitrary τ and §3.4
    dropout — see the stale-gossip note in ``repro.core.gossip``. The
    buffer is part of the engine state (``{"clients", "stale_theta",
    "stale_w"}``), travels through checkpoints, and rotates inside the
    round-block scan, so any block size and any kill/resume replays the
    identical trajectory bit-for-bit. τ=0 means immediate delivery: the
    engine then runs the vmap round program VERBATIM (same compiled
    program, unwrapped state), so ``staleness=0`` is bit-identical to
    ``backend="vmap"`` — params and epsilon — by construction (enforced
    by tests/test_conformance.py). Local-step RNG, batch draws and the DP
    accountant schedule are untouched by τ (staleness delays delivery,
    never compute), so epsilon is independent of τ. Semantics notes:
    inactive (§3.4) clients run no local steps and send nothing, but
    in-flight mass addressed to them still arrives (a mailbox merge —
    dropping it would destroy PushSum mass); the pure-permutation
    ``ring`` mix (CWT) keeps no self mass, so τ>0 would leave clients
    model-less for τ rounds — rejected at construction.

``hier`` (two-level hierarchical gossip)
    The thousand-client composition of the three stacked backends: a
    two-level cohort of ``cfg.n_shards × clients_per_shard`` (K must
    divide evenly) where intra-shard exchange is the on-device matmul mix
    over the stacked shard-local params (the vmap machinery —
    ``fused_pushsum_mix``-eligible under ``use_pallas``, vmapped over the
    shard axis) and inter-shard exchange is a sparse scaled permutation
    (at most ONE cross-shard edge per client per round — exactly the
    structure a ``ppermute`` collective realizes on a real device mesh;
    see ``launch/steps.py``/``launch/dryrun.py --program hier_block`` for
    the mesh deployment). Crucially this is NOT a different protocol:
    hier executes the SAME flat column-stochastic schedule P^(t) as vmap,
    FACTORED by edge locality (:func:`repro.core.gossip.hier_mix_split`:
    P = blockdiag[S, L, L] + cross scaled partial permutation — an exact
    sum decomposition), so ``n_shards`` is a pure execution-layout
    parameter at τ=0: the factored application is bit-identical to the
    dense [K, K] matmul (each output row performs the same ≤2 real
    additions), at O(K·L·D) + O(K·D) FLOPs instead of O(K²·D). With
    ``staleness`` τ>0 the cross-shard edges — and ONLY those — deliver
    through the async τ-deep in-flight buffer (``{"hier_buffer":
    [τ, K, D], "hier_w": [τ, K]}`` in the engine state, riding the
    block-scan carry and every checkpoint) while the intra-shard exchange
    stays synchronous: the deployment model is pods gossiping locally
    every round while inter-pod traffic hides behind τ rounds of compute.
    Mass conservation (clients + buffer) holds for any (n_shards, τ,
    dropout) — :func:`repro.core.gossip.hier_gossip_reference` is the
    executable spec. Checkpoints stay backend-portable: client states
    keep the FLAT [K, ...] vmap layout (the shard reshape happens only
    inside the traced programs), so a hier snapshot restores into
    loop/vmap engines unchanged; only the τ>0 buffer keys are
    hier-specific (a τ-mismatched restore fails the shape match, and the
    config fingerprint covers ``n_shards``). ``n_shards=1`` (any τ:
    every edge is intra-shard, so staleness is vacuous) and τ=0 S>1 run
    bit-identically to ``backend="vmap"`` — params AND epsilon — the
    former literally via the vmap round programs, the latter via the
    factored-application bit-equality (both enforced by
    tests/test_conformance.py). Dense mixing (``mix="mean"`` /
    ``topology="full"``) has O(K) cross edges per client — no O(1)
    collective schedule exists — and is rejected at construction for
    S>1, as is the pure-permutation ring mix with τ>0 (same model-less
    argument as async) and compressed exchange (the codec is wired to
    the dense matmul paths; factored compressed gossip is future work).

Backend selection guide
-----------------------
* heterogeneous private models            -> ``loop`` (forced)
* homogeneous cohort, one host            -> ``vmap``
* one client per device/pod on a mesh     -> ``shard_map``
* straggler-tolerant stale gossip         -> ``async`` (+ ``staleness``)
* two-level cohort (pods × local clients) -> ``hier`` (+ ``cfg.n_shards``,
  optional ``staleness`` on the cross-shard edges)
* ``"auto"``                              -> ``vmap`` when client states
  share one tree structure and the per-client data trees are
  *pad-compatible* (same structure, dtypes and trailing dims; leading
  example counts may differ — raggedness is handled by padding + masked
  sampling), otherwise ``loop``. Only genuinely incompatible trees fall
  back to the O(K·steps) Python loop. Caveat: in epoch mode
  (``local_steps == 0``) the stacked scan runs the cohort-MAX step count
  with exhausted clients masked, so at high size skew the loop backend's
  exact ``sum(n_k // B)`` steps can be cheaper (CPU especially) — pass
  ``backend="loop"`` explicitly there; ``benchmarks/fig_ragged.py``
  quantifies the tradeoff per regime.

Exchange rules (``mix``) are column-stochastic matrices built by
:func:`repro.core.gossip.mix_matrix`: ``"pushsum"`` (ProxyFL/AvgPush),
``"mean"`` (FedAvg/FML), ``"ring"`` (CWT), ``"none"`` (Regular/Joint).

Round-blocks (fused multi-round execution)
------------------------------------------
The ENGINE owns the round boundary, not the caller. ``run_round`` executes
one round; :meth:`FederationEngine.run_rounds` executes a whole block of
``n_rounds`` with the host re-entered only at the block edge. On the vmap
backend the block is ONE compiled XLA program — an outer ``lax.scan`` over
rounds wrapped around the per-round scan/vmap body, consuming the block's
exchange matrices as a single stacked ``[T, K, K]`` runtime argument
(:func:`repro.core.gossip.mix_schedule`) and folding each round's RNG key
in-scan (``round_key``; the per-round schedule is replayed bit-exactly, so
ANY block size produces bit-identical parameters and epsilon). shard_map
blocks unroll the per-round collective schedules inside one jit; the loop
backend keeps genuine per-round semantics as the bit-identity reference.

Block EDGES are the protocol's host-visible boundary: checkpoints are
written there (a kill/resume lands on an edge and replays bit-identically
— drivers cut blocks so every checkpoint/eval cadence round IS an edge),
evaluation and history rows read there, DP accountants bulk-step there
(``PrivacyAccountant.step(n)``), and §3.4 join/leave membership is
resolved there for the whole block (``active_schedule``). This is the
prerequisite for the planned ASYNC fourth backend: overlap-friendly
variants (clients gossiping stale proxies while the next local scan runs,
Assran et al.) need the engine — not the caller — to own a multi-round
horizon inside which rounds may interleave, while the block edge stays
the only point where external observers (checkpointer, evaluator,
membership changes) interact with the federation. The ``async`` backend
is exactly that fourth backend: rounds interleave INSIDE a block through
the τ-deep in-flight buffer carried in the block scan's state, while the
block edge stays the only host-visible boundary — the buffer is snapshot
and restored there, so kill/resume stays bit-identical at any τ. When is
τ>0 accuracy-safe? ``benchmarks/fig_async.py`` measures final proxy
accuracy and rounds/sec vs τ ∈ {0, 1, 2, 4}: private accuracy is
unaffected at any τ (the local DML schedule is untouched — only delivery
is delayed), and small staleness (τ ≤ 2) reaches the synchronous
reference's proxy accuracy given a modestly longer horizon (measured:
equal at 40 rounds on the synthetic MNIST task, where the sync run
converges by ~30), while large τ (≥ 4) visibly slows consensus — mix
information is τ rounds old — and needs proportionally more rounds.

Dropout/join (paper §3.4): every backend threads an ``active`` bool mask
through the round — inactive clients run no local steps, keep their state,
and the time-varying graph re-knits itself over the active subset (mass
conservation and de-biased convergence to the ACTIVE average are
preserved). Set ``ProxyFLConfig.dropout_rate`` for a deterministic
per-round schedule, or pass ``active=`` explicitly to ``run_round``.

Fused hot path (Pallas)
-----------------------
With ``ProxyFLConfig.use_pallas`` the two chains that dominate a round's
HBM traffic each touch every parameter chunk ONCE:

* the PushSum exchange — the matmul-mix backends (loop/vmap/async, both
  per-round and round-block programs) route through
  :func:`repro.core.gossip.pushsum_mix_debiased` /
  :func:`repro.core.gossip.stale_mix_apply`, whose fused kernels
  (``repro.kernels.pushsum_mix``) keep the small [K,K] exchange matrix
  resident in VMEM and stream the stacked [K, D] proxies block-by-block,
  computing mix + de-bias (and for the stale τ>0 split: re-bias, kept/sent
  split, buffer merge, de-bias) in one HBM→VMEM pass per chunk instead of
  XLA's materialized matmul → divide chain;
* the DP proxy update — ``cfg.dp.enabled`` steps go through
  :func:`repro.core.dp.dp_adam_update`, fusing per-microbatch clip→
  accumulate (``repro.kernels.dp_clip``) and the trailing noise→Adam step
  (``repro.kernels.dp_step``) over the flattened gradient vector.

Dispatch is platform-aware (``repro.kernels.default_interpret``): real
Mosaic kernels on TPU, interpret mode elsewhere. The fused path is
allclose — not bit-identical — to the plain-XLA reference (f32
accumulation, fused reduction order); tests/test_conformance.py pins the
parity (params AND epsilon) per backend, and ``benchmarks/fig_kernels.py``
measures the rounds/sec and bytes-moved-per-round effect. shard_map keeps
its ppermute collective exchange regardless of the flag.

Compressed proxy exchange (error feedback)
------------------------------------------
``ProxyFLConfig.compress`` ∈ {"none", "topk", "int8"} (plus
``compress_ratio`` for top-k) routes every matmul-mix exchange through
``repro.core.compress``: the off-diagonal transmissions — and ONLY those;
a client's kept mass never crosses the wire — are sparsified/quantized,
and each client carries its codec state — the PUBLIC COPY ``ẑ_k`` [D]
every receiver already holds — in the ENGINE state (the same carried-
state pattern as the async τ-buffer: a federation-level ``{"clients",
"ef_state"}`` wrapper, never inside the per-client trees a step_fn could
drop). The wire carries compressed DELTAS against that copy
(CHOCO-SGD-style): ``c_k = C(m_k − ẑ_k)``, ``ẑ'_k = ẑ_k + c_k``, and
receivers mix the DENSE ``ẑ'_k`` — so sparsification never zero-fills a
coordinate on the receiver and the de-bias denominator stays exact. The
error-feedback residual is implicit (``m_k − ẑ'_k``): per transmitting
client per round ``c_k + (m_k − ẑ'_k) == m_k − ẑ_k`` exactly in f32, so
truncated mass is DELAYED into later rounds, never destroyed; clients
that send nothing (§3.4 dropouts, no-exchange rounds) keep their public
copy untouched. The copies warm-start at the initial proxies (one
uncompressed setup broadcast). The codec state rides the block-scan
carry (any block size replays bit-identically), travels through
``_ckpt_payload``/``restore_state`` (kill/
resume is bit-identical; config fingerprints refuse a compression-config
mismatch), and de-bias weights are never compressed, so PushSum w-mass
conservation is exact at any τ. ``compress="none"`` keeps every round
program byte-for-byte the uncompressed one (enforced bitwise by
tests/test_conformance.py). Compression composes with loop/vmap/blocked/
async-τ>0; shard_map's ppermute exchange is uncompressed-only (rejected
at construction), and ``use_pallas`` falls back to the plain-XLA path for
the exchange while compressing (the fused kernels implement the
uncompressed chain — see ``repro.core.compress``).

Typical usage::

    engine = dml_engine((spec,) * K, proxy_spec, cfg)   # backend="auto"
    state = engine.init_states(jax.random.PRNGKey(0))
    for t in range(cfg.rounds):                         # per-round driving
        state, metrics = engine.run_round(
            state, client_data, t, round_key(key, t))
    # ... or hand the engine a whole fused horizon (same bits, one program):
    state, metrics = engine.run_rounds(state, client_data, 0, cfg.rounds, key)
    params_k = engine.client_params(state, k, role="private")

The per-client state is a pytree dict with (at least) ``{"proxy":
{"params", "opt"}, "w"}``; the engine gossips ``proxy.params`` and the
PushSum weight ``w`` and leaves everything else (private model, optimizer
moments, step counters) client-local — exactly the paper's privacy
boundary: only proxies ever cross clients.

The conventions this module depends on — the canonical ``round_key``
schedule, checkpoint coverage of every scan-carry key, config
fingerprinting, trace hygiene in the round cores — are machine-checked
contracts: ``docs/INVARIANTS.md`` documents them, ``tools/fedlint``
enforces them in CI (``scripts/ci.sh --lint``). Extending the engine
state or the RNG schedule means extending those tables in the same PR.
"""
from __future__ import annotations

import functools
import inspect
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.ckpt import load_checkpoint, save_checkpoint
from ..configs.base import ProxyFLConfig
from ..data.ragged import pad_compatible, pad_stack
from ..nn.modules import tree_flatten_vector, tree_unflatten_vector
from ..optim import Adam
from .compress import compress_round_key, compress_spec
from .gossip import (gossip_shift, hier_layout, hier_mix_debiased,
                     hier_mix_schedule, hier_mix_split,
                     hier_stale_mix_apply, mix_matrix, mix_schedule,
                     pushsum_gossip_shard, pushsum_mix_debiased,
                     shard_map_fn, shift_schedule, stale_mix_apply,
                     stale_mix_schedule, stale_mix_split)

BACKENDS = ("loop", "vmap", "shard_map", "async", "hier")
MIXES = ("pushsum", "mean", "ring", "none")

# round t's RNG key is fold_in(base_key, ROUND_KEY_OFFSET + t) — the
# historical schedule every driver used; round-blocks fold it IN-SCAN so a
# blocked run replays the identical per-round keys bit-exactly.
ROUND_KEY_OFFSET = 10_000

StepFn = Callable[[Dict, Any, jnp.ndarray], Tuple[Dict, Dict]]
InitFn = Callable[[jnp.ndarray], Dict]
SampleFn = Callable[[Any, jnp.ndarray], Any]


def _sampler_accepts_n_valid(fn) -> bool:
    """True when ``fn`` can be called ``fn(data_k, key, n_valid=...)`` —
    the masked-sampling protocol ragged cohorts need on the stacked path
    (``n_valid`` bounds the index draw so padding is never sampled). The
    parameter must be NAMED ``n_valid``: bare third-argument sniffing
    would silently feed the dataset length into an unrelated parameter of
    a legacy 3-arg sampler. Samplers without it stay supported for
    rectangular data."""
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):  # builtins / C callables: be conservative
        return False
    p = sig.parameters.get("n_valid")
    return p is not None and p.kind in (p.POSITIONAL_OR_KEYWORD,
                                        p.KEYWORD_ONLY)


def round_key(base_key, t):
    """Round t's RNG key under the engine's canonical schedule."""
    return jax.random.fold_in(base_key, ROUND_KEY_OFFSET + t)


def active_mask(t: int, n_clients: int, cfg: ProxyFLConfig
                ) -> Optional[np.ndarray]:
    """Deterministic per-round §3.4 dropout schedule from the config.

    Returns None (everyone participates) when ``cfg.dropout_rate == 0``;
    otherwise a bool[K] mask drawn from a seed derived from (cfg.seed, t),
    re-sampled identically by every backend and across reruns."""
    if not cfg.dropout_rate:
        return None
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, 7919, t]))
    act = rng.random(n_clients) >= cfg.dropout_rate
    floor = max(1, min(cfg.min_active, n_clients))
    if act.sum() < floor:
        act[rng.choice(n_clients, size=floor, replace=False)] = True
    return act


def block_spans(start: int, rounds: int, rounds_per_block: int, *cadences):
    """Yield ``(t0, n)`` round-block spans covering ``[start, rounds)``.

    Blocks are at most ``rounds_per_block`` long and are CUT so that every
    multiple of each nonzero cadence (checkpoint_every, eval_every, ...)
    lands exactly on a block edge — the one place drivers may observe the
    federation. This is the single definition of the block-cutting rule;
    both ``baselines._drive_blocks`` and ``launch/train.py`` iterate it,
    so the "cadence rounds are block edges" invariant cannot drift."""
    B = max(1, int(rounds_per_block or 1))
    t = start
    while t < rounds:
        n = min(B, rounds - t)
        for c in cadences:
            if c and c > 0:
                n = min(n, c - t % c)
        yield t, n
        t += n


def active_schedule(t0: int, n_rounds: int, n_clients: int,
                    cfg: ProxyFLConfig) -> Optional[np.ndarray]:
    """Block-level §3.4 membership: ``active_mask`` for each round of a
    block, stacked to bool[T, K]. None when no dropout is configured (the
    per-t masks are all None). The per-round draws are preserved exactly
    (seeded per (cfg.seed, t)), so a blocked run replays the identical
    dropout trajectory as the per-round path."""
    masks = [active_mask(t, n_clients, cfg)
             for t in range(t0, t0 + n_rounds)]
    if all(m is None for m in masks):
        return None
    return np.stack([np.ones(n_clients, bool) if m is None else m
                     for m in masks])


def stack_states(states: Sequence[Dict]) -> Dict:
    """List of per-client state pytrees -> one pytree with leading K dim."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def unstack_state(stacked: Dict, k: int) -> Dict:
    return jax.tree_util.tree_map(lambda x: x[k], stacked)


def _tree_where(mask_k: jnp.ndarray, new: Dict, old: Dict) -> Dict:
    """Per-client select over stacked pytrees (mask_k: bool[K])."""
    def sel(n, o):
        m = mask_k.reshape((mask_k.shape[0],) + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree_util.tree_map(sel, new, old)


def _stack_metric_rows(rows: Sequence[Dict[str, np.ndarray]], n_clients: int
                       ) -> Dict[str, np.ndarray]:
    """Per-round metric dicts ([K] arrays) -> one [T, K] array per key
    (key union, NaN where a round didn't emit that metric)."""
    keys = set().union(*(r.keys() for r in rows)) if rows else set()
    nan = np.full(n_clients, np.nan)
    return {k: np.stack([np.asarray(r.get(k, nan), float) for r in rows])
            for k in sorted(keys)}


def _key_data(key) -> np.ndarray:
    """Raw uint32 words of a PRNG key (old-style arrays and typed keys);
    zeros stand for 'no key recorded' in checkpoints."""
    if key is None:
        return np.zeros((2,), np.uint32)
    if hasattr(key, "dtype") and jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    return np.asarray(key, np.uint32)


class FederationEngine:
    """Multi-backend executor of one federated round (see module docstring).

    Parameters
    ----------
    cfg : ProxyFLConfig
        Protocol knobs (local_steps, batch_size, topology, dropout_rate...).
    n_clients : int
    step_fns : StepFn | Sequence[StepFn]
        ``step(state, batch, key) -> (state, metrics)`` — one client's local
        update. A sequence (len K) is allowed for the loop backend only
        (heterogeneous architectures).
    init_fns : InitFn | Sequence[InitFn]
        ``init(key) -> state`` per client.
    sample_fn : SampleFn
        ``sample(client_data, key) -> batch`` — draws one local batch.
    backend : "auto" | "loop" | "vmap" | "shard_map" | "async" | "hier"
    mix : "pushsum" | "mean" | "ring" | "none"
    mesh, axis : mesh + axis name for the shard_map backend.
    staleness : gossip delay τ for the async backend, and the CROSS-SHARD
        delay for the hier backend (None -> the value in
        ``cfg.staleness``); ignored by the synchronous backends. The hier
        shard count comes from ``cfg.n_shards`` (must divide n_clients).
    """

    def __init__(self, cfg: ProxyFLConfig, *, n_clients: int,
                 step_fns, init_fns, sample_fn: SampleFn,
                 backend: str = "auto", mix: str = "pushsum",
                 mesh=None, axis: str = "clients", staleness=None):
        assert mix in MIXES, mix
        self.cfg = cfg
        self.K = n_clients
        self.step_fns = (list(step_fns) if isinstance(step_fns, (list, tuple))
                         else [step_fns] * n_clients)
        self.init_fns = (list(init_fns) if isinstance(init_fns, (list, tuple))
                         else [init_fns] * n_clients)
        assert len(self.step_fns) == n_clients
        self.sample_fn = sample_fn
        self.mix = mix
        self.mesh = mesh
        self.axis = axis
        self.accountants: List = [None] * n_clients
        homogeneous = all(f is self.step_fns[0] for f in self.step_fns)
        if backend == "auto":
            backend = "vmap" if homogeneous else "loop"
        assert backend in BACKENDS, backend
        if backend in ("vmap", "shard_map", "async", "hier"):
            assert homogeneous, (
                f"{backend} backend requires a homogeneous cohort; "
                "heterogeneous private architectures need backend='loop'")
        if backend == "shard_map":
            assert mesh is not None, "shard_map backend needs a mesh"
            assert dict(mesh.shape).get(axis) == n_clients, (
                f"mesh axis {axis!r} must hold exactly {n_clients} devices")
        if backend in ("async", "hier"):
            self.staleness = int(cfg.staleness if staleness is None
                                 else staleness)
            assert self.staleness >= 0, self.staleness
            if self.staleness and mix == "ring":
                raise ValueError(
                    f"{backend} staleness>0 is incompatible with the pure-"
                    "permutation ring mix (CWT): clients keep no self mass, "
                    "so a delayed delivery would leave them model-less for "
                    "the first τ rounds; use staleness=0 or a mix with a "
                    "positive diagonal (pushsum/mean)")
        else:
            self.staleness = 0
        # staleness=0 is synchronous delivery: the async backend then runs
        # the vmap round programs verbatim on UNWRAPPED state (no buffer),
        # which is what makes τ=0 bit-identical to backend="vmap"
        self._stale = backend == "async" and self.staleness > 0
        # hier: two-level [n_shards × clients-per-shard] cohort executing
        # the SAME flat P^(t) factored by edge locality; n_shards=1 makes
        # every edge intra-shard (staleness vacuous), so the engine runs
        # the vmap round programs verbatim — the bit-identity anchor
        self.n_shards = (hier_layout(n_clients, cfg.n_shards)[0]
                         if backend == "hier" else 1)
        self._hier = backend == "hier" and self.n_shards > 1
        if self._hier and mix != "none" and n_clients > 1:
            topo = {"pushsum": cfg.topology, "mean": "full",
                    "ring": "ring"}[mix]
            if topo == "full":
                raise ValueError(
                    "hier with n_shards>1 needs a sparse exchange: dense "
                    "mixing (mix='mean' / topology='full') has O(K) "
                    "cross-shard edges per client, which no O(1) inter-"
                    "shard collective schedule can realize; use pushsum/"
                    "ring mixes or n_shards=1")
        self._hier_stale = self._hier and self.staleness > 0
        # compressed proxy exchange (cfg.compress): None keeps every round
        # program byte-for-byte the uncompressed one; a spec adds each
        # client's codec state (the public copy receivers mix) to the
        # engine state and routes the matmul exchanges through
        # repro.core.compress
        self.compress = compress_spec(cfg)
        if self.compress is not None and backend == "shard_map":
            raise ValueError(
                "compressed gossip (cfg.compress != 'none') is not "
                "implemented for the shard_map ppermute exchange — the "
                "collective ships full-precision tensors; use the loop/"
                "vmap/async backends for compressed rounds")
        if self.compress is not None and self._hier:
            raise ValueError(
                "compressed gossip (cfg.compress != 'none') is not "
                "implemented for the hier factored exchange — the codec "
                "is wired to the dense matmul paths; use n_shards=1 (which "
                "runs the vmap programs verbatim) or the loop/vmap/async "
                "backends for compressed rounds")
        self._compressed = (self.compress is not None
                            and mix != "none" and n_clients > 1)
        # a federation-level state wrapper {"clients": ..., [stale buffer,]
        # [codec public copies]} carries cross-round exchange state NEXT TO
        # the clients — per-client step_fns must never see (and drop) it
        self._wrapped = self._stale or self._compressed or self._hier_stale
        self.backend = backend
        # Pallas-fused exchange (cfg.use_pallas): the matmul-mix backends
        # route through the fused blocked kernels in repro.kernels —
        # allclose, not bit-identical, to the plain-XLA reference (f32
        # accumulation, fused de-bias). shard_map keeps its ppermute path.
        self.use_pallas = bool(getattr(cfg, "use_pallas", False))
        # Commitment verification of the received proxies (loop backend;
        # cfg.verify_commitments): each sender's released proxy is
        # committed to (repro.core.commit.client_commitment) before the
        # exchange and every receiver recomputes the digest from the wire
        # payload before mixing — a tampered in-flight proxy refuses with
        # a CommitmentError naming the client and round. transmit_tamper
        # is the adversary hook the byzantine tests inject (host-side
        # (flat [K, D] numpy, t) -> flat, e.g. attacks.bitflip_proxy);
        # None leaves the exchange untouched.
        self.verify_commitments = bool(getattr(cfg, "verify_commitments",
                                               False))
        self.transmit_tamper: Optional[Callable] = None
        # donation lets XLA update params/opt in place; CPU only warns
        self._donate = (0,) if jax.default_backend() != "cpu" else ()
        self._masked_sampler = _sampler_accepts_n_valid(sample_fn)
        self._loop_steps: Dict = {}   # id(step_fn) -> jitted one-step
        self._rounds: Dict = {}       # compile cache: key -> jitted round
        # small keyed LRU: id(data) -> (ref, stacked, n_valid). A single
        # entry thrashes when two datasets alternate (train/finetune
        # interleave) — every round would re-pad, re-stack and re-transfer.
        self._data_cache: "OrderedDict" = OrderedDict()
        self._data_cache_max = 4
        self._stack_misses = 0        # observability: cache-miss count

    # -- state construction / access ---------------------------------------

    def _clients_of(self, state):
        """The per-client state tree (stacked pytree, or a list on the loop
        backend). For the stale async backend (τ>0) and for compressed
        exchanges the engine state is a federation-level wrapper
        ``{"clients": <stacked tree | list>, ["stale_theta": [τ, K, D],
        "stale_w": [τ, K],] ["hier_buffer": [τ, K, D], "hier_w": [τ, K],]
        ["ef_state": [K, D]]}`` — the in-flight gossip buffers (flat async
        or hier cross-shard) and the codec's public copies ride next to
        the clients, never inside them (per-client step_fns must not see
        or drop them)."""
        return state["clients"] if self._wrapped else state

    def init_states(self, key) -> Any:
        """Per-client init at fold_in(key, k) — identical across backends.
        The stale async backend additionally allocates the empty τ-deep
        in-flight buffer (cold start: nothing arrives for τ rounds and the
        de-bias weights account for the mass in flight); compressed
        exchanges WARM-START the public copies at the initial proxies
        (f32 [K, D] — accumulator precision regardless of the proxy
        dtype): one uncompressed broadcast at setup, after which every
        round's wire carries only the compressed delta — without it the
        copies need ≈1/ratio rounds to even cover the coordinates and
        the top-k proxies measurably lag at short horizons."""
        states = [self.init_fns[k](jax.random.fold_in(key, k))
                  for k in range(self.K)]
        base: Any = (states if self.backend == "loop"
                     else stack_states(states))
        if not self._wrapped:
            return base
        state: Dict[str, Any] = {"clients": base}
        flat0 = tree_flatten_vector(states[0]["proxy"]["params"])
        if self._stale:
            state["stale_theta"] = jnp.zeros(
                (self.staleness, self.K, flat0.shape[0]), flat0.dtype)
            state["stale_w"] = jnp.zeros(
                (self.staleness, self.K),
                jnp.result_type(states[0]["w"]))
        if self._hier_stale:
            # cross-shard in-flight buffer (raw numerators θ = z·w + the
            # matching weights), cold-started empty: for τ rounds the
            # cross edges deliver nothing and the de-bias weights account
            # for the mass in flight — intra-shard mass is never buffered
            state["hier_buffer"] = jnp.zeros(
                (self.staleness, self.K, flat0.shape[0]), flat0.dtype)
            state["hier_w"] = jnp.zeros(
                (self.staleness, self.K),
                jnp.result_type(states[0]["w"]))
        if self._compressed:
            state["ef_state"] = jnp.stack(
                [tree_flatten_vector(s["proxy"]["params"])
                 for s in states]).astype(jnp.float32)
        return state

    def export_states(self, state) -> List[Dict]:
        clients = self._clients_of(state)
        if self.backend == "loop":
            return list(clients)
        return [unstack_state(clients, k) for k in range(self.K)]

    def client_state(self, state, k: int) -> Dict:
        clients = self._clients_of(state)
        return (clients[k] if self.backend == "loop"
                else unstack_state(clients, k))

    def client_params(self, state, k: int, role: str = "proxy"):
        clients = self._clients_of(state)
        s = clients[k] if self.backend == "loop" else clients
        p = s[role]["params"]
        return p if self.backend == "loop" else jax.tree_util.tree_map(
            lambda x: x[k], p)

    def stacked_params(self, state, role: str = "proxy"):
        """The whole cohort's ``role`` params with a leading K dim — the
        input batched evaluation wants. Free on the stacked backends (that
        IS the state layout); the loop backend stacks on demand, or returns
        None when the per-client trees differ (heterogeneous architectures
        cannot be batched — callers fall back to per-client evaluation)."""
        if self.backend != "loop":
            return self._clients_of(state)[role]["params"]
        trees = [s[role]["params"] for s in self._clients_of(state)]
        structs = {jax.tree_util.tree_structure(tr) for tr in trees}
        shapes = {tuple((x.shape, jnp.result_type(x))
                        for x in jax.tree_util.tree_leaves(tr))
                  for tr in trees}
        if len(structs) != 1 or len(shapes) != 1:
            return None
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)

    def attach_accountants(self, accountants: Sequence) -> None:
        assert len(accountants) == self.K
        self.accountants = list(accountants)

    # -- checkpointing -------------------------------------------------------

    def _ckpt_payload(self, state, t: int, base_key) -> Dict:
        """Backend-portable snapshot tree: per-client states (stacked
        vmap/shard_map state is gathered off the device mesh by the
        per-client unstack), the round counter, per-client accountant step
        counts, and the base RNG key the round keys derive from. The same
        builder produces the restore template, so save and restore always
        agree on tree structure."""
        clients = {f"c{k:04d}": s
                   for k, s in enumerate(self.export_states(state))}
        steps = np.asarray([a.steps if a is not None else 0
                            for a in self.accountants], np.int32)
        payload = {"clients": clients,
                   "rounds_done": np.asarray(t + 1, np.int32),
                   "accountant_steps": steps,
                   "base_key": _key_data(base_key),
                   # explicit flag: PRNGKey(0)'s key data is all zeros, so
                   # the key words alone cannot mean "no key recorded"
                   "base_key_set": np.asarray(base_key is not None, np.uint8)}
        if self._stale:
            # the in-flight gossip buffer is federation state: rounds
            # t+1..t+τ deliver sends recorded here, so a resume without it
            # could not replay the trajectory (a τ-mismatched or sync
            # checkpoint fails the key/shape match with a descriptive error)
            payload["stale_theta"] = state["stale_theta"]
            payload["stale_w"] = state["stale_w"]
        if self._hier_stale:
            # same argument for the hier cross-shard buffer: rounds
            # t+1..t+τ merge the cross-shard deliveries recorded here, so
            # a resume without it could not replay the trajectory (τ=0 /
            # n_shards=1 snapshots carry no buffer and stay plain vmap
            # payloads — backend-portable by construction)
            payload["hier_buffer"] = state["hier_buffer"]
            payload["hier_w"] = state["hier_w"]
        if self._compressed:
            # the codec's public copies are federation state for the same
            # reason: round t+1's transmission is C(m − ef_state) and the
            # receivers mix ef_state itself, so a resume without it (or
            # across a compression-config change — also refused by
            # FederationCheckpointer's config fingerprint) could not
            # replay the trajectory bit-identically
            payload["compress_ef_state"] = state["ef_state"]
        return payload

    def save_state(self, path: str, state, t: int, base_key=None) -> str:
        """Write a complete-federation snapshot after completed round ``t``
        (works on all backends; see ``repro.checkpoint.federation``)."""
        save_checkpoint(path, self._ckpt_payload(state, t, base_key))
        return path

    def restore_state(self, path: str, like=None, base_key=None
                      ) -> Tuple[Any, int]:
        """Bit-exact inverse of :meth:`save_state`; returns ``(state,
        rounds_done)`` in THIS engine's layout (a loop-backend checkpoint
        restores fine into a vmap engine and vice versa). ``like`` is a
        template state with the target tree structure (default: a throwaway
        ``init_states``). Attached accountants get their step counters
        back; passing the run's ``base_key`` verifies the checkpoint was
        written under the same key schedule."""
        if like is None:
            like = self.init_states(jax.random.PRNGKey(0))
        loaded = load_checkpoint(path, self._ckpt_payload(like, 0, None))
        clients = [loaded["clients"][f"c{k:04d}"] for k in range(self.K)]
        base: Any = (clients if self.backend == "loop"
                     else stack_states(clients))
        if self._wrapped:
            state: Any = {"clients": base}
            if self._stale:
                state["stale_theta"] = loaded["stale_theta"]
                state["stale_w"] = loaded["stale_w"]
            if self._hier_stale:
                state["hier_buffer"] = loaded["hier_buffer"]
                state["hier_w"] = loaded["hier_w"]
            if self._compressed:
                state["ef_state"] = loaded["compress_ef_state"]
        else:
            state = base
        rounds_done = int(loaded["rounds_done"])
        steps = np.asarray(loaded["accountant_steps"])
        for k, acc in enumerate(self.accountants):
            if acc is not None:
                acc.steps = int(steps[k])
        saved_key = np.asarray(loaded["base_key"], np.uint32)
        if base_key is not None and bool(loaded["base_key_set"]) and \
                not np.array_equal(saved_key, _key_data(base_key)):
            raise ValueError(
                f"checkpoint {path!r} was written under a different base RNG "
                "key; resuming would change the round key schedule")
        return state, rounds_done

    # -- round execution ----------------------------------------------------

    def n_steps(self, data_k) -> int:
        if self.cfg.local_steps:
            return self.cfg.local_steps
        n = jax.tree_util.tree_leaves(data_k)[0].shape[0]
        return max(1, n // self.cfg.batch_size)

    def client_steps(self, data: Sequence) -> np.ndarray:
        """int32[K] local steps per client this round — constant under
        ``cfg.local_steps``, per-client epoch length (``n_k // B``) in
        epoch mode; the source of the stacked backends' step mask."""
        return np.asarray([self.n_steps(d) for d in data], np.int32)

    def run_round(self, state, data: Sequence, t: int, key,
                  active=None) -> Tuple[Any, Dict[str, np.ndarray]]:
        """One full federated round: local steps on every ACTIVE client,
        then one graph exchange. ``data`` is a sequence of per-client data
        pytrees; ``key`` is the round key (client k steps with
        ``fold_in(key, k)``, matching the historical schedule)."""
        if active is None:
            active = active_mask(t, self.K, self.cfg)
        act = None if active is None else np.asarray(active, bool)
        if act is not None:
            assert act.shape == (self.K,)
        if self.backend == "loop":
            state, metrics = self._round_loop(state, data, t, key, act)
        elif self._stale:
            state, metrics = self._round_stale(state, data, t, key, act)
        elif self._hier:
            state, metrics = self._round_hier(state, data, t, key, act)
        else:
            state, metrics = self._round_stacked(state, data, t, key, act)
        for k, acc in enumerate(self.accountants):
            if acc is not None and (act is None or act[k]):
                acc.step(self.n_steps(data[k]))
        return state, metrics

    def run_rounds(self, state, data: Sequence, t0: int, n_rounds: int,
                   key) -> Tuple[Any, Dict[str, np.ndarray]]:
        """Engine-owned round-block: rounds ``t0 .. t0+n_rounds-1`` with the
        host re-entered only at the block edge.

        ``key`` is the run's BASE key (not a pre-folded round key): round t
        steps under ``round_key(key, t)``, folded in-scan, which is exactly
        the per-round schedule every driver historically used — so any
        block size replays the identical trajectory bit-for-bit, and a
        resume landing on a block edge continues it.

        vmap backend: the whole block is ONE compiled XLA program — an
        outer ``lax.scan`` over rounds around the per-round scan/vmap body,
        with the block's exchange matrices precomputed host-side as one
        stacked ``mix_schedule`` [T, K, K] runtime argument (one
        compilation serves every block of the same shape). shard_map: the
        per-round collective schedules are trace-time static, so the block
        is the rounds unrolled inside one jit. loop backend (and
        ``n_rounds == 1``): per-round semantics, unchanged — the
        bit-identity reference.

        Dropout (§3.4) replays the per-round ``active_mask`` schedule
        (``active_schedule``); attached accountants are bulk-stepped once
        per block (``PrivacyAccountant.step(n)`` over each client's active
        rounds), which lands on the same counters as per-round stepping.

        shard_map UNDER DROPOUT also takes the per-round path: its
        collective schedules are trace-time static, so a (typically
        unique) membership trajectory would compile a fresh T-round
        unrolled program every block, where per-round execution reuses one
        cached program per (shift, pattern).

        The async backend at staleness>0 runs :meth:`_rounds_block_stale`
        — the same outer scan with the τ-deep in-flight buffer in the
        carry (rounds interleave INSIDE the block; dropout stays on the
        blocked path since the stale splits are runtime arguments); at
        staleness=0 it runs the vmap block verbatim. The hier backend at
        n_shards>1 runs :meth:`_rounds_block_hier` — the factored
        two-level exchange in the same outer scan (the stacked factored
        schedules are runtime arguments, so dropout stays blocked too),
        with the cross-shard buffer joining the carry when staleness>0;
        at n_shards=1 it runs the vmap block verbatim.

        Returns ``(state, metrics)`` with each metric stacked to
        ``[n_rounds, K]`` (row i = round t0+i, NaN for inactive clients).
        """
        assert n_rounds >= 1, n_rounds
        if self.backend == "loop" or n_rounds == 1 or (
                self.backend == "shard_map" and self.cfg.dropout_rate):
            rows = []
            for t in range(t0, t0 + n_rounds):
                state, m = self.run_round(state, data, t, round_key(key, t))
                rows.append(m)
            return state, _stack_metric_rows(rows, self.K)
        block = (self._rounds_block_stale if self._stale else
                 self._rounds_block_hier if self._hier else
                 self._rounds_block)
        return block(state, data, t0, n_rounds, key,
                     active_schedule(t0, n_rounds, self.K, self.cfg))

    def _finish_block(self, ms, act_stack, data):
        """Shared block epilogue: pull the stacked [T, K] metrics to host
        and bulk-step attached accountants over each client's ACTIVE
        rounds. ONE definition for the sync and stale block paths, so the
        DP step schedule cannot diverge between backends."""
        metrics = {k: np.asarray(v) for k, v in ms.items()}
        for k, acc in enumerate(self.accountants):
            if acc is not None:
                n_active_rounds = int(act_stack[:, k].sum())
                if n_active_rounds:
                    acc.step(n_active_rounds * self.n_steps(data[k]))
        return metrics

    def _rounds_block(self, state, data, t0, T, key, act_sched):
        data_s, n_valid, pass_nv, n_steps, step_masked, steps_dev = \
            self._stacked_inputs(data)
        act_stack = (np.ones((T, self.K), bool) if act_sched is None
                     else act_sched)
        mixing = self.mix != "none" and self.K > 1
        Ps = jnp.zeros((T, 1))  # placeholder when no matmul mix runs
        if self.backend != "shard_map":  # vmap, or async at staleness=0
            rkey = ("vmap_block", T, n_steps, step_masked, pass_nv)
            if rkey not in self._rounds:
                self._rounds[rkey] = self._build_block(
                    T, n_steps, self._mix_matmul_op() if mixing else None,
                    step_masked, pass_nv)
            if mixing:
                Ps = jnp.asarray(
                    mix_schedule(self.mix, t0, T, self.K, self.cfg.topology,
                                 active=act_sched), jnp.float32)
        else:
            # full-membership only here (dropout delegated to per-round):
            # the block's ppermute schedule is just the shift sequence
            topo, _ = self._mix_topology()
            shifts = (tuple(int(s) for s in
                            shift_schedule(t0, T, self.K, topo))
                      if mixing else (None,) * T)
            rkey = ("shard_block", T, n_steps, step_masked, pass_nv,
                    self.mix, shifts)
            if rkey not in self._rounds:
                mix_ops = [self._shard_mix_op(t, None) if mixing else None
                           for t in range(t0, t0 + T)]
                self._rounds[rkey] = self._build_block(
                    T, n_steps, mix_ops, step_masked, pass_nv)
        ts = jnp.arange(t0, t0 + T, dtype=jnp.int32)
        if self._compressed and mixing:
            clients, ef_state, ms = self._rounds[rkey](
                self._clients_of(state), state["ef_state"], data_s, n_valid,
                steps_dev, Ps, jnp.asarray(act_stack), ts, key)
            state = {"clients": clients, "ef_state": ef_state}
        else:
            clients, ms = self._rounds[rkey](
                self._clients_of(state), data_s, n_valid, steps_dev, Ps,
                jnp.asarray(act_stack), ts, key)
            state = ({"clients": clients, "ef_state": state["ef_state"]}
                     if self._compressed else clients)
        return state, self._finish_block(ms, act_stack, data)

    # -- loop backend --------------------------------------------------------

    def _one_step(self, k: int):
        """(state, data_k, chain_key) -> (state, chain_key, metrics) —
        the same composed body the vmap/shard scan uses, jitted once per
        DISTINCT step_fn (homogeneous cohorts share one compilation).
        Masked samplers get the client's true length here too (the
        unpadded leading dim — same value the stacked path passes, so the
        index draws are identical AND a sampler with a required
        ``n_valid`` parameter works on every backend)."""
        step_fn, sample = self.step_fns[k], self.sample_fn
        masked = self._masked_sampler
        cached = self._loop_steps.get(id(step_fn))
        if cached is None:
            def one(state, data_k, key):
                key, kb, kn = jax.random.split(key, 3)
                # n_valid is only well-defined when every leaf shares the
                # example axis; trees with auxiliary leaves keep the
                # sampler's own default (shape-derived) bound
                dims = {x.shape[0] for x in jax.tree_util.tree_leaves(data_k)
                        if getattr(x, "ndim", 0)}
                if masked and len(dims) == 1:
                    batch = sample(data_k, kb, n_valid=dims.pop())
                else:
                    batch = sample(data_k, kb)
                state, m = step_fn(state, batch, kn)
                return state, key, m

            cached = self._loop_steps[id(step_fn)] = jax.jit(one)
        return cached

    def _round_loop(self, state, data, t, key, act):
        ef_state = state["ef_state"] if self._compressed else None
        states = list(self._clients_of(state))  # same no-aliasing contract
        per_client: List[Optional[Dict]] = [None] * self.K
        for k in range(self.K):
            if act is not None and not act[k]:
                continue
            one = self._one_step(k)
            ck = jax.random.fold_in(key, k)
            s = states[k]
            m: Dict = {}
            for _ in range(self.n_steps(data[k])):
                s, ck, m = one(s, data[k], ck)
            states[k] = s
            per_client[k] = m
        if self.mix != "none" and self.K > 1:
            P = mix_matrix(self.mix, t, self.K, self.cfg.topology, act)
            flat = jnp.stack([tree_flatten_vector(s["proxy"]["params"])
                              for s in states])
            if self.verify_commitments or self.transmit_tamper is not None:
                flat = self._verified_exchange(flat, states, t)
            w = jnp.asarray([jnp.asarray(s["w"]) for s in states], flat.dtype)
            if self._compressed:
                # same compressed exchange — and the same codec RNG key
                # derivation — as the stacked round programs, so loop stays
                # the heterogeneous-capable reference of the compressed path
                unb, w2, ef_state = pushsum_mix_debiased(
                    flat, w, P, use_pallas=self.use_pallas,
                    compress=self.compress, ef_state=ef_state,
                    key=compress_round_key(key))
            else:
                unb, w2 = pushsum_mix_debiased(flat, w, P,
                                               use_pallas=self.use_pallas)
            like = states[0]["proxy"]["params"]
            for k in range(self.K):
                states[k] = dict(states[k])
                states[k]["proxy"] = dict(
                    states[k]["proxy"],
                    params=tree_unflatten_vector(unb[k], like))
                states[k]["w"] = w2[k]
        keys = set().union(*(m.keys() for m in per_client if m is not None))
        # heterogeneous clients may emit different metric keys — absent
        # entries collate as NaN instead of raising
        metrics = {kk: np.asarray([float(m[kk]) if m is not None and kk in m
                                   else np.nan for m in per_client])
                   for kk in sorted(keys)}
        if self._compressed:
            return {"clients": states, "ef_state": ef_state}, metrics
        return states, metrics

    def _verified_exchange(self, flat, states, t: int):
        """Commitment-checked wire hop of the loop backend's exchange.

        Each sender DECLARES the commitment of the proxy it releases
        (hashed from its parameter tree, the same digest its audit-trail
        entries carry); the stacked wire payload then passes through the
        adversary hook (``transmit_tamper``, when injected); finally every
        receiver reconstructs the per-client trees from the received rows
        and recomputes the commitments. Any row whose digest no longer
        matches its sender's declaration raises ``CommitmentError`` naming
        the client and round BEFORE the tampered mass can be mixed. This is
        an in-process simulation of the cross-host protocol (declare →
        transmit → recompute → compare); the untampered path returns the
        payload bit-identically, so verified and unverified runs share one
        trajectory. Only the loop backend verifies receipts — it is the
        heterogeneous/reference executor; compiled backends are covered by
        the restore-time chain verification."""
        from .commit import CommitmentError, client_commitment
        declared = [client_commitment(s["proxy"]["params"])[0]
                    for s in states]
        flat_np = np.asarray(flat)
        if self.transmit_tamper is not None:
            flat_np = np.asarray(self.transmit_tamper(np.array(flat_np), t))
            assert flat_np.shape == (self.K,) + np.shape(flat)[1:], (
                "transmit_tamper must preserve the [K, D] wire shape")
        if self.verify_commitments:
            like = states[0]["proxy"]["params"]
            for k in range(self.K):
                received, _ = client_commitment(
                    tree_unflatten_vector(jnp.asarray(flat_np[k]), like))
                if received != declared[k]:
                    raise CommitmentError(
                        f"received proxy of client {k} at round {t} does "
                        f"not match its declared commitment (declared "
                        f"{declared[k]!r}, recomputed {received!r}) — the "
                        "proxy was tampered with in flight; refusing to "
                        "mix it", round=t, client=k)
        return jnp.asarray(flat_np, flat.dtype)

    def _stack_data(self, data):
        """Padded-stacked device copy of ``data`` + per-client valid
        lengths (device + host) + per-client step counts, memoized in a
        small keyed LRU (alternating train/finetune datasets each keep
        their stacked copy instead of thrashing a single slot with a
        re-stack + re-transfer every round). Compatibility checks and the
        host-side derived arrays are computed once per dataset, not per
        round."""
        ck = id(data)
        cached = self._data_cache.get(ck)
        if cached is not None and cached[0] is data:
            self._data_cache.move_to_end(ck)
            return cached[1:]
        self._stack_misses += 1
        structs = {jax.tree_util.tree_structure(d) for d in data}
        shapes = {tuple(x.shape for x in jax.tree_util.tree_leaves(d))
                  for d in data}
        if len(structs) == 1 and len(shapes) == 1:
            # rectangular cohort (identical trees — auxiliary leaves with
            # their own leading dims included): plain stack, no padding.
            # n_valid is only well-defined when every leaf shares the
            # example axis; aux-leaf trees get None and the sampler keeps
            # its own shape-derived bound.
            stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *data)
            dims = {x.shape[0] for x in jax.tree_util.tree_leaves(data[0])
                    if getattr(x, "ndim", 0)}
            if len(dims) == 1:
                n0 = dims.pop()
                n_valid = jnp.full((len(data),), n0, jnp.int32)
                lengths = np.full(len(data), n0)
            else:
                n_valid, lengths = None, None
        elif pad_compatible(data):
            stacked, n_valid = pad_stack(data)
            lengths = np.asarray(n_valid)
        else:
            raise ValueError(
                "vmap/shard_map backends need identical per-client data "
                "trees or pad-compatible ones (one structure, equal dtypes "
                "and trailing dims; ragged LEADING dims are fine — they "
                "are padded and mask-sampled); use backend='loop' for "
                "genuinely incompatible trees")
        steps = self.client_steps(data)
        entry = (data, stacked, n_valid, lengths, steps)  # ref keeps id valid
        self._data_cache[ck] = entry
        self._data_cache.move_to_end(ck)
        while len(self._data_cache) > self._data_cache_max:
            self._data_cache.popitem(last=False)
        return entry[1:]

    def _mix_topology(self):
        """(graph topology, self-weight) realizing ``self.mix`` — mean is
        dense averaging ("full"), CWT's ring hop keeps nothing of self."""
        return {
            "pushsum": (self.cfg.topology, 0.5),
            "mean": ("full", 0.5),
            "ring": ("ring", 0.0),
            "none": (None, None),
        }[self.mix]

    def _local_phase(self, n_steps: int, step_masked: bool = False,
                     pass_n_valid: bool = True):
        """``(stacked, data, n_valid, steps, act, key) -> (trained, last)``
        — the local-update half of every stacked round program (``n_steps``
        = the scan length, i.e. the cohort-max step count), shared VERBATIM
        by the synchronous (vmap/shard_map) and stale (async) round cores
        so their local trajectories — RNG chains, batch draws, DP noise —
        are identical by construction; only the exchange differs.

        Raggedness is handled by two runtime arguments: ``n_valid`` bounds
        the sampler's index draw (padding is never sampled), and — only
        when ``step_masked`` (trace-time static: per-client step counts
        actually differ, i.e. epoch mode on a size-skewed cohort) — the
        ``steps`` array composes with the §3.4 ``active`` mask into a
        per-scan-iteration ``live`` mask: once client k has run its
        ``steps[k]`` local steps its state AND its RNG chain freeze, so it
        sits out the rest of the scan without perturbing either. Uniform-
        step rounds skip the two per-step full-state selects entirely
        (inactive clients are reverted once, after the scan, exactly as
        before), so the common fixed-``local_steps`` configuration pays
        nothing for ragged support."""
        step_fn, sample, K = self.step_fns[0], self.sample_fn, self.K
        if self._masked_sampler and pass_n_valid:
            def one(state, data_k, nv_k, key):
                key, kb, kn = jax.random.split(key, 3)
                batch = sample(data_k, kb, n_valid=nv_k)
                state, m = step_fn(state, batch, kn)
                return state, key, m
        else:
            def one(state, data_k, nv_k, key):
                key, kb, kn = jax.random.split(key, 3)
                batch = sample(data_k, kb)
                state, m = step_fn(state, batch, kn)
                return state, key, m

        def local_fn(stacked, data, n_valid, steps, act, key):
            keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
                jnp.arange(K, dtype=jnp.uint32))

            def body(carry, i):
                st, ks = carry
                st2, ks2, m = jax.vmap(one)(st, data, n_valid, ks)
                if step_masked:
                    live = act & (i < steps)
                    st2 = _tree_where(live, st2, st)  # exhausted/inactive:
                    ks2 = _tree_where(live, ks2, ks)  # state + RNG frozen
                return (st2, ks2), m

            (trained, _), ms = jax.lax.scan(
                body, (stacked, keys), jnp.arange(n_steps, dtype=jnp.int32))
            # each client's LAST EXECUTED step's metrics (matches the loop
            # backend); inactive clients report NaN
            idx = jnp.clip(steps - 1, 0, n_steps - 1)
            last = jax.tree_util.tree_map(
                lambda x: x[idx, jnp.arange(K)], ms)
            last = {k: jnp.where(act, v, jnp.nan) for k, v in last.items()}
            trained = _tree_where(act, trained, stacked)  # dropouts keep state
            return trained, last

        return local_fn

    def _round_core(self, n_steps: int, mix_op, step_masked: bool = False,
                    pass_n_valid: bool = True):
        """One traceable program for the WHOLE synchronous round: the
        shared :meth:`_local_phase` followed by one graph exchange.
        ``mix_op(flat, w, P) -> (z2, w2)`` — the DE-BIASED mixed proxies
        plus the mixed weights — is the only backend difference: the
        stacked :func:`repro.core.gossip.pushsum_mix_debiased` exchange
        (vmap — P is a runtime arg, so every round reuses one compilation;
        plain matmuls or the Pallas-fused kernel per ``cfg.use_pallas``)
        or a ppermute collective (shard_map — the schedule is baked in, P
        is unused). ``mix_op=None`` skips the exchange.

        With compression active the round program's signature grows the
        codec state (each client's public copy): ``round_fn(stacked, ef_state, data, n_valid,
        steps, P, act, key) -> (trained, ef_state', last)`` and the mix_op
        contract becomes ``mix_op(flat, w, P, ef_state, ckey) -> (z2, w2,
        ef_state')`` (``ckey`` = the codec RNG key derived from the round
        key by ``compress_round_key`` — identical on every backend).
        Uncompressed engines keep the historical signature, so their
        compiled programs are byte-for-byte unchanged."""
        local = self._local_phase(n_steps, step_masked, pass_n_valid)
        compressed = self._compressed and mix_op is not None

        def exchange(trained, P, key, ef_state):
            theta = trained["proxy"]["params"]
            like = jax.tree_util.tree_map(lambda x: x[0], theta)
            flat = jax.vmap(tree_flatten_vector)(theta)            # [K, D]
            w = jnp.asarray(trained["w"], flat.dtype)
            if compressed:
                unb, w2, ef_state = mix_op(flat, w, P, ef_state,
                                        compress_round_key(key))
            else:
                unb, w2 = mix_op(flat, w, P)                       # on-device
            theta2 = jax.vmap(
                lambda v: tree_unflatten_vector(v, like))(unb)
            trained = dict(trained)
            trained["proxy"] = dict(trained["proxy"], params=theta2)
            trained["w"] = w2.astype(jnp.result_type(trained["w"]))
            return trained, ef_state

        if compressed:
            def round_fn(stacked, ef_state, data, n_valid, steps, P, act, key):
                trained, last = local(stacked, data, n_valid, steps, act,
                                      key)
                trained, ef_state = exchange(trained, P, key, ef_state)
                return trained, ef_state, last
        else:
            def round_fn(stacked, data, n_valid, steps, P, act, key):
                trained, last = local(stacked, data, n_valid, steps, act,
                                      key)
                if mix_op is not None:
                    trained, _ = exchange(trained, P, key, None)
                return trained, last

        return round_fn

    def _stale_round_core(self, n_steps: int, mixing: bool,
                          step_masked: bool = False,
                          pass_n_valid: bool = True):
        """One traceable program for a STALE (async, τ>0) round: the shared
        :meth:`_local_phase`, then the delayed exchange of
        ``repro.core.gossip.stale_gossip_reference`` — re-bias θ = z·w,
        keep ``kept(t)``·θ, push ``sent(t) @ θ`` into the τ-deep in-flight
        buffer, merge the round-(t−τ) delivery rotating out of it, and
        de-bias by the identically-delayed weights. ``kept``/``sent`` are
        runtime arguments (one compilation serves every round and every
        membership pattern); the buffer rows travel with the state so the
        same core replays bit-identically per-round, blocked, or across a
        kill/resume. Inactive clients keep ``kept=1``/zero ``sent``
        columns (they hold their mass and send nothing) but still merge
        arriving mail — in-flight PushSum mass is never dropped.

        With compression active the signature grows the codec state
        exactly as in :meth:`_round_core`: ``round_fn(stacked,
        buf_t, buf_w, ef_state, ...) -> (trained, buf_t, buf_w, ef_state',
        last)`` — the public copy tracks the raw PushSum numerator
        θ = z·w (the quantity that enters the in-flight buffer), the
        de-bias weights stay uncompressed, so w-mass conservation is
        exact at any τ."""
        local = self._local_phase(n_steps, step_masked, pass_n_valid)
        compressed = self._compressed and mixing

        def exchange(trained, buf_t, buf_w, kept, sent, key, ef_state):
            theta_tree = trained["proxy"]["params"]
            like = jax.tree_util.tree_map(lambda x: x[0], theta_tree)
            flat = jax.vmap(tree_flatten_vector)(theta_tree)       # [K, D]
            w = jnp.asarray(trained["w"], flat.dtype)
            if compressed:
                unb, send_t, w2, send_w, ef_state = stale_mix_apply(
                    flat, w, kept, sent, buf_t[0], buf_w[0],
                    use_pallas=self.use_pallas, compress=self.compress,
                    ef_state=ef_state, key=compress_round_key(key))
            else:
                unb, send_t, w2, send_w = stale_mix_apply(
                    flat, w, kept, sent, buf_t[0], buf_w[0],
                    use_pallas=self.use_pallas)
            buf_t = jnp.concatenate([buf_t[1:], send_t[None]])
            buf_w = jnp.concatenate([buf_w[1:], send_w[None]])
            theta2 = jax.vmap(
                lambda v: tree_unflatten_vector(v, like))(unb)
            trained = dict(trained)
            trained["proxy"] = dict(trained["proxy"], params=theta2)
            trained["w"] = w2.astype(jnp.result_type(trained["w"]))
            return trained, buf_t, buf_w, ef_state

        if compressed:
            def round_fn(stacked, buf_t, buf_w, ef_state, data, n_valid,
                         steps, kept, sent, act, key):
                trained, last = local(stacked, data, n_valid, steps, act,
                                      key)
                trained, buf_t, buf_w, ef_state = exchange(
                    trained, buf_t, buf_w, kept, sent, key, ef_state)
                return trained, buf_t, buf_w, ef_state, last
        else:
            def round_fn(stacked, buf_t, buf_w, data, n_valid, steps, kept,
                         sent, act, key):
                trained, last = local(stacked, data, n_valid, steps, act,
                                      key)
                if mixing:
                    trained, buf_t, buf_w, _ = exchange(
                        trained, buf_t, buf_w, kept, sent, key, None)
                return trained, buf_t, buf_w, last

        return round_fn

    def _stale_split(self, t: int, act):
        """Runtime (kept[K], sent[K,K]) arguments of one stale round."""
        kept, sent = stale_mix_split(
            mix_matrix(self.mix, t, self.K, self.cfg.topology, act))
        return jnp.asarray(kept, jnp.float32), jnp.asarray(sent, jnp.float32)

    def _round_stale(self, state, data, t, key, act):
        data_s, n_valid, pass_nv, n_steps, step_masked, steps_dev = \
            self._stacked_inputs(data)
        act_arr = jnp.asarray(np.ones(self.K, bool) if act is None else act)
        mixing = self.mix != "none" and self.K > 1
        rkey = ("async", n_steps, step_masked, pass_nv, mixing)
        if rkey not in self._rounds:
            ndon = 4 if (self._compressed and mixing) else 3
            self._rounds[rkey] = jax.jit(
                self._stale_round_core(n_steps, mixing, step_masked,
                                       pass_nv),
                donate_argnums=tuple(range(ndon)) if self._donate else ())
        if mixing:
            kept, sent = self._stale_split(t, act)
        else:  # placeholders, never read
            kept = jnp.zeros((self.K,), jnp.float32)
            sent = jnp.zeros((self.K, self.K), jnp.float32)
        if self._compressed and mixing:
            clients, buf_t, buf_w, ef_state, last = self._rounds[rkey](
                state["clients"], state["stale_theta"], state["stale_w"],
                state["ef_state"], data_s, n_valid, steps_dev, kept, sent,
                act_arr, key)
            out = {"clients": clients, "stale_theta": buf_t,
                   "stale_w": buf_w, "ef_state": ef_state}
        else:
            clients, buf_t, buf_w, last = self._rounds[rkey](
                state["clients"], state["stale_theta"], state["stale_w"],
                data_s, n_valid, steps_dev, kept, sent, act_arr, key)
            out = {"clients": clients, "stale_theta": buf_t,
                   "stale_w": buf_w}
            if self._compressed:  # mix-less round: codec state untouched
                out["ef_state"] = state["ef_state"]
        metrics = {k: np.asarray(v) for k, v in last.items()}
        return out, metrics

    def _rounds_block_stale(self, state, data, t0, T, key, act_sched):
        """Async round-block: ONE compiled outer ``lax.scan`` over rounds
        whose carry holds the stacked client states AND the rotating
        in-flight buffer — rounds genuinely interleave inside the block
        (round t's local scan runs while its delivery, recorded τ rounds
        earlier, is already in the carry), and the host sees only the
        block edge. The per-round (kept, sent) splits arrive stacked as
        runtime arguments (``stale_mix_schedule``), keys fold in-scan, so
        any block size replays the per-round trajectory bit-exactly."""
        data_s, n_valid, pass_nv, n_steps, step_masked, steps_dev = \
            self._stacked_inputs(data)
        act_stack = (np.ones((T, self.K), bool) if act_sched is None
                     else act_sched)
        mixing = self.mix != "none" and self.K > 1
        compressed = self._compressed and mixing
        rkey = ("async_block", T, n_steps, step_masked, pass_nv, mixing)
        if rkey not in self._rounds:
            core = self._stale_round_core(n_steps, mixing, step_masked,
                                          pass_nv)

            if compressed:
                def block_fn(stacked, buf_t, buf_w, ef_state, data, n_valid,
                             steps, kepts, sents, acts, ts, base_key):
                    def body(carry, xs):
                        st, bt, bw, r = carry
                        kept, sent, a, t = xs
                        st, bt, bw, r, last = core(
                            st, bt, bw, r, data, n_valid, steps, kept,
                            sent, a, round_key(base_key, t))
                        return (st, bt, bw, r), last

                    (st, bt, bw, r), ms = jax.lax.scan(
                        body, (stacked, buf_t, buf_w, ef_state),
                        (kepts, sents, acts, ts))
                    return st, bt, bw, r, ms

                ndon = 4
            else:
                def block_fn(stacked, buf_t, buf_w, data, n_valid, steps,
                             kepts, sents, acts, ts, base_key):
                    def body(carry, xs):
                        st, bt, bw = carry
                        kept, sent, a, t = xs
                        st, bt, bw, last = core(st, bt, bw, data, n_valid,
                                                steps, kept, sent, a,
                                                round_key(base_key, t))
                        return (st, bt, bw), last

                    (st, bt, bw), ms = jax.lax.scan(
                        body, (stacked, buf_t, buf_w),
                        (kepts, sents, acts, ts))
                    return st, bt, bw, ms

                ndon = 3
            self._rounds[rkey] = jax.jit(
                block_fn,
                donate_argnums=tuple(range(ndon)) if self._donate else ())
        if mixing:
            kepts, sents = stale_mix_schedule(
                self.mix, t0, T, self.K, self.cfg.topology,
                active=act_sched)
            kepts = jnp.asarray(kepts, jnp.float32)
            sents = jnp.asarray(sents, jnp.float32)
        else:
            kepts = jnp.zeros((T, self.K), jnp.float32)
            sents = jnp.zeros((T, self.K, self.K), jnp.float32)
        ts = jnp.arange(t0, t0 + T, dtype=jnp.int32)
        if compressed:
            clients, buf_t, buf_w, ef_state, ms = self._rounds[rkey](
                state["clients"], state["stale_theta"], state["stale_w"],
                state["ef_state"], data_s, n_valid, steps_dev, kepts, sents,
                jnp.asarray(act_stack), ts, key)
            out = {"clients": clients, "stale_theta": buf_t,
                   "stale_w": buf_w, "ef_state": ef_state}
        else:
            clients, buf_t, buf_w, ms = self._rounds[rkey](
                state["clients"], state["stale_theta"], state["stale_w"],
                data_s, n_valid, steps_dev, kepts, sents,
                jnp.asarray(act_stack), ts, key)
            out = {"clients": clients, "stale_theta": buf_t,
                   "stale_w": buf_w}
            if self._compressed:  # mix-less block: codec state untouched
                out["ef_state"] = state["ef_state"]
        return out, self._finish_block(ms, act_stack, data)

    # -- hier backend (two-level factored exchange) --------------------------

    def _hier_round_core(self, n_steps: int, mixing: bool,
                         step_masked: bool = False,
                         pass_n_valid: bool = True):
        """One traceable program for a HIER round: the shared
        :meth:`_local_phase` VERBATIM (local trajectories — RNG chains,
        batch draws, DP noise — bit-identical to vmap by construction),
        then the factored two-level exchange. The factored schedule
        ``(blocks[S, L, L], src[K], scale[K])`` arrives as runtime
        arguments (one compilation serves every round and membership
        pattern). At τ=0 the exchange is
        :func:`repro.core.gossip.hier_mix_debiased` — synchronous, and
        bit-identical to the dense vmap exchange on the same P; at τ>0 it
        is :func:`repro.core.gossip.hier_stale_mix_apply` with the
        cross-shard buffer rows in the signature, rotated here exactly
        like the async buffer. Client states keep the flat [K, ...]
        layout throughout — the shard reshape is internal to the
        exchange — which is what keeps checkpoints backend-portable and
        the data stacking layout-independent."""
        local = self._local_phase(n_steps, step_masked, pass_n_valid)
        up, tau = self.use_pallas, self.staleness

        def exchange(trained, blocks, src, scale, buf_t, buf_w):
            theta_tree = trained["proxy"]["params"]
            like = jax.tree_util.tree_map(lambda x: x[0], theta_tree)
            flat = jax.vmap(tree_flatten_vector)(theta_tree)       # [K, D]
            w = jnp.asarray(trained["w"], flat.dtype)
            if tau:
                unb, send_t, w2, send_w = hier_stale_mix_apply(
                    flat, w, blocks, src, scale, buf_t[0], buf_w[0],
                    use_pallas=up)
                buf_t = jnp.concatenate([buf_t[1:], send_t[None]])
                buf_w = jnp.concatenate([buf_w[1:], send_w[None]])
            else:
                unb, w2 = hier_mix_debiased(flat, w, blocks, src, scale,
                                            use_pallas=up)
            theta2 = jax.vmap(
                lambda v: tree_unflatten_vector(v, like))(unb)
            trained = dict(trained)
            trained["proxy"] = dict(trained["proxy"], params=theta2)
            trained["w"] = w2.astype(jnp.result_type(trained["w"]))
            return trained, buf_t, buf_w

        if tau:
            def round_fn(stacked, buf_t, buf_w, data, n_valid, steps,
                         blocks, src, scale, act, key):
                trained, last = local(stacked, data, n_valid, steps, act,
                                      key)
                if mixing:
                    trained, buf_t, buf_w = exchange(
                        trained, blocks, src, scale, buf_t, buf_w)
                return trained, buf_t, buf_w, last
        else:
            def round_fn(stacked, data, n_valid, steps, blocks, src, scale,
                         act, key):
                trained, last = local(stacked, data, n_valid, steps, act,
                                      key)
                if mixing:
                    trained, _, _ = exchange(trained, blocks, src, scale,
                                             None, None)
                return trained, last

        return round_fn

    def _hier_split(self, t: int, act):
        """Runtime (blocks, src, scale) device arguments of one hier
        round's factored exchange."""
        blocks, src, scale = hier_mix_split(
            mix_matrix(self.mix, t, self.K, self.cfg.topology, act),
            self.n_shards)
        return (jnp.asarray(blocks, jnp.float32),
                jnp.asarray(src, jnp.int32),
                jnp.asarray(scale, jnp.float32))

    def _hier_placeholders(self, T: int = 0):
        """Never-read factored-schedule placeholders for mix-less rounds."""
        S = self.n_shards
        L = self.K // S
        lead = () if T == 0 else (T,)
        return (jnp.zeros(lead + (S, L, L), jnp.float32),
                jnp.zeros(lead + (self.K,), jnp.int32),
                jnp.zeros(lead + (self.K,), jnp.float32))

    def _round_hier(self, state, data, t, key, act):
        data_s, n_valid, pass_nv, n_steps, step_masked, steps_dev = \
            self._stacked_inputs(data)
        act_arr = jnp.asarray(np.ones(self.K, bool) if act is None else act)
        mixing = self.mix != "none" and self.K > 1
        tau = self.staleness
        rkey = ("hier", n_steps, step_masked, pass_nv, mixing)
        if rkey not in self._rounds:
            donate = (tuple(range(3)) if tau else (0,)) if self._donate \
                else ()
            self._rounds[rkey] = jax.jit(
                self._hier_round_core(n_steps, mixing, step_masked,
                                      pass_nv),
                donate_argnums=donate)
        blocks, src, scale = (self._hier_split(t, act) if mixing
                              else self._hier_placeholders())
        if tau:
            clients, buf_t, buf_w, last = self._rounds[rkey](
                state["clients"], state["hier_buffer"], state["hier_w"],
                data_s, n_valid, steps_dev, blocks, src, scale, act_arr,
                key)
            out: Any = {"clients": clients, "hier_buffer": buf_t,
                        "hier_w": buf_w}
        else:
            out, last = self._rounds[rkey](
                self._clients_of(state), data_s, n_valid, steps_dev,
                blocks, src, scale, act_arr, key)
        metrics = {k: np.asarray(v) for k, v in last.items()}
        return out, metrics

    def _rounds_block_hier(self, state, data, t0, T, key, act_sched):
        """Hier round-block: ONE compiled outer ``lax.scan`` over rounds,
        consuming the block's stacked factored schedules
        (``hier_mix_schedule``: blocks[T, S, L, L] + src/scale[T, K]) as
        runtime arguments; at τ>0 the cross-shard in-flight buffer joins
        the scan carry exactly like the async buffer, so rounds
        interleave inside the block and the host sees only the edge.
        Keys fold in-scan — any block size replays the per-round
        trajectory bit-exactly."""
        data_s, n_valid, pass_nv, n_steps, step_masked, steps_dev = \
            self._stacked_inputs(data)
        act_stack = (np.ones((T, self.K), bool) if act_sched is None
                     else act_sched)
        mixing = self.mix != "none" and self.K > 1
        tau = self.staleness
        rkey = ("hier_block", T, n_steps, step_masked, pass_nv, mixing)
        if rkey not in self._rounds:
            core = self._hier_round_core(n_steps, mixing, step_masked,
                                         pass_nv)

            if tau:
                def block_fn(stacked, buf_t, buf_w, data, n_valid, steps,
                             blockss, srcs, scales, acts, ts, base_key):
                    def body(carry, xs):
                        st, bt, bw = carry
                        bl, sr, sc, a, t = xs
                        st, bt, bw, last = core(
                            st, bt, bw, data, n_valid, steps, bl, sr, sc,
                            a, round_key(base_key, t))
                        return (st, bt, bw), last

                    (st, bt, bw), ms = jax.lax.scan(
                        body, (stacked, buf_t, buf_w),
                        (blockss, srcs, scales, acts, ts))
                    return st, bt, bw, ms

                donate = tuple(range(3)) if self._donate else ()
            else:
                def block_fn(stacked, data, n_valid, steps, blockss, srcs,
                             scales, acts, ts, base_key):
                    def body(st, xs):
                        bl, sr, sc, a, t = xs
                        st2, last = core(st, data, n_valid, steps, bl, sr,
                                         sc, a, round_key(base_key, t))
                        return st2, last

                    return jax.lax.scan(
                        body, stacked, (blockss, srcs, scales, acts, ts))

                donate = self._donate
            self._rounds[rkey] = jax.jit(block_fn, donate_argnums=donate)
        if mixing:
            blockss, srcs, scales = hier_mix_schedule(
                self.mix, t0, T, self.K, self.n_shards, self.cfg.topology,
                active=act_sched)
            blockss = jnp.asarray(blockss, jnp.float32)
            srcs = jnp.asarray(srcs, jnp.int32)
            scales = jnp.asarray(scales, jnp.float32)
        else:
            blockss, srcs, scales = self._hier_placeholders(T)
        ts = jnp.arange(t0, t0 + T, dtype=jnp.int32)
        if tau:
            clients, buf_t, buf_w, ms = self._rounds[rkey](
                state["clients"], state["hier_buffer"], state["hier_w"],
                data_s, n_valid, steps_dev, blockss, srcs, scales,
                jnp.asarray(act_stack), ts, key)
            out: Any = {"clients": clients, "hier_buffer": buf_t,
                        "hier_w": buf_w}
        else:
            out, ms = self._rounds[rkey](
                self._clients_of(state), data_s, n_valid, steps_dev,
                blockss, srcs, scales, jnp.asarray(act_stack), ts, key)
        return out, self._finish_block(ms, act_stack, data)

    def _build_round(self, n_steps: int, mix_op, step_masked: bool = False,
                     pass_n_valid: bool = True):
        """Jitted single-round program (the ``run_round`` fast path)."""
        donate = self._donate
        if donate and self._compressed and mix_op is not None:
            donate = (0, 1)  # stacked state AND the codec state in place
        return jax.jit(self._round_core(n_steps, mix_op, step_masked,
                                        pass_n_valid),
                       donate_argnums=donate)

    def _build_block(self, n_rounds: int, n_steps: int, mix_ops,
                     step_masked: bool = False, pass_n_valid: bool = True):
        """One jitted program for a WHOLE round-block (``n_rounds`` federated
        rounds, host re-entered only at the block edge).

        ``mix_ops`` is either ONE mix_op shared by every round — the vmap
        matmul path, where the per-round exchange matrix arrives as the
        runtime-stacked ``Ps[T, K, K]`` and the block is a ``lax.scan`` over
        rounds (one compilation serves every block of this shape) — or a
        length-``n_rounds`` sequence of per-round static ops (shard_map,
        whose ppermute schedules are trace-time static: the block is a
        Python-unrolled sequence of round bodies inside one jit, exactly
        the per-round collective schedules fused end to end).

        Per-round RNG keys are folded IN-SCAN from the base key
        (``round_key(base_key, t)`` with the runtime ``ts`` round indices),
        so a blocked run replays the per-round key schedule bit-exactly."""
        donate = self._donate
        if not isinstance(mix_ops, (list, tuple)):
            core = self._round_core(n_steps, mix_ops, step_masked,
                                    pass_n_valid)

            if self._compressed and mix_ops is not None:
                # compressed vmap block: the codec state joins the scan
                # carry exactly like the async τ-buffer, so any block size
                # replays the per-round public-copy trajectory bit-exactly
                def block_fn(stacked, ef_state, data, n_valid, steps, Ps,
                             acts, ts, base_key):
                    def body(carry, xs):
                        st, r = carry
                        P, act, t = xs
                        st2, r2, last = core(st, r, data, n_valid, steps,
                                             P, act, round_key(base_key, t))
                        return (st2, r2), last

                    (st, r), ms = jax.lax.scan(
                        body, (stacked, ef_state), (Ps, acts, ts))
                    return st, r, ms

                if donate:
                    donate = (0, 1)
            else:
                def block_fn(stacked, data, n_valid, steps, Ps, acts, ts,
                             base_key):
                    def body(st, xs):
                        P, act, t = xs
                        st2, last = core(st, data, n_valid, steps, P, act,
                                         round_key(base_key, t))
                        return st2, last

                    return jax.lax.scan(body, stacked, (Ps, acts, ts))
        else:
            cores = [self._round_core(n_steps, op, step_masked, pass_n_valid)
                     for op in mix_ops]

            def block_fn(stacked, data, n_valid, steps, Ps, acts, ts,
                         base_key):
                lasts = []
                for i, core in enumerate(cores):
                    stacked, last = core(stacked, data, n_valid, steps,
                                         Ps[i], acts[i],
                                         round_key(base_key, ts[i]))
                    lasts.append(last)
                stacked_ms = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *lasts)
                return stacked, stacked_ms

        return jax.jit(block_fn, donate_argnums=donate)

    def _mix_matmul_op(self):
        """The stacked matmul exchange as a mix_op: ``(flat, w, P) ->
        (z2, w2)`` de-biased, dispatched plain-XLA or Pallas-fused per
        ``cfg.use_pallas``; with compression active the contract grows the
        codec-state/key operands (``(flat, w, P, ef_state, ckey) -> (z2,
        w2, ef_state2)``) and the exchange takes the compressed plain-XLA path
        (``use_pallas`` is a no-op there — the fused kernel is
        uncompressed-only). One definition serves the single-round and
        round-block programs so the two paths cannot drift."""
        up = self.use_pallas
        if self._compressed:
            spec = self.compress
            return lambda flat, w, P, ef_state, ckey: pushsum_mix_debiased(
                flat, w, P, use_pallas=up, compress=spec, ef_state=ef_state,
                key=ckey)
        return lambda flat, w, P: pushsum_mix_debiased(flat, w, P,
                                                       use_pallas=up)

    def _shard_mix_op(self, t: int, act_key):
        """ppermute exchange along ``self.axis``; t/active are trace-time
        static (new collective schedule per membership pattern). The
        collective returns pre-debias (mixed, w2); the de-bias divide
        happens here so the mix_op contract matches the matmul path."""
        topo, sw = self._mix_topology()
        spec = jax.sharding.PartitionSpec(self.axis)
        gossip_sm = shard_map_fn(
            lambda f, w: pushsum_gossip_shard(
                f, w, t, self.axis, self.K, topo, sw, active=act_key),
            self.mesh, in_specs=(spec, spec), out_specs=(spec, spec))

        def op(flat, w, P):
            mixed, w2 = gossip_sm(flat, w)
            return mixed / w2[:, None], w2

        return op

    def _stacked_inputs(self, data):
        """Shared prologue of the stacked round/block programs: padded
        device copy, masked-sampler validation, scan length and step-mask
        staticness derived from the per-client step counts."""
        data_s, n_valid, lengths, steps_arr = self._stack_data(data)
        if lengths is not None and (lengths != lengths[0]).any() \
                and not self._masked_sampler:
            raise ValueError(
                "ragged per-client datasets on the stacked path need a "
                "masked sampler: sample_fn must accept (data_k, key, "
                "n_valid) so padding is never drawn (see "
                "repro.core.engine.classifier_sampler)")
        pass_nv = n_valid is not None
        if n_valid is None:  # aux-leaf rectangular tree: dummy, never read
            n_valid = jnp.zeros((self.K,), jnp.int32)
        n_steps = int(steps_arr.max())
        # trace-time static: per-step state/RNG masking is only needed when
        # clients genuinely run different step counts (epoch mode on a
        # size-skewed cohort); uniform rounds keep the mask-free body
        step_masked = bool((steps_arr != steps_arr[0]).any())
        return data_s, n_valid, pass_nv, n_steps, step_masked, \
            jnp.asarray(steps_arr)

    def _round_stacked(self, state, data, t, key, act):
        data_s, n_valid, pass_nv, n_steps, step_masked, steps_dev = \
            self._stacked_inputs(data)
        stacked = self._clients_of(state)
        act_arr = jnp.asarray(np.ones(self.K, bool) if act is None else act)
        mixing = self.mix != "none" and self.K > 1
        P = jnp.zeros((0,))  # placeholder when no matmul mix runs
        if self.backend != "shard_map":  # vmap, or async at staleness=0
            rkey = ("vmap", n_steps, step_masked, pass_nv)
            if rkey not in self._rounds:
                self._rounds[rkey] = self._build_round(
                    n_steps, self._mix_matmul_op() if mixing else None,
                    step_masked, pass_nv)
            if mixing:
                P = jnp.asarray(
                    mix_matrix(self.mix, t, self.K, self.cfg.topology, act),
                    jnp.float32)
        else:
            A = self.K if act is None else int(act.sum())
            topo, _ = self._mix_topology()
            # cache key: the ppermute schedule is fully determined by the
            # (mix-mapped) shift and the membership pattern
            shift = gossip_shift(t, A, topo) if mixing else None
            act_key = None if act is None else tuple(bool(a) for a in act)
            rkey = ("shard", n_steps, shift, act_key, self.mix, step_masked,
                    pass_nv)
            if rkey not in self._rounds:
                self._rounds[rkey] = self._build_round(
                    n_steps,
                    self._shard_mix_op(t, act_key) if mixing else None,
                    step_masked, pass_nv)
        if self._compressed and mixing:
            stacked, ef_state, last = self._rounds[rkey](
                stacked, state["ef_state"], data_s, n_valid, steps_dev, P,
                act_arr, key)
            out: Any = {"clients": stacked, "ef_state": ef_state}
        else:
            stacked, last = self._rounds[rkey](
                stacked, data_s, n_valid, steps_dev, P, act_arr, key)
            out = ({"clients": stacked, "ef_state": state["ef_state"]}
                   if self._compressed else stacked)
        metrics = {k: np.asarray(v) for k, v in last.items()}
        return out, metrics


# ---------------------------------------------------------------------------
# factories: classifier-scale engines built from ModelSpecs


def classifier_sampler(batch_size: int) -> SampleFn:
    """Uniform-with-replacement batch draw from (x, y) — the historical
    client sampling used by ``local_round``/``_ce_local_round``.

    Masked: on the stacked (padded) path the engine passes the client's
    true length ``n_valid`` and indices are drawn ``randint(0, n_valid)``,
    so padding rows are never sampled. Without it (loop backend, where the
    data is unpadded) the bound is ``x.shape[0]`` — the same value, so
    loop and vmap draw identical batches on ragged cohorts."""

    def sample(data_k, kb, n_valid=None):
        x, y = data_k
        hi = x.shape[0] if n_valid is None else n_valid
        idx = jax.random.randint(kb, (batch_size,), 0, hi)
        return (x[idx], y[idx])

    return sample


def _dml_state_step(private_spec, proxy_spec, cfg: ProxyFLConfig) -> StepFn:
    from .protocol import dml_step_fn
    raw = dml_step_fn(private_spec, proxy_spec, cfg)

    def step(state, batch, key):
        phi, opt_phi, theta, opt_theta, m = raw(
            state["private"]["params"], state["private"]["opt"],
            state["proxy"]["params"], state["proxy"]["opt"], batch, key)
        return {"private": {"params": phi, "opt": opt_phi},
                "proxy": {"params": theta, "opt": opt_theta},
                "w": state["w"]}, m

    return step


def _dml_state_init(private_spec, proxy_spec, cfg: ProxyFLConfig) -> InitFn:
    opt = Adam(lr=cfg.lr, weight_decay=cfg.weight_decay)

    def init(key):
        kf, kh = jax.random.split(key)
        phi = private_spec.init(kf)
        theta = proxy_spec.init(kh)
        return {"private": {"params": phi, "opt": opt.init(phi)},
                "proxy": {"params": theta, "opt": opt.init(theta)},
                "w": jnp.ones((), jnp.float32)}

    return init


def _ce_state_step(spec, cfg: ProxyFLConfig, dp: bool) -> StepFn:
    from .protocol import ce_step_fn
    raw = ce_step_fn(spec, cfg, dp)

    def step(state, batch, key):
        params, opt, loss = raw(state["proxy"]["params"],
                                state["proxy"]["opt"], batch, key)
        return {"proxy": {"params": params, "opt": opt},
                "w": state["w"]}, {"loss": loss}

    return step


def _ce_state_init(spec, cfg: ProxyFLConfig) -> InitFn:
    opt = Adam(lr=cfg.lr, weight_decay=cfg.weight_decay)

    def init(key):
        params = spec.init(key)
        return {"proxy": {"params": params, "opt": opt.init(params)},
                "w": jnp.ones((), jnp.float32)}

    return init


@functools.lru_cache(maxsize=8)
def dml_engine(private_specs: Tuple, proxy_spec, cfg: ProxyFLConfig,
               backend: str = "auto", mix: str = "pushsum"
               ) -> FederationEngine:
    """Engine for the two-model (private+proxy DML) family: ProxyFL
    (mix="pushsum") and FML (mix="mean"). ``backend="auto"`` picks vmap
    for homogeneous cohorts — including ragged (size-skewed) datasets,
    which the stacked path pads and mask-samples — and loop only for
    heterogeneous private architectures. A small LRU lets repeated
    federations with the same specs reuse compiled round programs without
    pinning every sweep configuration's engine (and its device-resident
    stacked data) in memory forever."""
    K = len(private_specs)
    homogeneous = all(s == private_specs[0] for s in private_specs)
    if backend == "auto":
        backend = "vmap" if homogeneous else "loop"
    if homogeneous:
        step_fns: Any = _dml_state_step(private_specs[0], proxy_spec, cfg)
        init_fns: Any = _dml_state_init(private_specs[0], proxy_spec, cfg)
    else:
        step_fns = [_dml_state_step(s, proxy_spec, cfg) for s in private_specs]
        init_fns = [_dml_state_init(s, proxy_spec, cfg) for s in private_specs]
    return FederationEngine(
        cfg, n_clients=K, step_fns=step_fns, init_fns=init_fns,
        sample_fn=classifier_sampler(cfg.batch_size), backend=backend, mix=mix)


@functools.lru_cache(maxsize=8)
def single_model_engine(spec, cfg: ProxyFLConfig, dp: bool,
                        mix: str = "mean", backend: str = "auto",
                        n_clients: int = 0) -> FederationEngine:
    """Engine for the single-model baselines: FedAvg (mix="mean"), AvgPush
    ("pushsum"), CWT ("ring"), Regular/Joint ("none"). The model lives in
    the gossiped ``proxy`` slot of the engine state."""
    K = n_clients or cfg.n_clients
    return FederationEngine(
        cfg, n_clients=K,
        step_fns=_ce_state_step(spec, cfg, dp),
        init_fns=_ce_state_init(spec, cfg),
        sample_fn=classifier_sampler(cfg.batch_size),
        backend="vmap" if backend == "auto" else backend, mix=mix)
