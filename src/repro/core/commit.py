"""Per-round proxy commitments — the hash-chained audit trail.

The paper targets regulated domains (finance/healthcare) where a
participant must be able to prove that the proxy it gossips is the proxy
it trained. This module is the commitment layer under that claim, modeled
on chunked per-round parameter commitments (FL-ZKP style) without
committing to a full ZKP stack:

* every RELEASED proxy is committed to by a **client commitment** — a
  sha256 over the sorted ``(leaf path, chunked leaf digest)`` pairs of its
  parameter tree, where each leaf digest is a sha256 over fixed-size-chunk
  sha256 digests of the leaf's canonical bytes (the same dtype
  canonicalization ``save_checkpoint`` applies, so a commitment computed
  from live state and one recomputed from the ``.npz`` agree bit-for-bit);
* snapshots form a **hash chain** ``h_t = H(h_{t-1} || round metadata ||
  client commitments)`` anchored at :data:`GENESIS` — rewriting any past
  round breaks every later link;
* mismatches raise :class:`CommitmentError` (a distinct error from the
  config-fingerprint mismatch) naming the first divergent round and, for
  leaf-level tampering, the offending leaf path.

Everything here is host-side ``hashlib`` + ``numpy`` over the
backend-portable canonical payload (the per-client layout
``FederationEngine.save_state`` gathers), so commitments are
backend-invariant by construction: loop, vmap and hier snapshots of the
same state hash identically. The chain's round metadata deliberately
contains only backend-invariant facts (``rounds_done``, ``n_clients``) —
run-identity (lr, DP budget, architectures, ...) is the config
fingerprint's job, checked separately with its own error.

Consumers: :class:`repro.checkpoint.federation.FederationCheckpointer`
(stamps ``commitment``/``prev_commitment`` into every ``.meta.json``,
appends to ``audit.jsonl``, verifies on restore) and the loop backend of
:class:`repro.core.engine.FederationEngine` (verifies received-proxy
digests against the sender's declared commitment before mixing, under
``cfg.verify_commitments``).
"""
from __future__ import annotations

import hashlib
import json
from typing import Any, Dict, Optional, Tuple

import numpy as np

from ..checkpoint.ckpt import flatten_with_paths

# Chain anchor: h_0's predecessor. A fixed public constant (not a secret,
# not per-run) — the chain's security comes from the links, not the root.
GENESIS = "0" * 64

# Leaves are digested in fixed 1 MiB chunks of their canonical bytes and
# the chunk digests are hashed together (FL-ZKP style chunked commitment):
# large proxies stream through sha256 without a monolithic buffer, and a
# future Merkle/ZKP layer can open single chunks without rehashing the
# whole tensor. The chunk size is part of the commitment definition —
# changing it changes every digest, so it is a named constant, not a knob.
CHUNK_BYTES = 1 << 20

# Key-path namespace of the committed leaves inside a snapshot payload:
# clients/c0042/proxy/params/<leaf...> — only the RELEASED proxy is
# committed (private models never leave the client and are deliberately
# outside the audit trail).
CLIENT_KEY_FMT = "c{:04d}"
PROXY_PREFIX = "proxy/params/"


class CommitmentError(ValueError):
    """A proxy commitment failed verification.

    Distinct from the config-fingerprint ``ValueError`` so callers (and
    tests) can tell *state tampering* apart from *configuration drift*.
    ``round`` is the first divergent rounds_done (None when the failure is
    not round-specific), ``leaf`` the offending leaf path within the
    client's proxy tree, ``client`` the client index — whichever are known.
    """

    def __init__(self, message: str, *, round: Optional[int] = None,
                 leaf: Optional[str] = None, client: Optional[int] = None):
        super().__init__(message)
        self.round = round
        self.leaf = leaf
        self.client = client


def canon_array(v) -> np.ndarray:
    """The canonical array a leaf is committed to — byte-identical to what
    ``save_checkpoint`` writes into the ``.npz`` (bf16/exotic dtypes widen
    losslessly to f32), so live-state commitments and npz-recomputed
    commitments always agree."""
    a = np.asarray(v)
    if a.dtype.kind not in "fiub" or str(a.dtype) == "bfloat16":
        a = a.astype(np.float32)
    return np.ascontiguousarray(a)


def leaf_digest(arr, chunk_bytes: int = CHUNK_BYTES) -> str:
    """Chunked sha256 digest of one leaf.

    The outer hash covers a shape/dtype header plus the sha256 of every
    ``chunk_bytes``-sized slice of the canonical bytes — two tensors with
    the same bytes but different shapes (or dtypes) digest differently.
    """
    a = canon_array(arr)
    outer = hashlib.sha256()
    outer.update(f"{a.dtype.str}|{a.shape}|{chunk_bytes}".encode())
    raw = a.tobytes()
    for off in range(0, max(len(raw), 1), chunk_bytes):
        outer.update(hashlib.sha256(raw[off:off + chunk_bytes]).digest())
    return outer.hexdigest()


def proxy_leaves(proxy_params) -> Dict[str, Any]:
    """``{leaf path: array}`` of a client's released proxy parameters,
    under the same '/'-joined key paths the checkpoint npz uses (relative
    to the ``proxy/params/`` namespace)."""
    return flatten_with_paths(proxy_params)


def client_commitment(proxy_params) -> Tuple[str, Dict[str, str]]:
    """Commitment of one client's released proxy: sha256 over the sorted
    ``(leaf path, leaf digest)`` pairs. Returns ``(digest, per-leaf
    digests)`` — the per-leaf dict is what the audit trail records so a
    later verifier can name the exact divergent leaf."""
    leaves = {path: leaf_digest(a)
              for path, a in proxy_leaves(proxy_params).items()}
    blob = json.dumps(leaves, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest(), leaves


def chain_step(prev: str, rounds_done: int, n_clients: int,
               client_digests: Dict[str, str]) -> str:
    """One link of the snapshot hash chain:
    ``h_t = H(h_{t-1} || {rounds_done, n_clients} || client commitments)``.

    ``client_digests`` maps ``c0042``-style client keys to their
    :func:`client_commitment` digests. The metadata is restricted to
    backend-invariant facts — see the module docstring.
    """
    blob = json.dumps({"prev": prev,
                       "meta": {"rounds_done": int(rounds_done),
                                "n_clients": int(n_clients)},
                       "clients": client_digests},
                      sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


def npz_client_leaves(arrays: Dict[str, Any], k: int) -> Dict[str, Any]:
    """Extract client ``k``'s committed proxy leaves from a flat snapshot
    mapping (an open ``np.load`` handle or plain dict keyed by the
    '/'-joined payload paths), re-keyed relative to ``proxy/params/`` so
    the digests line up with :func:`client_commitment`'s."""
    prefix = f"clients/{CLIENT_KEY_FMT.format(k)}/{PROXY_PREFIX}"
    return {key[len(prefix):]: arrays[key]
            for key in arrays if key.startswith(prefix)}


def snapshot_client_digests(arrays: Dict[str, Any], n_clients: int
                            ) -> Tuple[Dict[str, str], Dict[str, Dict[str, str]]]:
    """Per-client commitments of a whole snapshot's released proxies.

    Returns ``(digests, leaf_digests)``: ``digests[c0042]`` is the client
    commitment, ``leaf_digests[c0042][path]`` the chunked per-leaf digests
    behind it (recorded in the audit trail for leaf-naming refusals).
    """
    digests: Dict[str, str] = {}
    leaves_out: Dict[str, Dict[str, str]] = {}
    for k in range(n_clients):
        ckey = CLIENT_KEY_FMT.format(k)
        leaves = {path: leaf_digest(a)
                  for path, a in npz_client_leaves(arrays, k).items()}
        blob = json.dumps(leaves, sort_keys=True).encode()
        digests[ckey] = hashlib.sha256(blob).hexdigest()
        leaves_out[ckey] = leaves
    return digests, leaves_out
