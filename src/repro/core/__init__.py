"""ProxyFL core: the paper's contribution as composable JAX modules.

- ``dp``          DP-SGD per-example clipping + Gaussian noise (Eq. 7)
- ``accountant``  RDP accounting of the sampled Gaussian mechanism (§3.3)
- ``gossip``      PushSum on time-varying directed graphs (§3.4)
- ``protocol``    Algorithm 1: DML client step + gossip round
- ``engine``      FederationEngine: loop/vmap/shard_map round executor
- ``commit``      hash-chained proxy commitments (verifiable federation)
- ``baselines``   FedAvg / FML / AvgPush / CWT / Regular / Joint (§4.1)
"""
from .accountant import PrivacyAccountant, epsilon_for, rdp_sampled_gaussian, rdp_to_eps
from .commit import CommitmentError, chain_step, client_commitment, leaf_digest
from .dp import add_gaussian_noise, clip_by_global_norm, dp_gradient, non_dp_gradient
from .engine import FederationEngine, active_mask, dml_engine, single_model_engine
from .gossip import (
    adjacency_matrix,
    comm_cost_per_round,
    debias,
    exponential_offsets,
    gossip_shift,
    mix_matrix,
    pushsum_gossip_shard,
    pushsum_mix,
)
from .protocol import (
    ClientState,
    ModelSpec,
    evaluate,
    gossip_proxies,
    init_client,
    local_round,
    make_ce_step,
    make_dml_step,
    proxyfl_round,
)
from .baselines import METHODS, final_mean_acc, run_federated

__all__ = [
    "PrivacyAccountant", "epsilon_for", "rdp_sampled_gaussian", "rdp_to_eps",
    "add_gaussian_noise", "clip_by_global_norm", "dp_gradient", "non_dp_gradient",
    "CommitmentError", "chain_step", "client_commitment", "leaf_digest",
    "FederationEngine", "active_mask", "dml_engine", "single_model_engine",
    "adjacency_matrix", "comm_cost_per_round", "debias", "exponential_offsets",
    "gossip_shift", "mix_matrix", "pushsum_gossip_shard", "pushsum_mix",
    "ClientState", "ModelSpec", "evaluate", "gossip_proxies", "init_client",
    "local_round", "make_ce_step", "make_dml_step", "proxyfl_round",
    "METHODS", "final_mean_acc", "run_federated",
]
