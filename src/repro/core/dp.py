"""DP-SGD (Abadi et al. 2016) — paper Eq. (7).

Per-example gradients are computed with an O(1)-memory ``lax.scan`` over the
batch (TPU adaptation: GPU DP-SGD implementations vmap the whole batch,
which multiplies gradient memory by B; sequentializing keeps the same FLOPs
with one live gradient pytree). Each per-example gradient is clipped to L2
norm C, the clipped gradients are summed, and Gaussian noise N(0, σ²C²) is
added once to the sum before dividing by B — exactly Eq. (7).

``microbatch`` > 1 trades memory for speed by treating groups of examples
as one DP unit (sensitivity then covers the group — guarantee becomes
per-group; keep 1 for per-example guarantees).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.dp_clip import scale_accumulate, sumsq
from ..kernels.dp_step import noise_adam_step
from ..nn.modules import tree_flatten_vector, tree_unflatten_vector

Params = Any


def clip_by_global_norm(tree: Params, max_norm: float) -> Tuple[Params, jnp.ndarray]:
    leaves = jax.tree_util.tree_leaves(tree)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))
    scale = 1.0 / jnp.maximum(1.0, norm / max_norm)
    # the scale is applied in f32 and the PRODUCT cast back: casting the
    # scale itself to a low-precision leaf dtype rounds it (bf16 has ~3
    # significant digits), and an upward-rounded scale leaves the clipped
    # tree ABOVE the sensitivity bound C the DP guarantee assumes
    clipped = jax.tree_util.tree_map(
        lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree)
    return clipped, norm


def add_gaussian_noise(tree: Params, key, stddev: float) -> Params:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noisy = [
        (x.astype(jnp.float32) + stddev * jax.random.normal(k, x.shape, jnp.float32)).astype(x.dtype)
        for x, k in zip(leaves, keys)
    ]
    return jax.tree_util.tree_unflatten(treedef, noisy)


def _flat_gaussian_like(tree: Params, key) -> jnp.ndarray:
    """The N(0,1) draws of :func:`add_gaussian_noise`, concatenated flat.

    Same per-leaf key-split schedule and per-leaf shapes, so the noise
    VALUES are identical to the tree-structured path — the fused flat
    chain differs from the unfused one only in arithmetic order, never in
    randomness (what keeps the use_pallas conformance columns allclose)."""
    leaves = jax.tree_util.tree_leaves(tree)
    keys = jax.random.split(key, len(leaves))
    return jnp.concatenate([
        jax.random.normal(k, x.shape, jnp.float32).reshape(-1)
        for x, k in zip(leaves, keys)])


def dp_gradient(
    loss_fn: Callable[[Params, Any], jnp.ndarray],
    params: Params,
    batch: Any,  # pytree whose leaves have leading batch dim B
    key,
    *,
    clip_norm: float,
    noise_multiplier: float,
    microbatch: int = 1,
    vectorized: bool = False,
    use_pallas: bool = False,
    interpret: Optional[bool] = None,
) -> Tuple[Params, dict]:
    """Noisy clipped mean gradient per Eq. (7). Returns (grad, metrics).

    ``vectorized=True`` vmaps the per-example gradients (O(B) gradient
    memory — fine for the paper's CNN-scale models, much faster); the
    default scan path is O(1) in B and is what the LLM-scale path uses.

    ``use_pallas=True`` runs the scan path's clip+accumulate over a
    FLATTENED gradient vector through the fused ``repro.kernels.dp_clip``
    kernels (``sumsq`` for the norm, ``scale_accumulate`` for both the
    clipped sum and the noise add), so each gradient chunk is streamed
    HBM→VMEM once per pass. Noise draws reuse the per-leaf key schedule
    of :func:`add_gaussian_noise` (identical values); results are
    allclose to the plain path (reduction-order-only divergence). The
    vectorized path ignores the flag (its einsum is already one fused
    contraction). ``interpret`` forwards to the kernels (None = platform
    autodetect)."""
    B = jax.tree_util.tree_leaves(batch)[0].shape[0]
    assert B % microbatch == 0, (B, microbatch)
    n_units = B // microbatch
    grad_fn = jax.value_and_grad(loss_fn)

    if vectorized:
        units = jax.tree_util.tree_map(
            lambda x: x.reshape((n_units, microbatch) + x.shape[1:]), batch)
        losses, grads = jax.vmap(grad_fn, in_axes=(None, 0))(params, units)
        norms = jax.vmap(lambda g: jnp.sqrt(sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree_util.tree_leaves(g))))(grads)
        scales = 1.0 / jnp.maximum(1.0, norms / clip_norm)
        acc = jax.tree_util.tree_map(
            lambda g: jnp.einsum("b...,b->...", g.astype(jnp.float32), scales), grads)
        noisy = add_gaussian_noise(acc, key, noise_multiplier * clip_norm)
        grad = jax.tree_util.tree_map(lambda x: x / n_units, noisy)
        return grad, {"loss": jnp.mean(losses), "mean_grad_norm": jnp.mean(norms)}

    def unit(i):
        return jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, i * microbatch, microbatch, 0),
            batch,
        )

    zero = jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32), params)

    if use_pallas:
        def body(carry, i):
            acc, loss_sum, norm_sum = carry
            loss, g = grad_fn(params, unit(i))
            gf = tree_flatten_vector(g)
            norm = jnp.sqrt(sumsq(gf, interpret=interpret))
            scale = 1.0 / jnp.maximum(1.0, norm / clip_norm)
            acc = scale_accumulate(acc, gf, scale, interpret=interpret)
            return (acc, loss_sum + loss, norm_sum + norm), None

        acc0 = tree_flatten_vector(zero)
        (acc, loss_sum, norm_sum), _ = jax.lax.scan(
            body, (acc0, jnp.zeros(()), jnp.zeros(())), jnp.arange(n_units))
        # noise add via the same kernel: acc + noise * (σ·C), one pass
        noise = _flat_gaussian_like(zero, key)
        stddev = jnp.asarray(noise_multiplier * clip_norm, jnp.float32)
        noisy = scale_accumulate(acc, noise, stddev, interpret=interpret)
        grad = tree_unflatten_vector(noisy / n_units, zero)
    else:
        def body(carry, i):
            acc, loss_sum, norm_sum = carry
            loss, g = grad_fn(params, unit(i))
            g_clip, norm = clip_by_global_norm(g, clip_norm)
            acc = jax.tree_util.tree_map(
                lambda a, x: a + x.astype(jnp.float32), acc, g_clip)
            return (acc, loss_sum + loss, norm_sum + norm), None

        (acc, loss_sum, norm_sum), _ = jax.lax.scan(
            body, (zero, jnp.zeros(()), jnp.zeros(())), jnp.arange(n_units))
        noisy = add_gaussian_noise(acc, key, noise_multiplier * clip_norm)
        grad = jax.tree_util.tree_map(lambda x: x / n_units, noisy)

    metrics = {
        "loss": loss_sum / n_units,
        "mean_grad_norm": norm_sum / n_units,
    }
    return grad, metrics


def dp_adam_update(
    loss_fn: Callable[[Params, Any], jnp.ndarray],
    params: Params,
    opt_state,
    batch: Any,
    key,
    *,
    opt,
    clip_norm: float,
    noise_multiplier: float,
    microbatch: int = 1,
    interpret: Optional[bool] = None,
) -> Tuple[Params, Any, dict]:
    """Fully fused DP-SGD + Adam step: Eq. (7) clip→noise and the
    optimizer update as ONE kernel chain over flat vectors.

    The per-unit scan clips and accumulates through the ``dp_clip``
    kernels, then :func:`repro.kernels.dp_step.noise_adam_step` applies
    noise-add, clipped-mean divide, weight decay, moment updates and the
    bias-corrected parameter step in a single HBM→VMEM pass — the tail
    the unfused path spreads over six ``tree_map`` sweeps. Returns
    ``(params', opt_state', metrics)`` with the same metrics dict as
    :func:`dp_gradient`.

    The fused elementwise chain is exact only for the optimizer's f32
    update path, so non-f32 params, master weights (``p32``) or non-f32
    moments fall back to ``dp_gradient(use_pallas=True)`` + ``opt.update``
    (still kernel-clipped, tree-structured step). ``opt`` must be a
    :class:`repro.optim.optimizers.Adam`."""
    from ..optim.optimizers import AdamState

    assert isinstance(opt_state, AdamState), type(opt_state)
    fusable = (
        opt_state.p32 is None
        and jnp.dtype(opt.moment_dtype) == jnp.float32
        and all(x.dtype == jnp.float32
                for x in jax.tree_util.tree_leaves(params)))
    if not fusable:
        grad, metrics = dp_gradient(
            loss_fn, params, batch, key, clip_norm=clip_norm,
            noise_multiplier=noise_multiplier, microbatch=microbatch,
            use_pallas=True, interpret=interpret)
        params2, opt2 = opt.update(grad, opt_state, params)
        return params2, opt2, metrics

    B = jax.tree_util.tree_leaves(batch)[0].shape[0]
    assert B % microbatch == 0, (B, microbatch)
    n_units = B // microbatch
    grad_fn = jax.value_and_grad(loss_fn)

    def unit(i):
        return jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, i * microbatch,
                                                   microbatch, 0), batch)

    def body(carry, i):
        acc, loss_sum, norm_sum = carry
        loss, g = grad_fn(params, unit(i))
        gf = tree_flatten_vector(g)
        norm = jnp.sqrt(sumsq(gf, interpret=interpret))
        scale = 1.0 / jnp.maximum(1.0, norm / clip_norm)
        acc = scale_accumulate(acc, gf, scale, interpret=interpret)
        return (acc, loss_sum + loss, norm_sum + norm), None

    p_flat = tree_flatten_vector(params)
    (acc, loss_sum, norm_sum), _ = jax.lax.scan(
        body, (jnp.zeros_like(p_flat), jnp.zeros(()), jnp.zeros(())),
        jnp.arange(n_units))

    noise = _flat_gaussian_like(params, key)
    t2 = opt_state.t + 1
    tf = t2.astype(jnp.float32)
    p2, m2, v2 = noise_adam_step(
        acc, noise, p_flat,
        tree_flatten_vector(opt_state.m), tree_flatten_vector(opt_state.v),
        stddev=noise_multiplier * clip_norm, n_units=n_units, lr=opt.lr,
        weight_decay=opt.weight_decay, b1=opt.b1, b2=opt.b2, eps=opt.eps,
        c1=1 - opt.b1 ** tf, c2=1 - opt.b2 ** tf, interpret=interpret)
    params2 = tree_unflatten_vector(p2, params)
    opt2 = AdamState(tree_unflatten_vector(m2, opt_state.m),
                     tree_unflatten_vector(v2, opt_state.v), t2, None)
    metrics = {
        "loss": loss_sum / n_units,
        "mean_grad_norm": norm_sum / n_units,
    }
    return params2, opt2, metrics


def dp_gradient_chunked(
    loss_fn: Callable[[Params, Any], jnp.ndarray],
    params: Params,
    batch: Any,
    key,
    *,
    clip_norm: float,
    noise_multiplier: float,
    chunk: int = 8,
    constrain: Callable[[Any], Any] = lambda b: b,
    prepare_chunk: Callable[[Any], Any] = lambda b: b,
    spmd_axis_name=None,
) -> Tuple[Params, dict]:
    """Per-example DP-SGD gradient (Eq. 7) with a scan-of-vmap schedule:
    scan over B/chunk chunks, vmap the per-example grads inside each chunk.
    Identical semantics to ``dp_gradient`` (every example clipped
    individually); ``chunk`` trades peak gradient memory (chunk × |θ|)
    against scan trip count — the knob the §Perf loop tunes on TPU.

    ``prepare_chunk`` runs ONCE per chunk, outside the per-example vmap —
    the ProxyFL step uses it to compute the (θ-independent) private-peer
    logits with one batched forward instead of once per example, which on
    a mesh removes per-example traversals of the large private model.
    ``spmd_axis_name`` shards the vmapped example dim over that mesh axis
    (GSPMD would otherwise be free to replicate the per-example compute)."""
    B = jax.tree_util.tree_leaves(batch)[0].shape[0]
    assert B % chunk == 0, (B, chunk)
    n_chunks = B // chunk
    grad_fn = jax.value_and_grad(lambda p, ex: loss_fn(
        p, jax.tree_util.tree_map(lambda x: x[None], ex)))

    def per_chunk(i):
        # ``constrain`` pins the chunk dim to the "data" mesh axis on the
        # distributed path so the vmapped per-example grads divide across
        # data rows instead of being computed redundantly on every device.
        return prepare_chunk(constrain(jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, 0), batch)))

    def body(carry, i):
        acc, loss_sum, norm_sum = carry
        losses, grads = jax.vmap(grad_fn, in_axes=(None, 0),
                                 spmd_axis_name=spmd_axis_name)(params, per_chunk(i))
        norms = jax.vmap(lambda g: jnp.sqrt(sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree_util.tree_leaves(g))),
                         spmd_axis_name=spmd_axis_name)(grads)
        scales = (1.0 / jnp.maximum(1.0, norms / clip_norm)).astype(jnp.float32)
        acc = jax.tree_util.tree_map(
            lambda a, g: a + jnp.einsum(
                "b...,b->...", g.astype(jnp.float32), scales), acc, grads)
        return (acc, loss_sum + jnp.sum(losses), norm_sum + jnp.sum(norms)), None

    zero = jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    (acc, loss_sum, norm_sum), _ = jax.lax.scan(
        body, (zero, jnp.zeros(()), jnp.zeros(())), jnp.arange(n_chunks))
    noisy = add_gaussian_noise(acc, key, noise_multiplier * clip_norm)
    grad = jax.tree_util.tree_map(lambda x: x / B, noisy)
    return grad, {"loss": loss_sum / B, "mean_grad_norm": norm_sum / B}


def dp_gradient_poisson(
    loss_fn: Callable[[Params, Any], jnp.ndarray],
    params: Params,
    batch: Any,          # padded batch (leaves [max_B, ...])
    mask: jnp.ndarray,   # [max_B] 1.0 = real example, 0.0 = padding
    key,
    *,
    clip_norm: float,
    noise_multiplier: float,
    expected_batch: float,
) -> Tuple[Params, dict]:
    """Eq. (7) under EXACT Poisson subsampling (Yu et al. 2019): clipped
    per-example gradients of the masked examples are summed, Gaussian noise
    N(0, sigma^2 C^2) added once, and the sum divided by the EXPECTED batch
    size qN — the estimator whose sensitivity the sampled-Gaussian RDP
    accountant analyzes. Padding slots contribute exactly zero."""
    grad_fn = jax.value_and_grad(lambda p, ex: loss_fn(
        p, jax.tree_util.tree_map(lambda x: x[None], ex)))
    losses, grads = jax.vmap(grad_fn, in_axes=(None, 0))(params, batch)
    norms = jax.vmap(lambda g: jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(g))))(grads)
    scales = mask / jnp.maximum(1.0, norms / clip_norm)
    acc = jax.tree_util.tree_map(
        lambda g: jnp.einsum("b...,b->...", g.astype(jnp.float32), scales),
        grads)
    noisy = add_gaussian_noise(acc, key, noise_multiplier * clip_norm)
    grad = jax.tree_util.tree_map(lambda x: x / expected_batch, noisy)
    n_real = jnp.maximum(jnp.sum(mask), 1.0)
    return grad, {"loss": jnp.sum(losses * mask) / n_real,
                  "mean_grad_norm": jnp.sum(norms * mask) / n_real}


def non_dp_gradient(
    loss_fn: Callable[[Params, Any], jnp.ndarray],
    params: Params,
    batch: Any,
    *,
    accum: int = 1,
) -> Tuple[Params, dict]:
    """Plain mean gradient, optionally accumulated over ``accum`` microbatch
    slices with a scan (bounds peak logits memory for large-vocab models)."""
    if accum <= 1:
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        return g, {"loss": loss}
    B = jax.tree_util.tree_leaves(batch)[0].shape[0]
    assert B % accum == 0, (B, accum)
    mb = B // accum
    grad_fn = jax.value_and_grad(loss_fn)

    def body(carry, i):
        acc, loss_sum = carry
        sl = jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, i * mb, mb, 0), batch)
        loss, g = grad_fn(params, sl)
        acc = jax.tree_util.tree_map(lambda a, x: a + x.astype(jnp.float32) / accum, acc, g)
        return (acc, loss_sum + loss / accum), None

    zero = jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    (g, loss), _ = jax.lax.scan(body, (zero, jnp.zeros(())), jnp.arange(accum))
    return g, {"loss": loss}
