"""DP-SGD (Abadi et al. 2016) — paper Eq. (7).

Per-example gradients are computed with an O(1)-memory ``lax.scan`` over the
batch (TPU adaptation: GPU DP-SGD implementations vmap the whole batch,
which multiplies gradient memory by B; sequentializing keeps the same FLOPs
with one live gradient pytree). Each per-example gradient is clipped to L2
norm C, the clipped gradients are summed, and Gaussian noise N(0, σ²C²) is
added once to the sum before dividing by B — exactly Eq. (7).

``microbatch`` > 1 trades memory for speed by treating groups of examples
as one DP unit (sensitivity then covers the group — guarantee becomes
per-group; keep 1 for per-example guarantees).
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Params = Any


def clip_by_global_norm(tree: Params, max_norm: float) -> Tuple[Params, jnp.ndarray]:
    leaves = jax.tree_util.tree_leaves(tree)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))
    scale = 1.0 / jnp.maximum(1.0, norm / max_norm)
    return jax.tree_util.tree_map(lambda x: x * scale.astype(x.dtype), tree), norm


def add_gaussian_noise(tree: Params, key, stddev: float) -> Params:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noisy = [
        (x.astype(jnp.float32) + stddev * jax.random.normal(k, x.shape, jnp.float32)).astype(x.dtype)
        for x, k in zip(leaves, keys)
    ]
    return jax.tree_util.tree_unflatten(treedef, noisy)


def dp_gradient(
    loss_fn: Callable[[Params, Any], jnp.ndarray],
    params: Params,
    batch: Any,  # pytree whose leaves have leading batch dim B
    key,
    *,
    clip_norm: float,
    noise_multiplier: float,
    microbatch: int = 1,
    vectorized: bool = False,
) -> Tuple[Params, dict]:
    """Noisy clipped mean gradient per Eq. (7). Returns (grad, metrics).

    ``vectorized=True`` vmaps the per-example gradients (O(B) gradient
    memory — fine for the paper's CNN-scale models, much faster); the
    default scan path is O(1) in B and is what the LLM-scale path uses."""
    B = jax.tree_util.tree_leaves(batch)[0].shape[0]
    assert B % microbatch == 0, (B, microbatch)
    n_units = B // microbatch
    grad_fn = jax.value_and_grad(loss_fn)

    if vectorized:
        units = jax.tree_util.tree_map(
            lambda x: x.reshape((n_units, microbatch) + x.shape[1:]), batch)
        losses, grads = jax.vmap(grad_fn, in_axes=(None, 0))(params, units)
        norms = jax.vmap(lambda g: jnp.sqrt(sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree_util.tree_leaves(g))))(grads)
        scales = 1.0 / jnp.maximum(1.0, norms / clip_norm)
        acc = jax.tree_util.tree_map(
            lambda g: jnp.einsum("b...,b->...", g.astype(jnp.float32), scales), grads)
        noisy = add_gaussian_noise(acc, key, noise_multiplier * clip_norm)
        grad = jax.tree_util.tree_map(lambda x: x / n_units, noisy)
        return grad, {"loss": jnp.mean(losses), "mean_grad_norm": jnp.mean(norms)}

    def unit(i):
        return jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, i * microbatch, microbatch, 0),
            batch,
        )

    def body(carry, i):
        acc, loss_sum, norm_sum = carry
        loss, g = grad_fn(params, unit(i))
        g_clip, norm = clip_by_global_norm(g, clip_norm)
        acc = jax.tree_util.tree_map(
            lambda a, x: a + x.astype(jnp.float32), acc, g_clip)
        return (acc, loss_sum + loss, norm_sum + norm), None

    zero = jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    (acc, loss_sum, norm_sum), _ = jax.lax.scan(
        body, (zero, jnp.zeros(()), jnp.zeros(())), jnp.arange(n_units))

    noisy = add_gaussian_noise(acc, key, noise_multiplier * clip_norm)
    grad = jax.tree_util.tree_map(lambda x: x / n_units, noisy)
    metrics = {
        "loss": loss_sum / n_units,
        "mean_grad_norm": norm_sum / n_units,
    }
    return grad, metrics


def dp_gradient_chunked(
    loss_fn: Callable[[Params, Any], jnp.ndarray],
    params: Params,
    batch: Any,
    key,
    *,
    clip_norm: float,
    noise_multiplier: float,
    chunk: int = 8,
    constrain: Callable[[Any], Any] = lambda b: b,
    prepare_chunk: Callable[[Any], Any] = lambda b: b,
    spmd_axis_name=None,
) -> Tuple[Params, dict]:
    """Per-example DP-SGD gradient (Eq. 7) with a scan-of-vmap schedule:
    scan over B/chunk chunks, vmap the per-example grads inside each chunk.
    Identical semantics to ``dp_gradient`` (every example clipped
    individually); ``chunk`` trades peak gradient memory (chunk × |θ|)
    against scan trip count — the knob the §Perf loop tunes on TPU.

    ``prepare_chunk`` runs ONCE per chunk, outside the per-example vmap —
    the ProxyFL step uses it to compute the (θ-independent) private-peer
    logits with one batched forward instead of once per example, which on
    a mesh removes per-example traversals of the large private model.
    ``spmd_axis_name`` shards the vmapped example dim over that mesh axis
    (GSPMD would otherwise be free to replicate the per-example compute)."""
    B = jax.tree_util.tree_leaves(batch)[0].shape[0]
    assert B % chunk == 0, (B, chunk)
    n_chunks = B // chunk
    grad_fn = jax.value_and_grad(lambda p, ex: loss_fn(
        p, jax.tree_util.tree_map(lambda x: x[None], ex)))

    def per_chunk(i):
        # ``constrain`` pins the chunk dim to the "data" mesh axis on the
        # distributed path so the vmapped per-example grads divide across
        # data rows instead of being computed redundantly on every device.
        return prepare_chunk(constrain(jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, 0), batch)))

    def body(carry, i):
        acc, loss_sum, norm_sum = carry
        losses, grads = jax.vmap(grad_fn, in_axes=(None, 0),
                                 spmd_axis_name=spmd_axis_name)(params, per_chunk(i))
        norms = jax.vmap(lambda g: jnp.sqrt(sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree_util.tree_leaves(g))),
                         spmd_axis_name=spmd_axis_name)(grads)
        scales = (1.0 / jnp.maximum(1.0, norms / clip_norm)).astype(jnp.float32)
        acc = jax.tree_util.tree_map(
            lambda a, g: a + jnp.einsum(
                "b...,b->...", g.astype(jnp.float32), scales), acc, grads)
        return (acc, loss_sum + jnp.sum(losses), norm_sum + jnp.sum(norms)), None

    zero = jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    (acc, loss_sum, norm_sum), _ = jax.lax.scan(
        body, (zero, jnp.zeros(()), jnp.zeros(())), jnp.arange(n_chunks))
    noisy = add_gaussian_noise(acc, key, noise_multiplier * clip_norm)
    grad = jax.tree_util.tree_map(lambda x: x / B, noisy)
    return grad, {"loss": loss_sum / B, "mean_grad_norm": norm_sum / B}


def dp_gradient_poisson(
    loss_fn: Callable[[Params, Any], jnp.ndarray],
    params: Params,
    batch: Any,          # padded batch (leaves [max_B, ...])
    mask: jnp.ndarray,   # [max_B] 1.0 = real example, 0.0 = padding
    key,
    *,
    clip_norm: float,
    noise_multiplier: float,
    expected_batch: float,
) -> Tuple[Params, dict]:
    """Eq. (7) under EXACT Poisson subsampling (Yu et al. 2019): clipped
    per-example gradients of the masked examples are summed, Gaussian noise
    N(0, sigma^2 C^2) added once, and the sum divided by the EXPECTED batch
    size qN — the estimator whose sensitivity the sampled-Gaussian RDP
    accountant analyzes. Padding slots contribute exactly zero."""
    grad_fn = jax.value_and_grad(lambda p, ex: loss_fn(
        p, jax.tree_util.tree_map(lambda x: x[None], ex)))
    losses, grads = jax.vmap(grad_fn, in_axes=(None, 0))(params, batch)
    norms = jax.vmap(lambda g: jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(g))))(grads)
    scales = mask / jnp.maximum(1.0, norms / clip_norm)
    acc = jax.tree_util.tree_map(
        lambda g: jnp.einsum("b...,b->...", g.astype(jnp.float32), scales),
        grads)
    noisy = add_gaussian_noise(acc, key, noise_multiplier * clip_norm)
    grad = jax.tree_util.tree_map(lambda x: x / expected_batch, noisy)
    n_real = jnp.maximum(jnp.sum(mask), 1.0)
    return grad, {"loss": jnp.sum(losses * mask) / n_real,
                  "mean_grad_norm": jnp.sum(norms * mask) / n_real}


def non_dp_gradient(
    loss_fn: Callable[[Params, Any], jnp.ndarray],
    params: Params,
    batch: Any,
    *,
    accum: int = 1,
) -> Tuple[Params, dict]:
    """Plain mean gradient, optionally accumulated over ``accum`` microbatch
    slices with a scan (bounds peak logits memory for large-vocab models)."""
    if accum <= 1:
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        return g, {"loss": loss}
    B = jax.tree_util.tree_leaves(batch)[0].shape[0]
    assert B % accum == 0, (B, accum)
    mb = B // accum
    grad_fn = jax.value_and_grad(loss_fn)

    def body(carry, i):
        acc, loss_sum = carry
        sl = jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, i * mb, mb, 0), batch)
        loss, g = grad_fn(params, sl)
        acc = jax.tree_util.tree_map(lambda a, x: a + x.astype(jnp.float32) / accum, acc, g)
        return (acc, loss_sum + loss / accum), None

    zero = jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    (g, loss), _ = jax.lax.scan(body, (zero, jnp.zeros(())), jnp.arange(accum))
    return g, {"loss": loss}
