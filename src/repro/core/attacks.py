"""Empirical privacy validation — membership-inference attacks (MIA).

The paper's conclusion calls for exactly this: "while differential privacy
provides theoretical guarantees ... it is important to validate the
effectiveness of these guarantees in practice. To be meaningful, such
guarantees should demonstrably reduce the susceptibility of systems to
reconstruction and membership inference attacks."

Implemented attacker: the standard loss-threshold MIA (Yeom et al. 2018) —
the adversary observes a model (e.g. a RELEASED PROXY) and predicts that
low-loss examples were training members. Reported as AUC over
member/non-member scores: 0.5 = no leakage, 1.0 = full leakage. The
DP-trained proxy should sit near 0.5 even when the non-DP private model
leaks; this is what makes releasing the proxy (and only the proxy) safe.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np



def per_example_losses(apply_fn: Callable, params, x: jnp.ndarray,
                       y: jnp.ndarray, batch: int = 256) -> np.ndarray:
    """CE loss of each example under the model (the MIA score)."""
    @jax.jit
    def batch_losses(p, xb, yb):
        logits = apply_fn(p, xb)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, logp.shape, logp.ndim - 1)
        picked = jnp.sum(jnp.where(iota == yb[..., None].astype(jnp.int32),
                                   logp, 0.0), axis=-1)
        return -picked

    out = []
    for i in range(0, x.shape[0], batch):
        out.append(np.asarray(batch_losses(params, x[i:i + batch],
                                           y[i:i + batch])))
    return np.concatenate(out)


def auc_from_scores(member_scores: np.ndarray,
                    nonmember_scores: np.ndarray) -> float:
    """Rank-based AUC of the attacker that predicts 'member' for LOWER
    scores (losses). 0.5 = chance; 1.0 = perfect membership inference."""
    m, n = np.asarray(member_scores), np.asarray(nonmember_scores)
    if len(m) == 0 or len(n) == 0:
        raise ValueError(
            f"auc_from_scores needs non-empty score arrays on both sides "
            f"(got {len(m)} member, {len(n)} non-member scores) — an AUC "
            "over an empty class is undefined, not 0.5; check the "
            "member/non-member split upstream")
    # Mann-Whitney U via tie-averaged ranks:
    all_scores = np.concatenate([m, n])
    _, inv, counts = np.unique(all_scores, return_inverse=True,
                               return_counts=True)
    cum = np.cumsum(counts)
    ranks = (cum - (counts - 1) / 2.0)[inv]
    u = ranks[: len(m)].sum() - len(m) * (len(m) + 1) / 2.0
    auc_high = u / (len(m) * len(n))  # P(member loss > nonmember loss)
    return float(1.0 - auc_high)      # members should have LOWER loss


def bitflip_proxy(client: int, *, bit: int = 0, index: int = 0,
                  rounds: Optional[Tuple[int, ...]] = None) -> Callable:
    """Byzantine tamper model for the engine's ``transmit_tamper`` hook:
    flip bit ``bit`` of float32 element ``index`` of client ``client``'s
    TRANSMITTED proxy vector — the smallest possible in-flight corruption,
    which commitment verification must still catch
    (``cfg.verify_commitments``; see ``FederationEngine._verified_
    exchange``). ``rounds`` restricts the attack to those round indices
    (None = every round). Returns ``tamper(flat [K, D] numpy, t) -> flat``.
    """
    def tamper(flat: np.ndarray, t: int) -> np.ndarray:
        if rounds is not None and t not in rounds:
            return flat
        out = np.array(flat, dtype=np.float32, copy=True)
        row = out[client].view(np.uint32)
        row[index] ^= np.uint32(1 << bit)
        return out
    return tamper


def loss_threshold_mia(apply_fn: Callable, params,
                       member_data: Tuple[jnp.ndarray, jnp.ndarray],
                       nonmember_data: Tuple[jnp.ndarray, jnp.ndarray],
                       ) -> float:
    """AUC of the loss-threshold membership-inference attack."""
    ml = per_example_losses(apply_fn, params, *member_data)
    nl = per_example_losses(apply_fn, params, *nonmember_data)
    return auc_from_scores(ml, nl)
