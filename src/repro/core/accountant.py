"""Rényi-DP accounting for the sampled Gaussian mechanism.

Follows Mironov (2017) / Mironov, Talwar, Zhang (2019): the RDP of one
DP-SGD step with sampling rate q and noise multiplier sigma is, at integer
order alpha,

    eps_RDP(alpha) = 1/(alpha-1) * log( sum_{k=0}^{alpha} C(alpha,k)
                     (1-q)^{alpha-k} q^k exp(k(k-1)/(2 sigma^2)) )

computed in log space for stability. RDP composes additively over steps,
and converts to (eps, delta)-DP with the improved bound of Balle et al.
(2020) (the conversion used by Opacus/TF-Privacy):

    eps = min_alpha eps_RDP(alpha) + log((alpha-1)/alpha)
          - (log delta + log alpha)/(alpha-1)

Restricting to integer alpha only weakens (never invalidates) the bound,
since every order yields a valid guarantee. Pure host-side Python — the
accountant sits outside the jitted training step.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Sequence

DEFAULT_ALPHAS: List[int] = list(range(2, 65)) + [96, 128, 256, 512]


def _log_comb(n: int, k: int) -> float:
    return math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1)


def _log_add(a: float, b: float) -> float:
    if a == -math.inf:
        return b
    if b == -math.inf:
        return a
    hi, lo = (a, b) if a > b else (b, a)
    return hi + math.log1p(math.exp(lo - hi))


def rdp_sampled_gaussian(q: float, sigma: float, alpha: int) -> float:
    """RDP epsilon of ONE sampled-Gaussian step at integer order alpha."""
    if q == 0.0:
        return 0.0
    if sigma == 0.0:
        return math.inf
    if q == 1.0:
        return alpha / (2 * sigma ** 2)
    log_sum = -math.inf
    log_q, log_1q = math.log(q), math.log1p(-q)
    for k in range(alpha + 1):
        term = (
            _log_comb(alpha, k)
            + k * log_q
            + (alpha - k) * log_1q
            + (k * k - k) / (2 * sigma ** 2)
        )
        log_sum = _log_add(log_sum, term)
    return log_sum / (alpha - 1)


def rdp_to_eps(rdp: Sequence[float], alphas: Sequence[int], delta: float) -> float:
    """Best (eps, delta) conversion over orders (Balle et al. 2020)."""
    best = math.inf
    for r, a in zip(rdp, alphas):
        if math.isinf(r):
            continue
        eps = r + math.log((a - 1) / a) - (math.log(delta) + math.log(a)) / (a - 1)
        best = min(best, eps)
    return max(best, 0.0)


@dataclass
class PrivacyAccountant:
    """Per-client accountant (paper §3.3: privacy tracked per client; the
    client drops out when its prespecified budget is reached)."""

    noise_multiplier: float
    sample_rate: float  # q = B / N
    delta: float = 1e-5
    alphas: List[int] = field(default_factory=lambda: list(DEFAULT_ALPHAS))
    steps: int = 0
    _per_step_rdp: List[float] = field(default_factory=list)

    def __post_init__(self):
        self._per_step_rdp = [
            rdp_sampled_gaussian(self.sample_rate, self.noise_multiplier, a)
            for a in self.alphas
        ]

    def step(self, n: int = 1) -> None:
        self.steps += n

    def epsilon(self, delta: float | None = None) -> float:
        delta = self.delta if delta is None else delta
        rdp = [r * self.steps for r in self._per_step_rdp]
        return rdp_to_eps(rdp, self.alphas, delta)

    def exceeds(self, budget: float) -> bool:
        return self.epsilon() > budget


def epsilon_for(
    *, noise_multiplier: float, sample_rate: float, steps: int, delta: float
) -> float:
    acc = PrivacyAccountant(noise_multiplier, sample_rate, delta)
    acc.step(steps)
    return acc.epsilon()
