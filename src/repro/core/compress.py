"""Compressed proxy exchange — top-k / int8 gossip with error feedback.

Communication efficiency is the paper's headline claim (§4, Fig. 4:
ProxyFL sends exactly ONE proxy per client per round, O(1) in federation
size). This module shrinks that one proxy with CHOCO-SGD-style
public-copy delta coding (Koloskova et al. 2019; Stich et al. 2018):
each client maintains a PUBLIC COPY ``ẑ_k`` of its vector that every
receiver already holds, transmits only a compressed DELTA against it,
and receivers mix the updated — dense — copies. Truncated mass stays in
the implicit error-feedback residual ``m_k − ẑ_k``, re-transmitted in
later rounds, so compression delays information instead of destroying
it.

Why deltas-against-a-copy rather than zero-filling the sparse message
into the mix (the naive scheme): under PushSum the receiver divides by
the FULL mixed weight ``P @ w``, so a zero-filled coordinate is not
"skipped" — it is multiplied by ``kept/w ≈ 0.5`` every round it goes
untransmitted. Top-k at ratio 0.25 then shrinks 75 % of every received
vector toward zero each round and the proxies diverge (measured: a
25-point proxy-accuracy gap at K=16). With a public copy the receiver
always mixes a dense ``ẑ_j ≈ z_j``; sparsity only bounds how fast the
copy tracks the truth.

The protocol shape (one round, stacked [K, D] client vectors):

1. split the column-stochastic P^(t) into the mass each client KEEPS
   (``kept`` = diag) and the mass it SENDS (``sent`` = off-diag) — a
   client's own state never crosses the wire, so only senders encode;
2. delta: ``u_k = m_k − ẑ_k`` (this round's would-be transmission minus
   the copy receivers hold; the error-feedback residual IS ``u_k``);
3. encode/decode: ``c_k = C(u_k)`` — the DE-compressed transmitted
   delta (receivers apply exactly ``c_k``);
4. copy update, sender and receivers in lockstep: ``ẑ'_k = ẑ_k + c_k``.
   The conservation invariant ``c_k + (m_k − ẑ'_k) == m_k − ẑ_k`` —
   transmitted delta plus remaining residual equals the mass owed — is
   EXACT in f32 by construction (``m − ẑ' = u − c`` elementwise, and at
   coordinates the codec kept, ``u − c`` is the bf16/int8 rounding
   error; at dropped coordinates ``c = 0`` leaves ``u`` intact);
5. mix: receivers merge ``kept_k · m_k + Σ_j sent_{kj} · ẑ'_j`` (dense!)
   and de-bias by the (uncompressed — K floats are free) PushSum
   weights.

Clients that send NOTHING this round (§3.4 dropouts: identity column,
zero off-diagonal mass; or a no-exchange round) keep their public copy
UNTOUCHED — receivers could not have observed an update, so advancing
``ẑ`` without a transmission would desynchronize sender and receivers.

Copies WARM-START at the initial vectors (one uncompressed broadcast at
setup — the engine owns init, so receivers hold ``ẑ_0 = m_0`` before the
first compressed round; a cold ``ẑ_0 = 0`` start needs ≈1/ratio rounds
just to cover the coordinates and measurably lags at short horizons),
and a lossless codec gives ``ẑ' ≡ m`` so the scheme reduces to the
plain exchange.

Codecs (wire formats, measured by :func:`wire_bytes`):

``"topk"``
    Keep the ``k = ratio · D`` largest-magnitude entries of the delta
    per client, values rounded to bf16 on the wire, positions as a D-bit
    bitmap: ``D/8 + 2k`` bytes vs ``4D`` full-precision — ≥4x at ratio
    0.25 (6.4x). Deterministic (no RNG). Magnitude selection on the
    delta rotates coordinates naturally: whatever went untransmitted
    grows in ``u`` until it wins a slot.
``"int8"``
    Per-client scale ``s = max|u| / 127``; entries stochastically rounded
    to int8 (unbiased: round up with probability equal to the fractional
    part): ``D + 4`` bytes — ~4x. The rounding noise is drawn from the
    round key (:func:`compress_round_key`), so every backend and any
    kill/resume replays identical bits.
``"none"``
    Not a codec: the engine bypasses this module entirely and the plain
    exchange runs VERBATIM (bitwise-identical to the uncompressed
    protocol — enforced by tests/test_conformance.py).

``compressed_gossip_reference`` is the numpy executable spec of the
synchronous compressed exchange (the engine and its property tests are
held to it), mirroring ``stale_gossip_reference`` in ``core.gossip``;
``topk_reference``/``int8_reference``/``ef_encode_reference`` are the
per-op numpy oracles used by tests/test_compress.py.

Interplay with the Pallas-fused hot path: the fused kernels implement the
UNCOMPRESSED mix chains; when compression is on, the exchange takes the
plain-XLA compressed path regardless of ``use_pallas`` (documented
honestly — fusing the codec into the kernels is future work; local DP
steps still fuse).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# compression RNG domain: the stochastic-rounding noise of round t is drawn
# from fold_in(round_key, COMPRESS_KEY_FOLD). The constant is far outside
# the engine's per-client fold domain (0..K-1) so codec noise can never
# collide with a client's local-step RNG chain.
COMPRESS_KEY_FOLD = 987_654_321

MODES = ("none", "topk", "int8")


@dataclass(frozen=True)
class CompressionSpec:
    """Static codec configuration (hashable — rides in jit closures)."""

    mode: str = "none"      # "topk" | "int8" ("none" never builds a spec)
    ratio: float = 0.25     # top-k kept fraction of D (ignored by int8)

    def __post_init__(self):
        assert self.mode in MODES, self.mode
        assert 0.0 < self.ratio <= 1.0, self.ratio


def compress_spec(cfg) -> Optional[CompressionSpec]:
    """``CompressionSpec`` from a ProxyFLConfig, or None for ``"none"``
    (None is the engine's signal to keep the uncompressed path verbatim)."""
    mode = getattr(cfg, "compress", "none") or "none"
    if mode == "none":
        return None
    return CompressionSpec(mode=mode,
                           ratio=float(getattr(cfg, "compress_ratio", 0.25)))


def compress_round_key(round_key):
    """Round t's codec RNG key under the canonical schedule — identical on
    every backend (loop folds the same round key the stacked scan folds),
    so loop/vmap/async draw the same stochastic-rounding bits."""
    return jax.random.fold_in(round_key, COMPRESS_KEY_FOLD)


def topk_k(D: int, ratio: float) -> int:
    """Entries kept per client: ``max(1, round(ratio · D))``, capped at D."""
    return max(1, min(int(round(ratio * D)), D))


# ---------------------------------------------------------------------------
# codecs: encode + immediately decode (simulation measures bytes, it does
# not ship them; ``c`` is exactly what a receiver would reconstruct)


def _topk_encode_decode(u: jnp.ndarray, k: int) -> jnp.ndarray:
    """Per-row top-k by |u| with bf16 wire values: dense [K, D] with zeros
    at dropped positions. f32 in, f32 out."""
    K = u.shape[0]
    _, idx = jax.lax.top_k(jnp.abs(u), k)
    mask = jnp.zeros(u.shape, bool).at[
        jnp.arange(K)[:, None], idx].set(True)
    wire = u.astype(jnp.bfloat16).astype(jnp.float32)
    return jnp.where(mask, wire, 0.0)


def _int8_encode_decode(u: jnp.ndarray, noise: jnp.ndarray) -> jnp.ndarray:
    """Per-row-scaled int8 stochastic rounding; ``noise`` ~ U[0,1) of
    u.shape decides each entry's round-up. f32 in, f32 out."""
    scale = jnp.maximum(jnp.max(jnp.abs(u), axis=1), 1e-12) / 127.0
    x = u / scale[:, None]
    lo = jnp.floor(x)
    q = lo + (noise < (x - lo)).astype(jnp.float32)
    q = jnp.clip(q, -127.0, 127.0)
    return q * scale[:, None]


def encode_decode(u: jnp.ndarray, key, spec: CompressionSpec) -> jnp.ndarray:
    """Decoded transmission ``C(u)`` for a stacked f32 [K, D] delta block
    (``key`` feeds int8's stochastic rounding; top-k ignores it)."""
    if spec.mode == "topk":
        return _topk_encode_decode(u, topk_k(u.shape[1], spec.ratio))
    if spec.mode == "int8":
        noise = jax.random.uniform(key, u.shape, jnp.float32)
        return _int8_encode_decode(u, noise)
    raise ValueError(spec.mode)


def wire_bytes(mode: str, D: int, ratio: float = 0.25,
               dtype_bytes: int = 4) -> int:
    """Bytes ONE client puts on the wire for one D-entry message.

    none: D full-precision values. topk: a D-bit position bitmap plus k
    bf16 values. int8: D bytes plus one f32 scale. De-bias weights (one
    float per client) are noise and excluded everywhere."""
    if mode == "none":
        return D * dtype_bytes
    if mode == "topk":
        return (D + 7) // 8 + 2 * topk_k(D, ratio)
    if mode == "int8":
        return D + 4
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# compressed exchanges (dispatched from the gossip choke points)


def _split_P(Pf: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    idx = jnp.arange(Pf.shape[0])
    kept = Pf[idx, idx]
    sent = Pf.at[idx, idx].set(0.0)
    return kept, sent


def _ef_encode(m, pub, sent, key, spec):
    """Shared public-copy core: message + copy -> (decoded delta c,
    copy'). The transmitted delta is ``c = C(m − pub)`` and sender plus
    receivers advance the copy in lockstep: ``pub' = pub + c``. Clients
    with zero off-diagonal column mass transmit nothing and keep their
    copy unchanged (receivers saw no update). Conservation, exact in f32
    per transmitting client: ``c + (m − pub') == m − pub`` — the owed
    mass is split between this round's wire and the carried residual."""
    sends = (sent.sum(axis=0) > 0)[:, None]
    u = m - pub
    c = jnp.where(sends, encode_decode(u, key, spec), 0.0)
    # explicit where (not pub + 0): keeps silent clients' copies BITWISE
    # untouched (x + 0 flips -0.0 to +0.0)
    pub2 = jnp.where(sends, pub + c, pub)
    return c, pub2


def compressed_pushsum_mix(flat, w, P, pub, key, spec: CompressionSpec):
    """Synchronous exchange with delta-coded transmissions: ``z' =
    (kept·z + sent @ (pub + C(z − pub))) / (P·w)`` — the compressed
    counterpart of :func:`repro.core.gossip.pushsum_mix_debiased`.
    Receivers mix the DENSE updated copies, so sparsification never
    zero-fills a received coordinate and the de-bias stays exact. f32
    accumulation; returns ``(z', w', pub')``. With a lossless codec
    (``pub' ≡ z``) this reduces to the plain ``P @ z`` exchange."""
    f = flat.astype(jnp.float32)
    Pf = jnp.asarray(P, jnp.float32)
    kept, sent = _split_P(Pf)
    c, pub2 = _ef_encode(f, pub, sent, key, spec)
    mixed = kept[:, None] * f + sent @ pub2
    w2 = Pf @ w.astype(jnp.float32)
    z2 = mixed / w2[:, None]
    return z2.astype(flat.dtype), w2.astype(w.dtype), pub2


def compressed_stale_mix(flat, w, kept, sent, buf_t0, buf_w0, pub, key,
                         spec: CompressionSpec):
    """Stale (async τ>0) exchange with delta-coded transmissions — the
    compressed counterpart of :func:`repro.core.gossip.stale_mix_apply`:
    the public copy tracks the raw PushSum numerator θ = z·w (the
    quantity that enters the in-flight buffer), ``sent @ (pub + C(θ −
    pub))`` enters the buffer dense, kept mass and deliveries stay
    exact. Returns ``(z', send_t, w', send_w, pub')``; the caller owns
    the buffer rotation. De-bias weights are never compressed, so total
    w-mass (clients + buffer) is conserved exactly at any τ."""
    f = flat.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    theta = f * wf[:, None]
    c, pub2 = _ef_encode(theta, pub, sent, key, spec)
    send_t = sent.astype(jnp.float32) @ pub2
    send_w = sent.astype(jnp.float32) @ wf
    mixed = kept.astype(jnp.float32)[:, None] * theta \
        + buf_t0.astype(jnp.float32)
    w2 = kept.astype(jnp.float32) * wf + buf_w0.astype(jnp.float32)
    z2 = mixed / w2[:, None]
    return (z2.astype(flat.dtype), send_t.astype(flat.dtype),
            w2.astype(w.dtype), send_w.astype(w.dtype), pub2)


# ---------------------------------------------------------------------------
# numpy oracles + executable spec (what tests/test_compress.py holds the
# jax ops and the engine to)


def topk_reference(u: np.ndarray, ratio: float) -> np.ndarray:
    """Numpy twin of the top-k codec (stable argsort ties == lax.top_k's
    lowest-index-first), bf16 wire rounding via ml_dtypes."""
    import ml_dtypes
    u = np.asarray(u, np.float32)
    k = topk_k(u.shape[1], ratio)
    idx = np.argsort(-np.abs(u), axis=1, kind="stable")[:, :k]
    mask = np.zeros(u.shape, bool)
    np.put_along_axis(mask, idx, True, axis=1)
    wire = u.astype(ml_dtypes.bfloat16).astype(np.float32)
    return np.where(mask, wire, 0.0)


def int8_reference(u: np.ndarray, noise: np.ndarray) -> np.ndarray:
    """Numpy twin of the int8 stochastic-rounding codec for a GIVEN noise
    block (tests feed the same U[0,1) draw to both sides)."""
    u = np.asarray(u, np.float32)
    scale = np.maximum(np.abs(u).max(axis=1), 1e-12).astype(np.float32) / \
        np.float32(127.0)
    x = u / scale[:, None]
    lo = np.floor(x)
    q = lo + (np.asarray(noise, np.float32) < (x - lo))
    q = np.clip(q, -127.0, 127.0).astype(np.float32)
    return q * scale[:, None]


def ef_encode_reference(m, pub, sent, spec: CompressionSpec, noise=None):
    """Numpy twin of the public-copy core: returns ``(c, pub')``.
    The conservation invariant ``c + (m − pub') == m − pub`` (per
    transmitting client, exact) is THE property tests pin."""
    m = np.asarray(m, np.float32)
    pub = np.asarray(pub, np.float32)
    sends = (np.asarray(sent).sum(axis=0) > 0)[:, None]
    u = m - pub
    if spec.mode == "topk":
        c = topk_reference(u, spec.ratio)
    elif spec.mode == "int8":
        c = int8_reference(u, noise)
    else:
        raise ValueError(spec.mode)
    c = np.where(sends, c, 0.0).astype(np.float32)
    pub2 = np.where(sends, pub + c, pub).astype(np.float32)
    return c, pub2


def compressed_gossip_reference(z0, w0, Ps, spec: CompressionSpec,
                                noises=None):
    """Numpy executable spec of the SYNCHRONOUS compressed exchange — the
    round body :func:`compressed_pushsum_mix` implements on device,
    f32 throughout to mirror the jax path bit-closely.

    ``z0``: [K, D] client vectors; ``w0``: [K] de-bias weights; ``Ps``:
    iterable of [K, K] column-stochastic matrices. ``noises``: one
    U[0,1) [K, D] block per round for int8 (None for the deterministic
    top-k). Returns ``(z, w, pub)`` after ``len(Ps)`` rounds (copies
    warm-start at ``z0``, matching the engine's setup broadcast).
    Invariants (tests/test_compress.py): per round and
    per transmitting client ``c + (message − pub') == message − pub``
    exactly; non-transmitting clients keep ``pub`` untouched; receivers
    mix the dense ``pub'``; w evolves exactly as the uncompressed
    protocol (weights are never compressed)."""
    z = np.asarray(z0, np.float32)
    w = np.asarray(w0, np.float32)
    pub = z.copy()
    for t, P in enumerate(Ps):
        Pf = np.asarray(P, np.float32)
        kept = np.diag(Pf).copy()
        sent = Pf.copy()
        np.fill_diagonal(sent, 0.0)
        c, pub = ef_encode_reference(
            z, pub, sent, spec,
            noise=None if noises is None else noises[t])
        mixed = kept[:, None] * z + sent @ pub
        w = Pf @ w
        z = mixed / w[:, None]
    return z, w, pub
