"""Batched serving driver — the paper's inference story ("after training, a
client's private model can be used for inference") at LLM scale.

Implements a simple static-batch serving loop: prefill the prompt batch
into the KV/SSM cache, then step the decode loop token by token with greedy
or temperature sampling. On CPU this serves the reduced (smoke) variant;
full-size serving programs are exercised via ``dryrun.py`` (prefill_32k /
decode_32k / long_500k).

Example::

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, list_archs
from ..configs.registry import smoke_variant
from ..nn.model import init_cache, init_model
from .steps import StepOptions, make_decode_step, make_prefill_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs(), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    key = jax.random.PRNGKey(args.seed)
    key, k_init, k_prompt, k_img, k_first = jax.random.split(key, 5)
    max_len = args.prompt_len + args.gen + (
        cfg.n_image_tokens if cfg.modality == "vlm" else 0)
    opts = StepOptions(remat=False, kv_chunk=max(64, args.prompt_len))

    params = init_model(k_init, cfg)
    cache = init_cache(cfg, args.batch, max_len, dtype=jnp.dtype(cfg.dtype))
    state = {"params": params, "cache": cache}

    tok_shape = ((args.batch, args.prompt_len, cfg.n_codebooks)
                 if cfg.modality == "audio" else (args.batch, args.prompt_len))
    prompt = jax.random.randint(k_prompt, tok_shape, 0, cfg.vocab_size)
    batch = {"tokens": prompt}
    if cfg.modality == "vlm":
        batch["img"] = jax.random.normal(
            k_img, (args.batch, cfg.n_image_tokens, cfg.frontend_dim),
            jnp.dtype(cfg.dtype))

    prefill = jax.jit(make_prefill_step(cfg, opts))
    decode = jax.jit(make_decode_step(cfg, opts))

    t0 = time.time()
    state, logits = prefill(state, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"[serve] {cfg.name}: prefill B={args.batch} S={args.prompt_len} "
          f"in {t_prefill:.2f}s")

    def sample(k, lg):
        if args.temperature <= 0:
            return jnp.argmax(lg, axis=-1)
        return jax.random.categorical(k, lg / args.temperature, axis=-1)

    pos0 = args.prompt_len + (cfg.n_image_tokens if cfg.modality == "vlm" else 0)
    tok = sample(k_first, logits)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        key, kk = jax.random.split(key)
        t_in = tok[:, None, :] if cfg.modality == "audio" else tok[:, None]
        state, logits = decode(state, {"tokens": t_in.astype(jnp.int32),
                                       "pos": jnp.asarray(pos0 + i, jnp.int32)})
        tok = sample(kk, logits)
        out.append(tok)
    jax.block_until_ready(out[-1])
    dt = time.time() - t0
    n_tok = args.batch * (args.gen - 1)
    print(f"[serve] decoded {args.gen-1} steps x batch {args.batch}: "
          f"{dt:.2f}s  ({n_tok/max(dt,1e-9):.1f} tok/s on CPU)")
    toks = jnp.stack(out, axis=1)
    print(f"[serve] sample tokens (client-private model output): "
          f"{toks[0].reshape(-1)[:16].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
