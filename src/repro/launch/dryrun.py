import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent without
real hardware.

For every (architecture × input shape × mesh) combination this lowers and
compiles the corresponding step program against ``jax.ShapeDtypeStruct``
stand-ins (no allocation), prints ``memory_analysis()`` /
``cost_analysis()``, parses the post-SPMD HLO for collective traffic, and
derives the three roofline terms (compute / memory / collective) against
TPU v5e constants. Results are written as JSON artifacts consumed by
``benchmarks/roofline.py`` and EXPERIMENTS.md.

The two lines above MUST stay the very first statements of this module:
jax locks the device count at first initialization, and the dry-run needs
512 placeholder host devices to build the production meshes. They are set
here and ONLY here — tests and benchmarks keep seeing one CPU device.

Usage::

    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun
    python -m repro.launch.dryrun --arch jamba-1.5-large-398b --shape long_500k \
        --mesh single --tag kvq8 --kv-cache-dtype bfloat16
    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k \
        --mesh multi --program hier_block --clients-per-shard 4

``--program hier_block`` lowers the engine's ``backend="hier"`` round-block
on the two-level mesh: one shard of ``--clients-per-shard`` stacked clients
per pod, intra-shard PushSum as a local block matmul, cross-shard edges as
at most two ppermutes along "pod" per round.
"""
# NOTE: no ``from __future__ import annotations`` here — the XLA_FLAGS lines
# above must be the first statements of the module, which rules it out.

import argparse
import json
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import INPUT_SHAPES, get_config, list_archs
from ..configs.base import DPConfig, InputShape, ModelConfig, ProxyFLConfig
from ..configs.registry import proxy_of
from .mesh import TPU_V5E, make_production_mesh, mesh_context
from .sharding import named
from .steps import (
    StepOptions,
    input_specs,
    make_decode_step,
    make_fl_round_step,
    make_hier_round_block_step,
    make_prefill_step,
    make_round_block_step,
    make_train_step,
    serve_shardings,
    serve_state_shapes,
    train_shardings,
    train_state_shapes,
)

#: rounds fused into one program by ``--program round_block`` /
#: ``hier_block`` (the engine's round-block unit; static — each round's
#: ppermute schedule is baked in)
BLOCK_ROUNDS = 4

#: clients stacked per pod by ``--program hier_block`` (the two-level mesh:
#: n_shards = pod count, clients_per_shard vmapped within each pod;
#: override with --clients-per-shard)
CLIENTS_PER_SHARD = 4

# Architectures with sub-quadratic context handling run long_500k; pure
# full-attention architectures skip it (DESIGN.md "long_500k skip decisions").
LONG_CONTEXT_OK = {
    "falcon-mamba-7b",       # SSM: O(1) state
    "jamba-1.5-large-398b",  # hybrid: KV only on every 8th layer
    "gemma3-4b",             # 5:1 sliding-window
    "qwen2-7b-swa",          # beyond-paper dense->SWA override
}

from .hlo_cost import collective_wire_bytes, step_cost


def _cost_dict(compiled) -> Dict[str, float]:
    try:
        c = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return dict(c) if c else {}


def _memory_dict(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _spec_shard_count(spec: P, mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for entry in spec:
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            n *= sizes[a]
    return n


def sharded_bytes_per_device(shapes_tree, specs_tree, mesh) -> int:
    """Analytic per-device bytes of a sharded pytree of ShapeDtypeStructs."""
    total = 0
    flat_s, _ = jax.tree_util.tree_flatten(shapes_tree)
    flat_p, _ = jax.tree_util.tree_flatten(
        specs_tree, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p), (len(flat_s), len(flat_p))
    for sds, spec in zip(flat_s, flat_p):
        nbytes = int(np.prod(sds.shape)) * jnp.dtype(sds.dtype).itemsize if sds.shape else jnp.dtype(sds.dtype).itemsize
        total += nbytes // _spec_shard_count(spec, mesh)
    return total


# ---------------------------------------------------------------------------
# roofline


def roofline(flops_dev: float, bytes_dev: float, coll: Dict[str, Any],
             hw=TPU_V5E) -> Dict[str, Any]:
    """Three-term roofline, all in seconds-per-step on ONE chip (the SPMD
    program is per-device, so per-device terms ARE the global-step terms)."""
    coll_total = coll["total_wire_bytes"]
    t_compute = flops_dev / hw["peak_flops_bf16"]
    t_memory = bytes_dev / hw["hbm_bandwidth"]
    t_collective = coll_total / hw["ici_bandwidth"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dominant = max(terms, key=terms.get)
    return {**terms, "dominant": dominant.replace("_s", ""),
            "collective_bytes_per_device": coll_total,
            "collective_breakdown": coll["wire_bytes"],
            "collective_op_counts": coll["op_counts"]}


def model_flops(cfg: ModelConfig, shape: InputShape, proxy: Optional[ModelConfig],
                fl_dp: bool = True) -> float:
    """Useful-work FLOPs for one step: 6·N_active·tokens for training (the
    ProxyFL DML step trains private AND proxy, plus each model runs one
    extra peer forward → private 6+2, proxy 6+2), 2·N_active·tokens for
    inference."""
    counts = cfg.param_counts()
    n_act = counts["active"]
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        f = 8.0 * n_act * toks  # 6 (fwd+bwd) + 2 (peer forward for proxy's KL)
        if proxy is not None:
            n_px = proxy.param_counts()["active"]
            f += 8.0 * n_px * toks
        return f
    toks = shape.global_batch * (shape.seq_len if shape.kind == "prefill" else 1)
    return 2.0 * n_act * toks


# ---------------------------------------------------------------------------
# one dry-run combination


#: dry-run defaults: activation constraints ON (we are on a mesh), DP chunk
#: = data-axis size so per-example grads divide across data rows.
DRYRUN_OPTS = StepOptions(shard_acts=True, dp_chunk=16)


def run_one(arch: str, shape_name: str, mesh_kind: str, *,
            program: str = "auto", opts: StepOptions = DRYRUN_OPTS,
            clients_per_shard: int = CLIENTS_PER_SHARD,
            tag: str = "", verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(mesh.devices.shape))
    if program == "auto":
        program = {"train": "train", "prefill": "prefill",
                   "decode": "decode"}[shape.kind]

    if shape_name == "long_500k" and arch not in LONG_CONTEXT_OK:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "program": program, "status": "skipped",
                "reason": "pure full-attention architecture (DESIGN.md skip)"}

    fl = ProxyFLConfig(dp=DPConfig(enabled=True))
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    t0 = time.time()

    if program in ("train", "fl_round", "round_block", "hier_block"):
        proxy = proxy_of(cfg)
        pods = mesh.shape.get("pod", 0)
        if program == "hier_block":
            if not pods:
                raise ValueError(
                    "--program hier_block needs the two-level (multi-pod) "
                    "mesh — run with --mesh multi")
            # two-level cohort: one SHARD per pod, clients_per_shard
            # clients vmapped within it
            n_clients = pods * clients_per_shard
        else:
            n_clients = pods if program in ("fl_round", "round_block") else 0
        state_sds = train_state_shapes(cfg, proxy, fl, opts)
        if n_clients:
            state_sds = jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((n_clients,) + s.shape, s.dtype),
                state_sds)
            key_sds = jax.ShapeDtypeStruct((n_clients, 2), jnp.uint32)
        batch_sds = input_specs(cfg, shape, n_clients=n_clients)
        state_spec, batch_spec, modes = train_shardings(
            mesh, state_sds, batch_sds, n_clients=n_clients,
            expert_parallel=opts.expert_parallel)
        if program == "fl_round":
            step = make_fl_round_step(cfg, proxy, fl, mesh, n_clients, opts,
                                      round_t=0)
            metrics_spec = {"private_loss": P("pod"), "proxy_loss": P("pod")}
        elif program == "round_block":
            step = make_round_block_step(cfg, proxy, fl, mesh, n_clients,
                                         opts, n_rounds=BLOCK_ROUNDS)
            # metrics stacked [n_rounds, K]: round dim replicated, K on pod
            metrics_spec = {"private_loss": P(None, "pod"),
                            "proxy_loss": P(None, "pod")}
        elif program == "hier_block":
            step = make_hier_round_block_step(
                cfg, proxy, fl, mesh, pods, clients_per_shard, opts,
                n_rounds=BLOCK_ROUNDS)
            # stacked [n_rounds, K]: K = pods·clients_per_shard, contiguous
            # shard blocks of clients_per_shard live on each pod
            metrics_spec = {"private_loss": P(None, "pod"),
                            "proxy_loss": P(None, "pod")}
        else:
            step = make_train_step(cfg, proxy, fl, opts)
            metrics_spec = {"private_loss": P(), "proxy_loss": P()}
        jitted = jax.jit(
            step,
            in_shardings=(named(state_spec, mesh), named(batch_spec, mesh),
                          NamedSharding(mesh, P() if not n_clients else P("pod"))),
            out_shardings=(named(state_spec, mesh), named(metrics_spec, mesh)),
            donate_argnums=(0,),  # in-place params/opt update (no double buffer)
        )
        args = (state_sds, batch_sds, key_sds)
        arg_bytes_dev = (sharded_bytes_per_device(state_sds, state_spec, mesh)
                         + sharded_bytes_per_device(batch_sds, batch_spec, mesh))
        mf = model_flops(cfg, shape, proxy)
        if program in ("round_block", "hier_block"):
            mf *= BLOCK_ROUNDS  # the program does n_rounds rounds of work
        if program == "hier_block":
            # n_clients DML steps per round, not one per pod
            mf *= n_clients / max(1, pods)
    if program not in ("train", "fl_round", "round_block", "hier_block"):
        modes = None
        state_sds = serve_state_shapes(cfg, shape)
        batch_sds = input_specs(cfg, shape)
        state_spec, batch_spec = serve_shardings(
            mesh, state_sds, batch_sds, expert_parallel=opts.expert_parallel,
            serve_2d=opts.serve_2d)
        maker = make_prefill_step if program == "prefill" else make_decode_step
        step = maker(cfg, opts)
        logits_spec = P(None, "model") if cfg.modality != "audio" else P()
        jitted = jax.jit(
            step,
            in_shardings=(named(state_spec, mesh), named(batch_spec, mesh)),
            out_shardings=(named(state_spec, mesh), None),
            donate_argnums=(0,),  # in-place KV-cache update
        )
        args = (state_sds, batch_sds)
        arg_bytes_dev = (sharded_bytes_per_device(state_sds, state_spec, mesh)
                         + sharded_bytes_per_device(batch_sds, batch_spec, mesh))
        mf = model_flops(cfg, shape, None)

    with mesh_context(mesh):
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        # global, trip-count-corrected cost from the traced jaxpr (XLA's
        # cost_analysis counts while bodies once — useless for scan stacks)
        jc = step_cost(step, *args)
    cost = _cost_dict(compiled)
    memory = _memory_dict(compiled)
    coll = collective_wire_bytes(compiled.as_text())
    flops_dev = jc["flops"] / chips
    bytes_dev = jc["bytes"] / chips
    rl = roofline(flops_dev, bytes_dev, coll)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "program": program, "tag": tag, "status": "ok",
        "chips": chips, "sharding_modes": modes,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_global": jc["flops"],
        "bytes_global": jc["bytes"],
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "xla_cost_analysis_raw": {
            "flops_per_device": float(cost.get("flops", 0.0)),
            "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
            "note": "while bodies counted once by XLA; see flops_global",
        },
        "argument_bytes_per_device": arg_bytes_dev,
        "memory_analysis": memory,
        "roofline": rl,
        "model_flops": mf,
        "useful_flops_ratio": (mf / jc["flops"]) if jc["flops"] else None,
        "params_total": cfg.param_counts()["total"],
        "params_active": cfg.param_counts()["active"],
        "opts": {k: getattr(opts, k) for k in
                 ("remat", "accum", "dp_chunk", "kv_chunk", "mamba_chunk",
                  "expert_parallel", "moment_dtype", "serve_2d")},
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {mesh_kind} × {program}"
              f"{' × ' + tag if tag else ''}")
        print(f"  lower {t_lower:.1f}s  compile {t_compile:.1f}s  chips {chips}")
        print(f"  memory_analysis: {memory}")
        print(f"  jaxpr cost: flops/dev {flops_dev:.3e}  bytes/dev {bytes_dev:.3e}")
        print(f"  collective wire bytes/dev: { {k: f'{v:.3e}' for k, v in rl['collective_breakdown'].items()} }")
        print(f"  roofline: compute {rl['compute_s']*1e3:.2f}ms  memory "
              f"{rl['memory_s']*1e3:.2f}ms  collective {rl['collective_s']*1e3:.2f}ms"
              f"  → {rl['dominant']}-bound")
        print(f"  MODEL_FLOPS {mf:.3e}  useful/jaxpr {result['useful_flops_ratio']:.3f}")
    return result


# ---------------------------------------------------------------------------
# CLI


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--program", default="auto",
                    choices=("auto", "train", "fl_round", "round_block",
                             "hier_block", "prefill", "decode"))
    ap.add_argument("--clients-per-shard", type=int,
                    default=CLIENTS_PER_SHARD,
                    help="clients stacked per pod for --program hier_block "
                         "(the two-level mesh: n_shards = pod count)")
    ap.add_argument("--all", action="store_true",
                    help="every (arch × shape) for the chosen mesh(es)")
    ap.add_argument("--out", default="results/dryrun", help="JSON output dir")
    ap.add_argument("--tag", default="", help="perf-iteration tag")
    # StepOptions overrides (the §Perf levers)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--accum", type=int)
    ap.add_argument("--dp-chunk", type=int)
    ap.add_argument("--kv-chunk", type=int)
    ap.add_argument("--mamba-chunk", type=int)
    ap.add_argument("--expert-parallel", action="store_true")
    ap.add_argument("--serve-2d", action="store_true")
    ap.add_argument("--moment-dtype")
    args = ap.parse_args(argv)

    opts = DRYRUN_OPTS
    kw = {}
    if args.no_remat:
        kw["remat"] = False
    for name in ("accum", "dp_chunk", "kv_chunk", "mamba_chunk", "moment_dtype"):
        v = getattr(args, name)
        if v is not None:
            kw[name] = v
    if args.expert_parallel:
        kw["expert_parallel"] = True
    if args.serve_2d:
        kw["serve_2d"] = True
    if kw:
        opts = opts.with_(**kw)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    combos = []
    if args.all:
        for a in list_archs():
            for s in sorted(INPUT_SHAPES):
                for m in meshes:
                    combos.append((a, s, m))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        combos = [(args.arch, args.shape, m) for m in meshes]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for a, s, m in combos:
        try:
            res = run_one(a, s, m, program=args.program, opts=opts,
                          clients_per_shard=args.clients_per_shard,
                          tag=args.tag)
        except Exception as e:  # a dry-run failure is a bug in the system
            failures += 1
            res = {"arch": a, "shape": s, "mesh": m, "status": "FAILED",
                   "error": f"{type(e).__name__}: {e}"}
            print(f"[dryrun] FAILED {a} × {s} × {m}: {e}", file=sys.stderr)
        fname = f"{a}__{s}__{m}__{args.program}"
        if args.tag:
            fname += f"__{args.tag}"
        with open(os.path.join(args.out, fname + ".json"), "w") as f:
            json.dump(res, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
