"""End-to-end ProxyFL training driver for the LLM-scale path.

Runs the full protocol — per-round local DML steps (private non-DP +
proxy DP-SGD, Algorithm 1 lines 2–5) followed by the PushSum proxy
exchange (lines 7–11) — across K simulated clients, each holding a
private model of the selected architecture family and the shared proxy
architecture, on synthetic non-IID language-modelling data.

On CPU this runs the reduced (smoke) variant of the chosen architecture;
the full-size configs are exercised through ``dryrun.py``. The default
``--preset 100m`` trains a ~100M-parameter private model.

Examples::

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
        --rounds 3 --steps-per-round 5
    PYTHONPATH=src python -m repro.launch.train --preset 100m --rounds 10
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import INPUT_SHAPES, get_config, list_archs
from ..configs.base import DPConfig, InputShape, LayerSpec, ModelConfig, ProxyFLConfig
from ..configs.registry import proxy_of, smoke_variant
from ..core.accountant import PrivacyAccountant
from ..core.gossip import adjacency_matrix, debias, pushsum_mix
from ..data.synthetic import make_lm_data
from ..nn.losses import cross_entropy
from ..nn.model import forward
from ..nn.modules import tree_flatten_vector, tree_size, tree_unflatten_vector
from .steps import StepOptions, init_train_state, make_train_step


def preset_100m(vocab: int = 8192) -> ModelConfig:
    """~100M-parameter dense decoder for the end-to-end example."""
    return ModelConfig(
        name="repro-100m", arch_type="dense", vocab_size=vocab, d_model=768,
        n_layers=12, n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072,
        pattern=(LayerSpec(),), tie_embeddings=True,
        source="end-to-end driver preset")


def build_cfgs(args):
    if args.preset == "100m":
        cfg = preset_100m()
        proxy = proxy_of(cfg, n_layers=4, d_model=256)
    else:
        cfg = get_config(args.arch)
        if args.smoke:
            cfg = smoke_variant(cfg)
        proxy = smoke_variant(proxy_of(cfg)) if args.smoke else proxy_of(cfg)
    return cfg, proxy


def evaluate_ppl(params, cfg: ModelConfig, tokens: jnp.ndarray, batch: int = 8
                 ) -> float:
    losses = []
    fwd = jax.jit(lambda p, t: cross_entropy(
        forward(p, cfg, t[:, :-1])[0], t[:, 1:]))
    for i in range(0, tokens.shape[0], batch):
        losses.append(float(fwd(params, tokens[i:i + batch])))
    return float(np.exp(np.mean(losses)))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--preset", choices=("100m",), default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family variant (CPU-friendly)")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--steps-per-round", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--no-dp", action="store_true")
    ap.add_argument("--sigma", type=float, default=1.0)
    ap.add_argument("--clip", type=float, default=1.0)
    ap.add_argument("--topology", default="exponential",
                    choices=("exponential", "ring", "full"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if not args.preset and not args.arch:
        args.preset = "100m"

    cfg, proxy = build_cfgs(args)
    K = args.clients
    fl = ProxyFLConfig(
        alpha=args.alpha, beta=args.alpha, n_clients=K, rounds=args.rounds,
        local_steps=args.steps_per_round, lr=args.lr, batch_size=args.batch,
        topology=args.topology, seed=args.seed,
        dp=DPConfig(enabled=not args.no_dp, clip_norm=args.clip,
                    noise_multiplier=args.sigma))
    opts = StepOptions(remat=False, accum=1, dp_chunk=args.batch)

    key = jax.random.PRNGKey(args.seed)
    print(f"[train] private={cfg.name} ({tree_size_of(cfg)} params approx: "
          f"{cfg.param_counts()['total']/1e6:.1f}M)  proxy={proxy.name} "
          f"({proxy.param_counts()['total']/1e6:.1f}M)  clients={K}")

    # non-IID synthetic LM data: each client's stream comes from its own
    # bigram chain (domain = client id); the test stream mixes all domains.
    def lm_set(k2, n_seqs, domain):
        v = min(cfg.vocab_size, 2048)
        stream = make_lm_data(k2, n_seqs * (args.seq + 1), v, domain=domain)
        return stream.reshape(n_seqs, args.seq + 1)

    data: List[jnp.ndarray] = [
        lm_set(jax.random.fold_in(key, 100 + k), 64, domain=k)
        for k in range(K)]
    test = jnp.concatenate([
        lm_set(jax.random.fold_in(key, 999 + k), max(1, 32 // K), domain=k)
        for k in range(K)])

    states = [init_train_state(jax.random.fold_in(key, k), cfg, proxy, fl, opts)
              for k in range(K)]
    accountants = [PrivacyAccountant(args.sigma, args.batch / (64), 1e-5)
                   for _ in range(K)] if not args.no_dp else None
    step = jax.jit(make_train_step(cfg, proxy, fl, opts))

    for t in range(args.rounds):
        t0 = time.time()
        metrics = {}
        for k in range(K):
            kk = jax.random.fold_in(key, 10_000 + t * K + k)
            toks = data[k]
            for s in range(args.steps_per_round):
                kk, kb, kn = jax.random.split(kk, 3)
                idx = jax.random.randint(kb, (args.batch,), 0, toks.shape[0])
                batch = {"tokens": toks[idx, :-1], "labels": toks[idx, 1:]}
                states[k], metrics = step(states[k], batch, kn)
                if accountants:
                    accountants[k].step()
        # PushSum proxy exchange (simulation backend: Θ ← P^(t) Θ, w ← P w)
        thetas = jnp.stack([tree_flatten_vector(s["proxy"]["params"])
                            for s in states])
        ws = jnp.asarray([float(s["w"]) for s in states], thetas.dtype)
        Pm = adjacency_matrix(t, K, args.topology)
        mixed, w2 = pushsum_mix(thetas, ws, Pm)
        unb = debias(mixed, w2)
        like = states[0]["proxy"]["params"]
        for k in range(K):
            states[k]["proxy"]["params"] = tree_unflatten_vector(unb[k], like)
            states[k]["w"] = jnp.asarray(float(w2[k]))
        ppl = evaluate_ppl(states[0]["private"]["params"], cfg, test)
        eps = accountants[0].epsilon() if accountants else float("nan")
        print(f"[round {t+1}/{args.rounds}] "
              f"private_loss={float(metrics['private_loss']):.4f} "
              f"proxy_loss={float(metrics['proxy_loss']):.4f} "
              f"client0_test_ppl={ppl:.2f} eps={eps:.3f} "
              f"({time.time()-t0:.1f}s)")
    return 0


def tree_size_of(cfg: ModelConfig) -> str:
    return f"{cfg.n_layers}L/d{cfg.d_model}"


if __name__ == "__main__":
    raise SystemExit(main())
