"""End-to-end ProxyFL training driver for the LLM-scale path.

Runs the full protocol — per-round local DML steps (private non-DP +
proxy DP-SGD, Algorithm 1 lines 2–5) followed by the PushSum proxy
exchange (lines 7–11) — across K simulated clients, each holding a
private model of the selected architecture family and the shared proxy
architecture, on synthetic non-IID language-modelling data.

Rounds are executed by :class:`repro.core.engine.FederationEngine`
driving ``make_train_step``: with the default ``--backend vmap`` the whole
round (scan over local steps × vmap over clients × on-device PushSum
matmul) is ONE compiled XLA program; ``--rounds-per-block B`` goes
further and fuses B consecutive rounds into one engine round-block (outer
scan over rounds, stacked ``mix_schedule`` exchange matrices, in-scan RNG
folding) so the host syncs only at block edges — bit-identical to
per-round execution, with checkpoints landing on block edges.
``--backend loop`` keeps the per-client dispatch (useful for debugging /
heterogeneous experiments). ``--backend async --staleness T`` switches to
the stale-gossip exchange: the round-t mix merges neighbor proxy mass put
in flight τ rounds earlier (communication overlapped with the local
scans, Assran et al. 2019; τ=0 is bit-identical to vmap). ``--backend hier
--n-shards S`` runs the two-level cohort: block-diagonal intra-shard
matmul mixing plus at-most-one sparse cross-shard edge per client per
round — the same flat ``mix_schedule`` matrices factored by edge
locality, bit-identical to vmap at τ=0; ``--staleness`` then delays only
the cross-shard edges. ``--dropout-rate``
exercises the §3.4 dropout/join scenario: clients sit rounds out and the
time-varying gossip graph re-knits around them.

On CPU this runs the reduced (smoke) variant of the chosen architecture;
the full-size configs are exercised through ``dryrun.py``. The default
``--preset 100m`` trains a ~100M-parameter private model.

``--checkpoint-dir`` snapshots the complete federation (client states,
PushSum weights, round counter, DP accountant steps) every
``--checkpoint-every`` rounds; ``--resume`` restarts a killed run from the
newest snapshot and replays the remaining rounds bit-identically to an
uninterrupted run (see ``repro.checkpoint``).

Examples::

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
        --rounds 3 --steps-per-round 5
    PYTHONPATH=src python -m repro.launch.train --preset 100m --rounds 10
    PYTHONPATH=src python -m repro.launch.train --preset 100m --rounds 50 \
        --checkpoint-dir ckpts/run0 --checkpoint-every 5 --resume
"""
from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import FederationCheckpointer, config_fingerprint
from ..configs import list_archs, get_config
from ..configs.base import DPConfig, LayerSpec, ModelConfig, ProxyFLConfig
from ..configs.registry import proxy_of, smoke_variant
from ..core.accountant import PrivacyAccountant
from ..core.engine import FederationEngine, block_spans
from ..data.synthetic import make_lm_data
from ..nn.losses import cross_entropy
from ..nn.model import forward
from .steps import StepOptions, init_train_state, make_train_step


def preset_100m(vocab: int = 8192) -> ModelConfig:
    """~100M-parameter dense decoder for the end-to-end example."""
    return ModelConfig(
        name="repro-100m", arch_type="dense", vocab_size=vocab, d_model=768,
        n_layers=12, n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072,
        pattern=(LayerSpec(),), tie_embeddings=True,
        source="end-to-end driver preset")


def build_cfgs(args):
    if args.preset == "100m":
        cfg = preset_100m()
        proxy = proxy_of(cfg, n_layers=4, d_model=256)
    else:
        cfg = get_config(args.arch)
        if args.smoke:
            cfg = smoke_variant(cfg)
        proxy = smoke_variant(proxy_of(cfg)) if args.smoke else proxy_of(cfg)
    return cfg, proxy


def evaluate_ppl(params, cfg: ModelConfig, tokens: jnp.ndarray, batch: int = 8
                 ) -> float:
    losses = []
    fwd = jax.jit(lambda p, t: cross_entropy(
        forward(p, cfg, t[:, :-1])[0], t[:, 1:]))
    for i in range(0, tokens.shape[0], batch):
        losses.append(float(fwd(params, tokens[i:i + batch])))
    return float(np.exp(np.mean(losses)))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--preset", choices=("100m",), default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family variant (CPU-friendly)")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--steps-per-round", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--weight-decay", type=float, default=1e-4)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--no-dp", action="store_true")
    ap.add_argument("--sigma", type=float, default=1.0)
    ap.add_argument("--clip", type=float, default=1.0)
    ap.add_argument("--topology", default="exponential",
                    choices=("exponential", "ring", "full"))
    ap.add_argument("--backend", default="vmap",
                    choices=("loop", "vmap", "async", "hier"),
                    help="federation engine backend (vmap = one compiled "
                         "round program; async = staleness-τ stale gossip, "
                         "see --staleness; hier = two-level cohort of "
                         "--n-shards shards with block-diagonal intra-shard "
                         "mixing and sparse cross-shard edges, see "
                         "--n-shards; shard_map needs a multi-device "
                         "mesh, see dryrun.py)")
    ap.add_argument("--staleness", type=int, default=0,
                    help="gossip delay τ for --backend async or hier: the "
                         "round-t exchange merges neighbor proxy mass sent "
                         "τ rounds earlier (communication overlapped with "
                         "the local scans); with hier only the CROSS-SHARD "
                         "edges are delayed; 0 is bit-identical to the "
                         "vmap backend")
    ap.add_argument("--n-shards", type=int, default=1,
                    help="two-level cohort layout for --backend hier: "
                         "n_shards shards of clients/n_shards clients each "
                         "(must divide evenly); 1 keeps every edge "
                         "intra-shard and runs the vmap round programs "
                         "verbatim")
    ap.add_argument("--dropout-rate", type=float, default=0.0,
                    help="per-round client dropout probability (§3.4)")
    ap.add_argument("--min-active", type=int, default=1,
                    help="floor on participating clients per round when "
                         "--dropout-rate > 0")
    ap.add_argument("--rounds-per-block", type=int, default=1,
                    help="rounds fused into one compiled engine round-block "
                         "(vmap backend: the host is re-entered only at "
                         "block edges; 1 = historical per-round execution; "
                         "any value is bit-identical, checkpoints land on "
                         "block edges)")
    ap.add_argument("--size-skew", type=float, default=0.0,
                    help="per-client corpus size skew in [0, 1): client k "
                         "holds ~64*(1-skew)^k sequences, a ragged cohort "
                         "that exercises the padded/masked vmap path")
    ap.add_argument("--use-pallas", action="store_true",
                    help="Pallas-fused round hot path: the PushSum exchange "
                         "runs as one blocked HBM->VMEM kernel pass (real "
                         "Mosaic kernels on TPU, interpret mode elsewhere); "
                         "allclose to the plain-XLA path. The LLM DP step "
                         "keeps its chunked XLA path — the fused DP "
                         "clip->noise->step applies to the classifier-scale "
                         "protocol steps (repro.core.protocol)")
    ap.add_argument("--compress", default="none",
                    choices=("none", "topk", "int8"),
                    help="compressed proxy exchange (repro.core.compress): "
                         "top-k sparsification or int8 stochastic-rounding "
                         "quantization of the DELTA against a public proxy "
                         "copy carried per client in the engine state "
                         "(error feedback — truncated mass is re-sent "
                         "later); 'none' keeps the exchange byte-for-byte "
                         "full-precision")
    ap.add_argument("--compress-ratio", type=float, default=0.25,
                    help="top-k kept fraction of the flattened proxy "
                         "(with --compress topk; 0.25 -> ~6.4x fewer "
                         "bytes on the wire)")
    ap.add_argument("--verify-commitments", action="store_true",
                    help="verifiable federation (repro.core.commit): check "
                         "every received proxy against its sender's "
                         "declared commitment before mixing (loop backend) "
                         "and restore checkpoints in strict commitment "
                         "mode — snapshots whose hash chain, leaf digests "
                         "or fingerprint records fail verification are "
                         "refused with the divergent round/leaf named")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="snapshot complete federation state here (enables "
                         "preemption-tolerant runs; see repro.checkpoint)")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    help="rounds between snapshots (with --checkpoint-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="restart from the newest snapshot in "
                         "--checkpoint-dir (bit-identical continuation)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if not args.preset and not args.arch:
        args.preset = "100m"

    cfg, proxy = build_cfgs(args)
    K = args.clients
    fl = ProxyFLConfig(
        alpha=args.alpha, beta=args.alpha, n_clients=K, rounds=args.rounds,
        local_steps=args.steps_per_round, lr=args.lr,
        weight_decay=args.weight_decay, batch_size=args.batch,
        topology=args.topology, seed=args.seed,
        dropout_rate=args.dropout_rate, min_active=args.min_active,
        staleness=args.staleness, n_shards=args.n_shards,
        use_pallas=args.use_pallas, compress=args.compress,
        compress_ratio=args.compress_ratio,
        verify_commitments=args.verify_commitments,
        dp=DPConfig(enabled=not args.no_dp, clip_norm=args.clip,
                    noise_multiplier=args.sigma))
    if args.staleness and args.backend not in ("async", "hier"):
        raise SystemExit("--staleness requires --backend async or hier "
                         "(the synchronous backends deliver every round)")
    if args.n_shards > 1 and args.backend != "hier":
        raise SystemExit("--n-shards > 1 requires --backend hier "
                         "(the flat backends have no shard level)")
    opts = StepOptions(remat=False, accum=1, dp_chunk=args.batch)

    key = jax.random.PRNGKey(args.seed)
    print(f"[train] private={cfg.name} ({tree_size_of(cfg)} params approx: "
          f"{cfg.param_counts()['total']/1e6:.1f}M)  proxy={proxy.name} "
          f"({proxy.param_counts()['total']/1e6:.1f}M)  clients={K} "
          f"backend={args.backend}")

    # non-IID synthetic LM data: each client's stream comes from its own
    # bigram chain (domain = client id); the test stream mixes all domains.
    def lm_set(k2, n_seqs, domain):
        v = min(cfg.vocab_size, 2048)
        stream = make_lm_data(k2, n_seqs * (args.seq + 1), v, domain=domain)
        return stream.reshape(n_seqs, args.seq + 1)

    n_seqs = [max(args.batch, int(round(64 * (1.0 - args.size_skew) ** k)))
              for k in range(K)]
    data: List[jnp.ndarray] = [
        lm_set(jax.random.fold_in(key, 100 + k), n_seqs[k], domain=k)
        for k in range(K)]
    test = jnp.concatenate([
        lm_set(jax.random.fold_in(key, 999 + k), max(1, 32 // K), domain=k)
        for k in range(K)])

    def sample(toks, kb, n_valid=None):
        # masked-sampler protocol: ragged per-client corpora on the vmap
        # backend pass the true sequence count so padding is never drawn
        hi = toks.shape[0] if n_valid is None else n_valid
        idx = jax.random.randint(kb, (args.batch,), 0, hi)
        return {"tokens": toks[idx, :-1], "labels": toks[idx, 1:]}

    engine = FederationEngine(
        fl, n_clients=K,
        step_fns=make_train_step(cfg, proxy, fl, opts),
        init_fns=lambda k2: init_train_state(k2, cfg, proxy, fl, opts),
        sample_fn=sample, backend=args.backend, mix="pushsum")
    if not args.no_dp:
        # DP sample rate q = B / n_local from each client's ACTUAL dataset
        # size (the accountant's subsampling amplification assumes this).
        engine.attach_accountants([
            PrivacyAccountant(args.sigma,
                              min(1.0, args.batch / data[k].shape[0]), 1e-5)
            for k in range(K)])
    state = engine.init_states(key)

    ckpt = None
    start = 0
    if args.checkpoint_dir:
        ckpt = FederationCheckpointer(
            args.checkpoint_dir, every=args.checkpoint_every,
            fingerprint=config_fingerprint(
                fl, arch=cfg.name, proxy=proxy.name, clients=K,
                # data-shaping flag: resuming under a different skew would
                # silently continue on a different cohort
                size_skew=args.size_skew),
            verify=fl.verify_commitments)
        if args.resume:
            restored = ckpt.restore_latest(engine, like=state, base_key=key)
            if restored is not None:
                state, start = restored
                print(f"[train] resumed from {args.checkpoint_dir} at "
                      f"round {start}")

    # engine-owned round-blocks: up to --rounds-per-block rounds run as one
    # compiled program; the host syncs (checkpoint, ppl eval, logging) only
    # at block edges, and block_spans cuts blocks so every checkpoint-
    # cadence round IS a block edge — the snapshot set matches per-round
    # execution.
    for t, n_block in block_spans(start, args.rounds, args.rounds_per_block,
                                  ckpt.every if ckpt is not None else 0):
        t0 = time.time()
        state, metrics = engine.run_rounds(state, data, t, n_block, key)
        if ckpt is not None:
            ckpt.maybe_save(engine, state, t + n_block - 1, base_key=key)
        dt = time.time() - t0
        ppl = evaluate_ppl(engine.client_params(state, 0, "private"), cfg, test)
        # worst case over clients: under --size-skew the smallest client has
        # the largest sample rate and spends epsilon fastest
        eps = max((a.epsilon() for a in engine.accountants if a is not None),
                  default=float("nan"))
        for i in range(n_block):
            n_active = int(np.sum(~np.isnan(metrics["private_loss"][i])))
            line = (f"[round {t+i+1}/{args.rounds}] "
                    f"private_loss={np.nanmean(metrics['private_loss'][i]):.4f} "
                    f"proxy_loss={np.nanmean(metrics['proxy_loss'][i]):.4f} "
                    f"active={n_active}/{K} ")
            if i == n_block - 1:  # block edge: host-synced ppl/eps/time
                line += f"client0_test_ppl={ppl:.2f} eps={eps:.3f} ({dt:.1f}s)"
            print(line)
    return 0


def tree_size_of(cfg: ModelConfig) -> str:
    return f"{cfg.n_layers}L/d{cfg.d_model}"


if __name__ == "__main__":
    raise SystemExit(main())
