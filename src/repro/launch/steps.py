"""LLM-scale ProxyFL steps — the paper's Algorithm 1 applied to the assigned
architectures on the production mesh.

Three program kinds are built here and lowered by ``dryrun.py``:

* ``train_step``    — ONE client's local DML step (Algorithm 1 lines 2–5):
                      private model updated on Eq. (4) without DP, proxy
                      updated on Eq. (5) with per-example DP-SGD (Eq. 7).
* ``fl_round_step`` — a FULL ProxyFL round with one federated client per
                      pod: vmapped DML over the stacked client dim followed
                      by the PushSum proxy exchange, realized as a single
                      ``jax.lax.ppermute`` along the "pod" mesh axis
                      (Algorithm 1 lines 7–11).
* ``hier_round_block_step`` — the TWO-LEVEL round-block: one shard of
                      stacked clients per pod; the flat PushSum matrix is
                      factored into a local intra-shard matmul plus at most
                      two cross-shard ``ppermute``s per round (the engine's
                      ``backend="hier"`` at production-mesh scale).
* ``prefill_step`` / ``decode_step`` — inference on the client's private
                      model (the paper: "After training, a client's private
                      model can be used for inference").

Everything here is shape-polymorphic over the assigned architectures and is
exercised at full scale only through ``.lower().compile()`` with
``jax.ShapeDtypeStruct`` stand-ins (no allocation).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import InputShape, ModelConfig, ProxyFLConfig
from ..core.dp import dp_gradient_chunked, non_dp_gradient
from ..core.gossip import gossip_shift, hier_mix_schedule, shard_map_fn
from ..nn.losses import dml_loss
from ..nn.model import forward, init_cache, init_model
from ..nn.modules import tree_flatten_vector, tree_unflatten_vector
from ..optim import Adam
from .sharding import batch_pspecs, cache_pspecs

Params = Any


@dataclass(frozen=True)
class StepOptions:
    """Implementation knobs (the §Perf hillclimb levers)."""

    remat: bool = True            # activation-checkpoint the layer-stack scan
    accum: int = 8                # private-grad microbatch accumulation chunks
    dp_chunk: int = 8             # examples per DP vmap chunk (scan over chunks)
    moment_dtype: str = "float32"  # Adam m/v dtype ("bfloat16" halves opt HBM)
    kv_chunk: int = 1024          # online-softmax KV chunk length
    mamba_chunk: int = 256        # Mamba chunked-scan block length
    expert_parallel: bool = False  # shard experts (not d_ff) over "model"
    logits_dtype: str = "float32"  # loss-side logits precision
    serve_2d: bool = False         # weight-stationary 2D-TP decode: params
    # sharded over (data × model), decode batch REPLICATED over data, KV
    # cache sequence-sharded — the per-step ZeRO-3 weight gathers become
    # small activation psums instead (§Perf hillclimb B, qwen1.5-110b)
    shard_acts: bool = False       # with_sharding_constraint on activations
    # (set by dryrun/train on a mesh; default False so single-device tests
    # and the paper-scale simulation backend never reference mesh axes)

    def with_(self, **kw) -> "StepOptions":
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — never allocated)


def input_specs(cfg: ModelConfig, shape: InputShape, *, n_clients: int = 0
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one step at ``shape``. With ``n_clients`` > 0 a
    leading stacked-client dim is added (the multi-pod FL-round layout)."""
    B, S = shape.global_batch, shape.seq_len
    lead = (n_clients,) if n_clients else ()

    def tok(shape_):
        return jax.ShapeDtypeStruct(lead + shape_, jnp.int32)

    if shape.kind == "train":
        if cfg.modality == "audio":
            specs = {"tokens": tok((B, S, cfg.n_codebooks)),
                     "labels": tok((B, S, cfg.n_codebooks))}
        else:
            specs = {"tokens": tok((B, S)), "labels": tok((B, S))}
        if cfg.modality == "vlm":
            specs["img"] = jax.ShapeDtypeStruct(
                lead + (B, cfg.n_image_tokens, cfg.frontend_dim), jnp.dtype(cfg.dtype))
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": tok((B, S, cfg.n_codebooks)) if cfg.modality == "audio"
                 else tok((B, S))}
        if cfg.modality == "vlm":
            specs["img"] = jax.ShapeDtypeStruct(
                lead + (B, cfg.n_image_tokens, cfg.frontend_dim), jnp.dtype(cfg.dtype))
        return specs
    if shape.kind == "decode":
        return {"tokens": tok((B, 1, cfg.n_codebooks)) if cfg.modality == "audio"
                else tok((B, 1)),
                "pos": jax.ShapeDtypeStruct(lead, jnp.int32)}
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# state construction (init fns; shapes via jax.eval_shape in dryrun)


def init_train_state(key, cfg_priv: ModelConfig, cfg_proxy: ModelConfig,
                     fl: ProxyFLConfig, opts: StepOptions) -> Dict:
    opt = Adam(lr=fl.lr, weight_decay=fl.weight_decay, moment_dtype=opts.moment_dtype)
    kp, kx = jax.random.split(key)
    phi = init_model(kp, cfg_priv)
    theta = init_model(kx, cfg_proxy)
    return {
        "private": {"params": phi, "opt": opt.init(phi)},
        "proxy": {"params": theta, "opt": opt.init(theta)},
        "w": jnp.ones((), jnp.float32),   # PushSum de-bias weight
        "t": jnp.zeros((), jnp.int32),
    }


def train_state_shapes(cfg_priv, cfg_proxy, fl, opts) -> Dict:
    return jax.eval_shape(
        lambda k: init_train_state(k, cfg_priv, cfg_proxy, fl, opts),
        jax.random.PRNGKey(0))


def init_serve_state(key, cfg: ModelConfig, shape: InputShape) -> Dict:
    max_len = shape.seq_len + (cfg.n_image_tokens if cfg.modality == "vlm" else 0)
    return {"params": init_model(key, cfg),
            "cache": init_cache(cfg, shape.global_batch, max_len,
                                dtype=jnp.dtype(cfg.dtype))}


def serve_state_shapes(cfg, shape) -> Dict:
    return jax.eval_shape(
        lambda k: init_serve_state(k, cfg, shape), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# losses


def _split_batch(cfg: ModelConfig, batch: Dict):
    return batch["tokens"], batch["labels"], batch.get("img")


def _text_logits(cfg: ModelConfig, logits: jnp.ndarray) -> jnp.ndarray:
    """Drop image-position logits so labels align with text tokens."""
    if cfg.modality == "vlm" and cfg.n_image_tokens:
        return logits[:, cfg.n_image_tokens:]
    return logits


def _constrain_batch(batch: Dict, opts: StepOptions) -> Dict:
    """Pin the batch dim of every batch leaf to the "data" mesh axis.

    Without this, GSPMD propagation through the loss region can decide to
    replicate the (micro)batch and shard vocab instead — turning the CE
    backward into multi-GiB cross-data all-reduces (observed on
    qwen1.5-4b × train_4k before this constraint existed)."""
    if not opts.shard_acts:
        return batch
    return {k: jax.lax.with_sharding_constraint(
                v, P(*(("data",) + (None,) * (v.ndim - 1))))
            for k, v in batch.items() if v is not None}


def _constrain_logits(logits, opts: StepOptions):
    """Logits [B, S, ..., V]: batch on "data", vocab on "model". Inside the
    per-example DP vmap (leading dim 1, example dim carried by
    ``spmd_axis_name="data"``) the batch axis must stay unconstrained."""
    if not opts.shard_acts:
        return logits
    b = "data" if logits.shape[0] > 1 else None
    spec = (b,) + (None,) * (logits.ndim - 2) + ("model",)
    return jax.lax.with_sharding_constraint(logits, P(*spec))


def _forward_logits(params, cfg: ModelConfig, tokens, img, opts: StepOptions):
    ea = "model" if (opts.shard_acts and opts.expert_parallel) else None
    # batch pin only when the (micro)batch can actually divide the data axis
    # (the per-example DP vmap carries its batch via spmd_axis_name instead)
    ba = "data" if (opts.shard_acts and tokens.shape[0] > 1) else None
    # pin the residual stream [B, S, d] between layers: without it the
    # GSPMD solver shards the scan carry on d(model) with batch REPLICATED,
    # and every saved activation / backward dgrad runs at full batch
    # (observed on deepseek-v2 × train_4k: f32[59, 32, 4096, 320] residual
    # stacks and TB-scale dot_general all-reduces)
    act = ("data", None, None) if ba else None
    logits, _, aux = forward(params, cfg, tokens, img, remat=opts.remat,
                             kv_chunk=opts.kv_chunk, mamba_chunk=opts.mamba_chunk,
                             moe_expert_axis=ea, batch_axis=ba, act_spec=act)
    return _constrain_logits(_text_logits(cfg, logits), opts), aux


# ---------------------------------------------------------------------------
# train step (single client — Algorithm 1 lines 2–5)


def make_train_step(cfg_priv: ModelConfig, cfg_proxy: ModelConfig,
                    fl: ProxyFLConfig, opts: StepOptions = StepOptions()):
    opt = Adam(lr=fl.lr, weight_decay=fl.weight_decay, moment_dtype=opts.moment_dtype)

    def step(state, batch, key):
        phi0 = state["private"]["params"]
        theta0 = state["proxy"]["params"]

        # ---- private model: Eq. (4), non-DP, microbatch-accumulated.
        # The proxy peer logits are recomputed per microbatch inside the
        # loss (theta0 is closed over; accumulation slices tokens/labels/img
        # together through the batch dict).
        def ploss(phi, mb):
            mb = _constrain_batch(mb, opts)
            t_, l_, i_ = mb["tokens"], mb["labels"], mb.get("img")
            peer, _ = _forward_logits(theta0, cfg_proxy, t_, i_, opts)
            own, aux = _forward_logits(phi, cfg_priv, t_, i_, opts)
            return dml_loss(own, peer, l_, fl.alpha) + aux

        g_phi, m_phi = non_dp_gradient(ploss, phi0, batch, accum=opts.accum)

        # ---- proxy model: Eq. (5) with per-example DP-SGD (Eq. 7).
        # The private peer logits depend only on phi0, so they are computed
        # ONCE per DP chunk with a batched forward (prepare_chunk) and
        # threaded into the per-example loss — one extra private forward
        # over the batch in total, never per example.
        def add_peer(cb):
            peer, _ = _forward_logits(phi0, cfg_priv, cb["tokens"],
                                      cb.get("img"), opts)
            return dict(cb, peer=peer)

        def xloss(theta, ex):
            t_, l_, i_ = ex["tokens"], ex["labels"], ex.get("img")
            own, aux = _forward_logits(theta, cfg_proxy, t_, i_, opts)
            return dml_loss(own, ex["peer"], l_, fl.beta) + aux

        if fl.dp.enabled:
            g_theta, m_theta = dp_gradient_chunked(
                xloss, theta0, batch, key,
                clip_norm=fl.dp.clip_norm,
                noise_multiplier=fl.dp.noise_multiplier,
                chunk=opts.dp_chunk,
                constrain=lambda b: _constrain_batch(b, opts),
                prepare_chunk=add_peer,
                spmd_axis_name="data" if opts.shard_acts else None)
        else:
            g_theta, m_theta = non_dp_gradient(
                lambda th, b: xloss(th, add_peer(b)), theta0, batch,
                accum=opts.accum)

        phi1, opt_phi1 = opt.update(g_phi, state["private"]["opt"], phi0)
        theta1, opt_theta1 = opt.update(g_theta, state["proxy"]["opt"], theta0)
        new_state = {
            "private": {"params": phi1, "opt": opt_phi1},
            "proxy": {"params": theta1, "opt": opt_theta1},
            "w": state["w"],
            "t": state["t"] + 1,
        }
        metrics = {"private_loss": m_phi["loss"], "proxy_loss": m_theta["loss"]}
        return new_state, metrics

    return step


# ---------------------------------------------------------------------------
# FL round step (multi-pod — one client per pod, gossip on the pod axis)


def make_fl_round_step(cfg_priv: ModelConfig, cfg_proxy: ModelConfig,
                       fl: ProxyFLConfig, mesh, n_clients: int,
                       opts: StepOptions = StepOptions(),
                       round_t: int = 0):
    """Full Algorithm-1 round: vmapped local DML over the stacked client dim
    (sharded on "pod"), then the PushSum exchange as ONE collective-permute
    along "pod" — the TPU-native realization of the paper's O(1)-per-round
    communication claim. ``round_t`` is static (the graph P^(t) is known at
    trace time, exactly like the paper's per-round permutation)."""
    dml = make_train_step(cfg_priv, cfg_proxy, fl, opts)
    shift = gossip_shift(round_t, n_clients, fl.topology)
    self_w = 0.5

    def gossip(flat, w):
        # flat: [K_local(=1 per pod), D]; w: [K_local]
        if shift == 0:
            return flat, w
        perm = [(i, (i + shift) % n_clients) for i in range(n_clients)]
        send_f = (1.0 - self_w) * flat
        send_w = (1.0 - self_w) * w
        recv_f = jax.lax.ppermute(send_f, "pod", perm)
        recv_w = jax.lax.ppermute(send_w, "pod", perm)
        return self_w * flat + recv_f, self_w * w + recv_w

    gossip_sm = shard_map_fn(
        gossip, mesh,
        in_specs=(P("pod"), P("pod")),
        out_specs=(P("pod"), P("pod")))

    def round_step(stacked_state, stacked_batch, keys):
        # local DML on every client in parallel (clients stacked on "pod")
        new_state, metrics = jax.vmap(dml)(stacked_state, stacked_batch, keys)
        # PushSum exchange of the proxies (Algorithm 1 lines 7–11)
        theta = new_state["proxy"]["params"]
        flat = jax.vmap(tree_flatten_vector)(theta)           # [K, D]
        w = new_state["w"]                                    # [K]
        mixed, w2 = gossip_sm(flat, w)
        unb = mixed / jnp.maximum(w2, 1e-9)[:, None]          # de-bias θ/w
        theta2 = jax.vmap(lambda v: tree_unflatten_vector(v, jax.tree_util.tree_map(
            lambda x: x[0], theta)))(unb)
        new_state = dict(new_state)
        new_state["proxy"] = dict(new_state["proxy"], params=theta2)
        new_state["w"] = w2
        return new_state, metrics

    return round_step


def make_round_block_step(cfg_priv: ModelConfig, cfg_proxy: ModelConfig,
                          fl: ProxyFLConfig, mesh, n_clients: int,
                          opts: StepOptions = StepOptions(),
                          n_rounds: int = 4, t0: int = 0):
    """A whole FUSED round-block as one program: ``n_rounds`` consecutive
    Algorithm-1 rounds (local DML + PushSum ppermute each) unrolled inside
    a single jit — the multi-pod counterpart of the FederationEngine's
    round-blocks, and the unit ``dryrun.py --program round_block`` lowers
    so the roofline reports amortized per-BLOCK cost (the per-round
    collective schedules are static, exactly like ``_build_block``'s
    shard_map path). Per-round keys fold in from the stacked client keys,
    so the block replays the same per-round RNG schedule as ``n_rounds``
    separate ``make_fl_round_step`` dispatches with ``fold_in(keys, t)``
    applied by the host. Metrics come back stacked [n_rounds, K]."""
    rounds = [make_fl_round_step(cfg_priv, cfg_proxy, fl, mesh, n_clients,
                                 opts, round_t=t0 + i)
              for i in range(n_rounds)]

    def block_step(stacked_state, stacked_batch, keys):
        ms = []
        for i, round_step in enumerate(rounds):
            round_keys = jax.vmap(
                lambda kk: jax.random.fold_in(kk, t0 + i))(keys)
            stacked_state, m = round_step(stacked_state, stacked_batch,
                                          round_keys)
            ms.append(m)
        metrics = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ms)
        return stacked_state, metrics

    return block_step


def make_hier_round_block_step(cfg_priv: ModelConfig, cfg_proxy: ModelConfig,
                               fl: ProxyFLConfig, mesh, n_shards: int,
                               clients_per_shard: int,
                               opts: StepOptions = StepOptions(),
                               n_rounds: int = 4, t0: int = 0):
    """Two-level (hier) fused round-block: one SHARD of ``clients_per_shard``
    clients per pod, ``n_shards`` = pod count. Each round the flat PushSum
    matrix P^(t) is factored by edge locality (``hier_mix_schedule``): the
    block-diagonal intra-shard part runs as a LOCAL [L, L] matmul over each
    pod's stacked clients (no wire traffic), and the at-most-one cross-shard
    edge per client is realized as a distributed roll — the uniform shift
    σ(t) decomposed as ``q·L + r`` needs at most two ``ppermute``s of the
    [L, D] shard block along "pod" (rounds with σ(t) < L that stay inside
    the shard boundary need at most one). Per-client wire bytes stay O(D),
    independent of K — the paper's O(1)-per-round communication claim at
    the two-level scale ``dryrun.py --program hier_block`` lowers."""
    dml = make_train_step(cfg_priv, cfg_proxy, fl, opts)
    S, L = n_shards, clients_per_shard
    K = S * L

    def make_exchange(t):
        shift = gossip_shift(t, K, fl.topology) % K
        if shift == 0:
            return None
        blocks, _src, scale = hier_mix_schedule("pushsum", t, 1, K, S,
                                                fl.topology)
        blocks0 = jnp.asarray(blocks[0], jnp.float32)  # [S, L, L]
        scale0 = jnp.asarray(scale[0], jnp.float32)    # [K]
        q, r = divmod(shift, L)

        def body(x, w, blk, sc):
            # per-pod view: x [L, D], w [L], blk [1, L, L], sc [L]
            intra = jnp.einsum("ij,jd->id", blk[0], x)
            wm = jnp.einsum("ij,j->i", blk[0], w)

            def from_pods_back(offset, arr):
                # deliver pod (s - offset)'s block to pod s; offset ≡ 0
                # (mod S) is the pod's own block — no collective
                if offset % S == 0:
                    return arr
                perm = [(p, (p + offset) % S) for p in range(S)]
                return jax.lax.ppermute(arr, "pod", perm)

            ax, aw = from_pods_back(q, x), from_pods_back(q, w)
            if r:
                # client j's source j-σ straddles two source shards when
                # σ is not a multiple of L: last r rows come from one pod
                # further back
                bx, bw = from_pods_back(q + 1, x), from_pods_back(q + 1, w)
                rx = jnp.concatenate([bx[L - r:], ax[:L - r]], axis=0)
                rw = jnp.concatenate([bw[L - r:], aw[:L - r]], axis=0)
            else:
                rx, rw = ax, aw
            # sc is zero on rows whose σ-edge stayed intra-shard (those
            # rows were already mixed by the block matmul above)
            return intra + sc[:, None] * rx, wm + sc * rw

        sm = shard_map_fn(body, mesh,
                          in_specs=(P("pod"), P("pod"), P("pod"), P("pod")),
                          out_specs=(P("pod"), P("pod")))
        return lambda flat, w: sm(flat, w, blocks0, scale0)

    exchanges = [make_exchange(t0 + i) for i in range(n_rounds)]

    def block_step(stacked_state, stacked_batch, keys):
        ms = []
        for i, ex in enumerate(exchanges):
            round_keys = jax.vmap(
                lambda kk: jax.random.fold_in(kk, t0 + i))(keys)
            new_state, m = jax.vmap(dml)(stacked_state, stacked_batch,
                                         round_keys)
            if ex is not None:
                theta = new_state["proxy"]["params"]
                flat = jax.vmap(tree_flatten_vector)(theta)   # [K, D]
                mixed, w2 = ex(flat, new_state["w"])
                unb = mixed / jnp.maximum(w2, 1e-9)[:, None]  # de-bias θ/w
                theta2 = jax.vmap(lambda v: tree_unflatten_vector(
                    v, jax.tree_util.tree_map(lambda a: a[0], theta)))(unb)
                new_state = dict(new_state)
                new_state["proxy"] = dict(new_state["proxy"], params=theta2)
                new_state["w"] = w2
            stacked_state = new_state
            ms.append(m)
        metrics = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ms)
        return stacked_state, metrics

    return block_step


# ---------------------------------------------------------------------------
# serve steps (private model inference)


def _serve_act_spec(opts: StepOptions):
    # 2D weight-stationary serving: residual stream [B, S, d] with d
    # sharded over "data" (sequence-parallel style) so matmuls against
    # (data × model)-sharded weights psum small partials instead of
    # gathering weights
    return (None, None, "data") if opts.serve_2d else None


def make_prefill_step(cfg: ModelConfig, opts: StepOptions = StepOptions()):
    def prefill(state, batch):
        tokens = batch["tokens"]
        img = batch.get("img")
        logits, cache, _ = forward(state["params"], cfg, tokens, img,
                                   cache=state["cache"], pos_offset=0,
                                   kv_chunk=opts.kv_chunk,
                                   mamba_chunk=opts.mamba_chunk,
                                   act_spec=_serve_act_spec(opts),
                                   moe_expert_axis="model" if (
                                       opts.shard_acts and opts.expert_parallel)
                                   else None)
        return {"params": state["params"], "cache": cache}, logits[:, -1]

    return prefill


def make_decode_step(cfg: ModelConfig, opts: StepOptions = StepOptions()):
    def decode(state, batch):
        tokens = batch["tokens"]          # [B, 1] (or [B, 1, K] audio)
        pos = batch["pos"]                # scalar int32 — current length
        logits, cache, _ = forward(state["params"], cfg, tokens,
                                   cache=state["cache"], pos_offset=pos,
                                   kv_chunk=opts.kv_chunk,
                                   mamba_chunk=opts.mamba_chunk,
                                   act_spec=_serve_act_spec(opts),
                                   moe_expert_axis="model" if (
                                       opts.shard_acts and opts.expert_parallel)
                                   else None)
        return {"params": state["params"], "cache": cache}, logits[:, -1]

    return decode


# ---------------------------------------------------------------------------
# sharding assembly


def train_shardings(mesh, state_shapes, batch_shapes, *, n_clients: int = 0,
                    expert_parallel: bool = False, modes: Optional[Dict] = None):
    """Per-model placement: ``choose_mode`` picks tp / zero1 / zero3 from the
    replicated-copy size (see sharding.py). ``modes`` overrides per role."""
    from .sharding import choose_mode, tree_pspecs as _tp

    cs = n_clients > 0
    modes = modes or {}
    state_spec: Dict = {}
    for role in ("private", "proxy"):
        p_shapes = state_shapes[role]["params"]
        mode = modes.get(role) or choose_mode(p_shapes, mesh)
        kw = dict(client_stacked=cs, expert_parallel=expert_parallel)
        state_spec[role] = {
            "params": _tp(p_shapes, mesh, fsdp_data=(mode == "zero3"), **kw),
            "opt": _tp(state_shapes[role]["opt"], mesh,
                       fsdp_data=(mode in ("zero1", "zero3")), **kw),
            "_mode": mode,
        }
    lead = P("pod") if cs and "pod" in mesh.axis_names else P()
    state_spec["w"] = lead
    state_spec["t"] = lead
    resolved = {r: state_spec[r].pop("_mode") for r in ("private", "proxy")}
    batch_spec = batch_pspecs(batch_shapes, mesh, client_stacked=cs)
    return state_spec, batch_spec, resolved


def serve_shardings(mesh, state_shapes, batch_shapes, *,
                    expert_parallel: bool = False, serve_2d: bool = False):
    from .sharding import choose_mode, tree_pspecs as _tp

    if serve_2d:
        # weight-stationary 2D TP: weights sharded over data AND model,
        # batch replicated over data, KV sequence sharded over data
        params_spec = _tp(state_shapes["params"], mesh,
                          expert_parallel=expert_parallel, fsdp_data=True)
        cache_spec = cache_pspecs(state_shapes["cache"], mesh,
                                  batch_replicated=True)
        batch_spec = jax.tree_util.tree_map(
            lambda l: P(*([None] * jnp.ndim(l))), batch_shapes)
        return {"params": params_spec, "cache": cache_spec}, batch_spec

    # default: never FSDP unless the replicated copy cannot fit (zero3-style
    # per-step gathers are hostile to decode latency)
    mode = choose_mode(state_shapes["params"], mesh)
    params_spec = _tp(state_shapes["params"], mesh,
                      expert_parallel=expert_parallel,
                      fsdp_data=(mode == "zero3"))
    cache_spec = cache_pspecs(state_shapes["cache"], mesh)
    batch_spec = batch_pspecs(batch_shapes, mesh)
    return {"params": params_spec, "cache": cache_spec}, batch_spec
