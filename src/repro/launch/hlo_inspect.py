import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""HLO inspector — the dry-run "profiler".

Compiles one (arch × shape × mesh) combination exactly like dryrun.py and
prints (a) collective wire bytes aggregated by op_name metadata (with
while-loop trip-count multipliers), (b) the largest live tensors. This is
what the §Perf hypothesis loop reads instead of a wall-clock profile.

    python -m repro.launch.hlo_inspect --arch jamba-1.5-large-398b \
        --shape train_4k --mesh single [--expert-parallel ...]
"""

import argparse
import re
from collections import Counter

import numpy as np

_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
          "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8}


def analyze(txt: str, top: int = 20):
    from .hlo_cost import (_BODY, _CALLS, _COLL_LINE, _COMP_HEADER,
                           _CONDITION, _TRIP, _group_size, _result_bytes,
                           _wire)
    comps = {}
    entry = None
    cur = None
    for line in txt.splitlines():
        h = _COMP_HEADER.match(line)
        if h:
            cur = h.group(2)
            comps[cur] = {"c": [], "e": []}
            if h.group(1):
                entry = cur
            continue
        if cur is None:
            continue
        m = _COLL_LINE.search(line)
        if m:
            d, dims, kind = m.groups()
            md = re.search(r'op_name="([^"]*)"', line)
            comps[cur]["c"].append(
                (kind, _result_bytes(d, dims), _group_size(line),
                 (md.group(1)[:90] if md else line.strip()[:90])))
        if re.search(r"\bwhile\(", line):
            t = _TRIP.search(line)
            n = int(t.group(1)) if t else 1
            b = _BODY.search(line)
            c2 = _CONDITION.search(line)
            if b:
                comps[cur]["e"].append((b.group(1), n))
            if c2:
                comps[cur]["e"].append((c2.group(1), n + 1))
        else:
            for cal in _CALLS.findall(line):
                comps[cur]["e"].append((cal, 1))
    mult = {}
    st = [(entry, 1.0)]
    while st:
        nm, m_ = st.pop()
        mult[nm] = mult.get(nm, 0.0) + m_
        for cal, n in comps.get(nm, {}).get("e", []):
            if cal in comps:
                st.append((cal, m_ * n))
    agg = Counter()
    for nm, d in comps.items():
        for kind, r, g, op in d["c"]:
            agg[(kind, op)] += mult.get(nm, 0) * _wire(kind, r, g)
    print("=== collective wire bytes by op (trip-count weighted) ===")
    for (kind, op), w in agg.most_common(top):
        print(f"{w/2**30:9.2f}GiB {kind:18s} {op}")

    pat = re.compile(r"= (f32|bf16|s32|f16|u32)\[([0-9,]+)\]")
    seen = []
    for line in txt.splitlines():
        m = pat.search(line)
        if m:
            d, dims = m.groups()
            n = int(np.prod([int(x) for x in dims.split(",")])) * _BYTES[d]
            if n > 2 ** 30:
                seen.append((n, line.strip()[:150]))
    seen.sort(key=lambda t: -t[0])
    print("=== tensors >1GiB (per-device) ===")
    done = set()
    for n, l in seen:
        md = re.search(r'op_name="([^"]*)"', l)
        key = md.group(1)[:70] if md else l.split("(")[0][-60:]
        if key in done:
            continue
        done.add(key)
        print(f"{n/2**30:7.2f}GiB {key}")
        if len(done) >= top:
            break


def main(argv=None) -> int:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..configs import INPUT_SHAPES, get_config, list_archs
    from ..configs.base import DPConfig, ProxyFLConfig
    from ..configs.registry import proxy_of
    from .mesh import make_production_mesh, mesh_context
    from .sharding import named
    from .steps import (input_specs, make_decode_step,
                        make_prefill_step, make_train_step, serve_shardings,
                        serve_state_shapes, train_shardings,
                        train_state_shapes)
    from .dryrun import DRYRUN_OPTS

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs(), required=True)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), required=True)
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--accum", type=int)
    ap.add_argument("--dp-chunk", type=int)
    ap.add_argument("--kv-chunk", type=int)
    ap.add_argument("--mamba-chunk", type=int)
    ap.add_argument("--expert-parallel", action="store_true")
    ap.add_argument("--serve-2d", action="store_true")
    ap.add_argument("--moment-dtype")
    args = ap.parse_args(argv)

    opts = DRYRUN_OPTS
    kw = {}
    if args.no_remat:
        kw["remat"] = False
    for name in ("accum", "dp_chunk", "kv_chunk", "mamba_chunk", "moment_dtype"):
        v = getattr(args, name)
        if v is not None:
            kw[name] = v
    if args.expert_parallel:
        kw["expert_parallel"] = True
    if args.serve_2d:
        kw["serve_2d"] = True
    if kw:
        opts = opts.with_(**kw)

    cfg = get_config(args.arch)
    shape = INPUT_SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    fl = ProxyFLConfig(dp=DPConfig(enabled=True))
    if shape.kind == "train":
        proxy = proxy_of(cfg)
        state_sds = train_state_shapes(cfg, proxy, fl, opts)
        batch_sds = input_specs(cfg, shape)
        state_spec, batch_spec, _ = train_shardings(mesh, state_sds, batch_sds,
                                                    expert_parallel=opts.expert_parallel)
        step = make_train_step(cfg, proxy, fl, opts)
        jitted = jax.jit(step, in_shardings=(
            named(state_spec, mesh), named(batch_spec, mesh),
            NamedSharding(mesh, P())),
            out_shardings=(named(state_spec, mesh),
                           named({"private_loss": P(), "proxy_loss": P()}, mesh)),
            donate_argnums=(0,))
        args_ = (state_sds, batch_sds, jax.ShapeDtypeStruct((2,), jnp.uint32))
    else:
        state_sds = serve_state_shapes(cfg, shape)
        batch_sds = input_specs(cfg, shape)
        state_spec, batch_spec = serve_shardings(
            mesh, state_sds, batch_sds, expert_parallel=opts.expert_parallel,
            serve_2d=opts.serve_2d)
        maker = make_prefill_step if shape.kind == "prefill" else make_decode_step
        jitted = jax.jit(maker(cfg, opts), in_shardings=(
            named(state_spec, mesh), named(batch_spec, mesh)),
            out_shardings=(named(state_spec, mesh), None), donate_argnums=(0,))
        args_ = (state_sds, batch_sds)

    with mesh_context(mesh):
        txt = jitted.lower(*args_).compile().as_text()
    analyze(txt, top=args.top)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
