"""Production meshes.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model").

In the ProxyFL mapping each *pod is one federated client* (an institution's
own slice of the fleet): client state is stacked on a leading axis sharded
over "pod", and the PushSum proxy exchange runs along "pod". "data" carries
batch + ZeRO-style parameter/optimizer sharding (FSDP), "model" carries
tensor/expert parallelism.

Functions, not module-level constants — importing this module must never
touch jax device state (the dry-run forces a 512-device host platform
before any jax initialization; tests/benches must keep seeing 1 device).
"""
from __future__ import annotations

from typing import Tuple

import jax

TPU_V5E = {
    "peak_flops_bf16": 197e12,  # per chip
    "hbm_bandwidth": 819e9,  # bytes/s
    "ici_bandwidth": 50e9,  # bytes/s per link
    "hbm_bytes": 16 * 2 ** 30,
}


def mesh_context(mesh):
    """Context manager that installs ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` landed after the 0.4.x series; on older jax the Mesh
    object itself is the context manager (it pushes onto the resource-env
    stack), so fall back to returning ``mesh`` directly.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_client_mesh(n_clients: int = 16, model: int = 16):
    """Distributed-gossip demo mesh: one federated client per 'client' index."""
    return jax.make_mesh((n_clients, model), ("client", "model"))


def fsdp_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes used for batch/FSDP sharding (everything except model/pod)."""
    return tuple(a for a in mesh.axis_names if a in ("data",))


def batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("data",))


def n_pods(mesh) -> int:
    return dict(mesh.shape).get("pod", 1)


def axis_size(mesh, name: str) -> int:
    """Axis size by name; works for Mesh and AbstractMesh (both expose a
    name->size ``.shape`` mapping)."""
    return dict(mesh.shape).get(name, 1)
