"""Cost models for the dry-run roofline.

Two complementary sources:

1. **Jaxpr walker** (:func:`jaxpr_cost`) — XLA's ``cost_analysis()`` counts
   every ``while`` body ONCE, so any scan-based program (our layer stacks,
   microbatch accumulation, DP per-example loop, KV-chunked attention) is
   undercounted by the trip count. We therefore walk the traced jaxpr where
   every ``scan`` carries its static ``length`` and multiply body costs
   through. FLOPs are exact for dot/conv (2·M·N·K) and approximate
   (1 flop/element) for elementwise ops. Memory traffic uses a
   fused-elementwise model: only "major" ops (dot, conv, gather/scatter,
   dynamic slices, reduces, RNG) are charged HBM reads/writes — chains of
   elementwise ops are assumed fused by XLA and never hit HBM.
   Costs are GLOBAL (logical shapes); divide by chip count for per-device
   numbers under the perfect-SPMD assumption.

2. **HLO collective parser** (:func:`collective_wire_bytes`) — the
   post-SPMD-partitioning HLO is the per-device program; every collective
   op line carries its (per-device) result shape and replica groups. We
   convert those to per-device *wire* bytes with the standard ring-algorithm
   factors, which is what the ICI roofline term wants.
"""
from __future__ import annotations

import re
from typing import Any, Dict

import jax
import numpy as np

# ---------------------------------------------------------------------------
# jaxpr cost walker

_MAJOR_MEM_PRIMS = {
    "dot_general", "conv_general_dilated", "gather", "scatter", "scatter-add",
    "scatter_add", "dynamic_slice", "dynamic_update_slice", "reduce_sum",
    "reduce_max", "reduce_min", "reduce_prod", "reduce_and", "reduce_or",
    "argmax", "argmin", "sort", "random_bits", "cumsum", "cumlogsumexp",
    "cummax", "top_k",
}

_CALL_PRIMS = {
    "pjit", "closed_call", "core_call", "remat", "remat2", "checkpoint",
    "custom_jvp_call", "custom_vjp_call", "custom_jvp_call_jaxpr",
    "custom_vjp_call_jaxpr", "shard_map", "custom_partitioning",
}


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize if aval.shape else aval.dtype.itemsize
    except Exception:
        return 0


def _aval_size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:
        return 0


def _dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    m = 1
    for i, d in enumerate(lhs.shape):
        if i not in lc and i not in lb:
            m *= d
    n = 1
    for i, d in enumerate(rhs.shape):
        if i not in rc and i not in rb:
            n *= d
    k = 1
    for i in lc:
        k *= lhs.shape[i]
    b = 1
    for i in lb:
        b *= lhs.shape[i]
    return 2.0 * b * m * n * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # flops = 2 * out_elems * (kernel spatial * in_channels)
    dnums = eqn.params["dimension_numbers"]
    k_spatial = 1
    for i in dnums.rhs_spec[2:]:
        k_spatial *= rhs.shape[i]
    cin = rhs.shape[dnums.rhs_spec[1]]
    return 2.0 * _aval_size(out) * k_spatial * cin


def _sub_jaxprs(eqn):
    """(jaxpr, multiplier) pairs for call-like primitives."""
    p = eqn.params
    name = eqn.primitive.name
    if name == "scan":
        return [(p["jaxpr"], int(p["length"]))]
    if name == "while":
        subs = []
        if "body_jaxpr" in p:
            subs.append((p["body_jaxpr"], 1))
        if "cond_jaxpr" in p:
            subs.append((p["cond_jaxpr"], 1))
        return subs
    if name == "cond":
        return [(b, 1) for b in p.get("branches", ())][:1] or []
    for k in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if k in p:
            return [(p[k], 1)]
    return []


def jaxpr_cost(jaxpr) -> Dict[str, float]:
    """{"flops": ..., "bytes": ...} — global, trip-count-corrected."""
    if hasattr(jaxpr, "jaxpr"):  # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    flops = 0.0
    mem = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        subs = _sub_jaxprs(eqn)
        if subs:
            for sub, mult in subs:
                c = jaxpr_cost(sub)
                flops += mult * c["flops"]
                mem += mult * c["bytes"]
            continue
        out_b = sum(_aval_bytes(v.aval) for v in eqn.outvars)
        out_n = sum(_aval_size(v.aval) for v in eqn.outvars)
        if name == "dot_general":
            flops += _dot_flops(eqn)
        elif name == "conv_general_dilated":
            flops += _conv_flops(eqn)
        elif name.startswith("reduce") or name in ("cumsum", "argmax", "argmin"):
            flops += sum(_aval_size(v.aval) for v in eqn.invars)
        else:
            flops += out_n  # elementwise approx: 1 flop / output element
        if name in _MAJOR_MEM_PRIMS:
            in_b = sum(_aval_bytes(v.aval) for v in eqn.invars
                       if hasattr(v, "aval"))
            mem += in_b + out_b
    return {"flops": flops, "bytes": mem}


def step_cost(fn, *args) -> Dict[str, float]:
    """Trace ``fn`` at ShapeDtypeStruct args and return its global cost."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    return jaxpr_cost(jaxpr)


# ---------------------------------------------------------------------------
# HLO collective parser

_COLL_LINE = re.compile(
    r"=\s*(?:\()?\s*(pred|s4|s8|s16|s32|s64|u8|u16|u32|u64|f8e4m3fn|f8e5m2|"
    r"f16|bf16|f32|f64|c64|c128)\[([0-9,]*)\][^=]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "c64": 8,
    "s64": 8, "u64": 8, "f64": 8, "c128": 16,
}


def _result_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_BODY = re.compile(r"body=%?([\w.\-]+)")
_CONDITION = re.compile(r"condition=%?([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|true_computation|false_computation)"
                    r"=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")


def _wire(kind: str, r: int, g: int) -> float:
    """Ring-algorithm per-device wire volume for result bytes R, group g:
      all-reduce:          2·(g−1)/g · R          (reduce-scatter + all-gather)
      all-gather:          (g−1)/g · R            (R is the gathered result)
      reduce-scatter:      (g−1) · R              (operand is g× the result)
      all-to-all:          (g−1)/g · R
      collective-permute:  R                      (point-to-point)
    """
    if g <= 1 and kind != "collective-permute":
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g * r
    if kind == "all-gather":
        return (g - 1) / g * r
    if kind == "reduce-scatter":
        return (g - 1.0) * r
    if kind == "all-to-all":
        return (g - 1) / g * r
    return float(r)  # collective-permute


def collective_wire_bytes(hlo_text: str) -> Dict[str, Any]:
    """Per-device ICI wire bytes by collective kind, from post-SPMD HLO.

    Collectives inside while-loop bodies (our layer-stack / microbatch / DP
    scans) execute once per iteration, so their bytes are multiplied by the
    loop's ``known_trip_count`` backend annotation, propagated through the
    computation call graph from ENTRY.
    """
    # pass 1: split into computations; collect collectives and call edges
    comps: Dict[str, Dict[str, list]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        h = _COMP_HEADER.match(line)
        if h:
            cur = h.group(2)
            comps[cur] = {"colls": [], "calls": []}
            if h.group(1):
                entry = cur
            continue
        if cur is None:
            continue
        m = _COLL_LINE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            comps[cur]["colls"].append(
                (kind, _result_bytes(dtype, dims), _group_size(line)))
        if " while(" in line or "= while(" in line or re.search(r"\bwhile\(", line):
            t = _TRIP.search(line)
            n = int(t.group(1)) if t else 1
            b = _BODY.search(line)
            c = _CONDITION.search(line)
            if b:
                comps[cur]["calls"].append((b.group(1), n))
            if c:
                comps[cur]["calls"].append((c.group(1), n + 1))
        else:
            for callee in _CALLS.findall(line):
                comps[cur]["calls"].append((callee, 1))
            br = _BRANCHES.search(line)
            if br:
                for callee in br.group(1).split(","):
                    comps[cur]["calls"].append((callee.strip().lstrip("%"), 1))

    # pass 2: propagate execution multipliers from ENTRY
    mult: Dict[str, float] = {}
    if entry is None:  # fall back: count everything once
        entry_list = list(comps)
        for c in entry_list:
            mult[c] = 1.0
    else:
        stack = [(entry, 1.0)]
        while stack:
            name, m_ = stack.pop()
            mult[name] = mult.get(name, 0.0) + m_
            for callee, n in comps.get(name, {}).get("calls", []):
                if callee in comps:
                    stack.append((callee, m_ * n))

    out: Dict[str, float] = {}
    count: Dict[str, float] = {}
    for name, data in comps.items():
        m_ = mult.get(name, 0.0)
        if not m_:
            continue
        for kind, r, g in data["colls"]:
            out[kind] = out.get(kind, 0.0) + m_ * _wire(kind, r, g)
            count[kind] = count.get(kind, 0) + m_
    return {"wire_bytes": out, "op_counts": count,
            "total_wire_bytes": sum(out.values())}
