"""Sharding rules: parameter, optimizer, cache and batch PartitionSpecs.

Conventions (Megatron + ZeRO, adapted to the ProxyFL client mapping):

* leading CLIENT dim of federation state  -> "pod"  (one client per pod)
* stacked layer-repeat dim (under stack/) -> never sharded (lax.scan runs
  over it; sharding it would turn every scan step into a collective)
* weight output dim                       -> "model"  (column parallel)
* weight input dim (wo/down/out_proj)     -> "model"  (row parallel)
* one remaining large dim                 -> "data"   (ZeRO-3 / FSDP)
* batch dim of activations                -> "data"
* KV-cache: batch -> "data" (or seq when batch=1), head_dim -> "model"

``expert_parallel=True`` switches stacked expert weights from tensor
parallelism to expert parallelism (experts over "model") — a perf lever
explored in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import axis_size

_ROW_PARALLEL = re.compile(r"(wo|down|out_proj|residual/down|shared/down)(/w)?$")
_EXPERT_STACK = re.compile(r"ffn/(gate|up|down)$")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _assign(dims, used, size_of, axis_total, *, prefer, min_shard=8):
    """Pick one dim index from ``dims`` (ordered by ``prefer``) divisible by
    axis_total with a reasonable shard; returns index or None."""
    order = sorted(dims, key=prefer)
    for d in order:
        if d in used:
            continue
        if size_of(d) % axis_total == 0 and size_of(d) // axis_total >= min_shard:
            return d
    return None


def param_pspec(
    path_str: str,
    shape,
    mesh: Mesh,
    *,
    client_stacked: bool = False,
    expert_parallel: bool = False,
    fsdp_data: bool = True,
) -> P:
    model = axis_size(mesh, "model")
    data = axis_size(mesh, "data")
    has_pod = "pod" in mesh.axis_names
    ndim = len(shape)
    spec: list = [None] * ndim
    lo = 0
    if client_stacked:
        if has_pod:
            spec[0] = "pod"
        lo = 1
    if "stack/" in path_str or path_str.startswith("stack"):
        lo += 1  # layer-repeat dim: never sharded

    dims = [d for d in range(lo, ndim)]
    if not dims:
        return P(*spec)
    size_of = lambda d: shape[d]
    total = 1
    for d in dims:
        total *= shape[d]
    if total < 2 ** 15:  # small tensors: replicate (cheaper than tiny shards)
        return P(*spec)

    used = set()
    # Embedding tables: shard the VOCAB dim on "model". The lookup side only
    # costs a small [tokens, d] all-reduce, while the logits side (tied
    # embeddings, and every head/w) then produces vocab-sharded logits with
    # no collective — the loss is written to reduce vocab locally.
    if path_str.endswith("embed/e"):
        v_dim = lo if ndim - lo == 2 else lo + 1  # audio tables are [K, V, d]
        if v_dim < ndim and shape[v_dim] % model == 0:
            spec[v_dim] = "model"
            used.add(v_dim)
    is_expert = bool(_EXPERT_STACK.search(path_str)) and ndim - lo >= 3
    if expert_parallel and is_expert:
        e_dim = dims[0]  # expert dim directly after client/stack dims
        if shape[e_dim] % model == 0:
            spec[e_dim] = "model"
            used.add(e_dim)
    if "model" not in spec:
        if _ROW_PARALLEL.search(path_str):
            m = _assign(dims, used, size_of, model, prefer=lambda d: (d != ndim - 2, -shape[d]))
        else:
            m = _assign(dims, used, size_of, model, prefer=lambda d: (d != ndim - 1, -shape[d]))
        if m is not None:
            spec[m] = "model"
            used.add(m)
    if fsdp_data:
        f = _assign(dims, used, size_of, data, prefer=lambda d: -shape[d], min_shard=4)
        if f is not None:
            spec[f] = "data"
    return P(*spec)


def tree_pspecs(tree, mesh: Mesh, *, client_stacked=False, expert_parallel=False,
                fsdp_data=True):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = [
        param_pspec(_path_str(path), jnp.shape(leaf), mesh,
                    client_stacked=client_stacked, expert_parallel=expert_parallel,
                    fsdp_data=fsdp_data)
        for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


def _n_elems(l) -> int:
    shape = getattr(l, "shape", ())
    n = 1
    for d in shape:
        n *= int(d)
    return n


def tree_bytes(tree) -> int:
    return sum(
        int(jnp.dtype(getattr(l, "dtype", jnp.float32)).itemsize) * _n_elems(l)
        for l in jax.tree_util.tree_leaves(tree))


def choose_mode(params_shapes, mesh: Mesh, *, budget_bytes: float = 6e9) -> str:
    """Pick the parameter/optimizer placement for one model:

    * ``tp``    — tensor parallel only; params AND optimizer replicated over
                  "data". Zero gather traffic per forward; grads all-reduce
                  once per step. Best when 3×|θ|/model_axis fits.
    * ``zero1`` — params replicated over "data" (fast forwards), optimizer
                  moments sharded over "data" (ZeRO-1). Grads reduce-scatter,
                  updated params all-gather once per step.
    * ``zero3`` — params and optimizer sharded over "data" too (ZeRO-3 /
                  FSDP); weights are gathered per traversal. Only for models
                  whose replicated copy cannot fit.
    """
    model = axis_size(mesh, "model")
    total = tree_bytes(params_shapes)
    # optimizer ≈ 2 fp32 moments + fp32 master copy for sub-fp32 params
    n_elems = sum(_n_elems(l) for l in jax.tree_util.tree_leaves(params_shapes))
    master = any(jnp.dtype(getattr(l, "dtype", jnp.float32)) != jnp.float32
                 for l in jax.tree_util.tree_leaves(params_shapes))
    opt = (12 if master else 8) * n_elems
    if (total + opt) / model <= budget_bytes:
        return "tp"
    if total / model <= budget_bytes:
        return "zero1"
    return "zero3"


# ---------------------------------------------------------------------------
# caches


def _batch_axes_for(mesh: Mesh, extent: int):
    """Largest of ("pod","data") / ("data",) that divides ``extent``."""
    data = axis_size(mesh, "data")
    pod = axis_size(mesh, "pod") if "pod" in mesh.axis_names else 1
    if pod > 1 and extent % (pod * data) == 0 and extent >= pod * data:
        return ("pod", "data")
    if extent % data == 0 and extent >= data:
        return "data"
    return None


def cache_pspec(path_str: str, shape, mesh: Mesh, *, seq_shard: bool = True,
                batch_replicated: bool = False) -> P:
    model = axis_size(mesh, "model")
    data = axis_size(mesh, "data")
    ndim = len(shape)
    spec: list = [None] * ndim
    name = path_str.rsplit("/", 1)[-1]
    # batch/seq placement (batch preferred; batch=1 long-context — or the
    # 2D weight-stationary decode scheme — shards the KV sequence instead)
    ba = None if batch_replicated else _batch_axes_for(mesh, shape[0])
    if ba is not None:
        spec[0] = ba
    elif seq_shard and ndim >= 2 and name in ("k", "v", "ckv", "kr"):
        sa = _batch_axes_for(mesh, shape[1])
        if sa is not None:
            spec[1] = sa  # long-context: shard the KV sequence
    # feature placement
    if name in ("k", "v"):
        hd, H = shape[3], shape[2]
        if hd % model == 0:
            spec[3] = "model"
        elif H % model == 0:
            spec[2] = "model"
    elif name in ("ckv", "kr"):
        if shape[2] % model == 0:
            spec[2] = "model"
    elif name == "conv":
        if shape[2] % model == 0:
            spec[2] = "model"
    elif name == "ssm":
        if shape[1] % model == 0:
            spec[1] = "model"
    return P(*spec)


def cache_pspecs(cache, mesh: Mesh, *, seq_shard: bool = True,
                 batch_replicated: bool = False):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    specs = [cache_pspec(_path_str(p), jnp.shape(l), mesh, seq_shard=seq_shard,
                         batch_replicated=batch_replicated)
             for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# batches / activations


def batch_pspec(shape, mesh: Mesh, *, client_stacked=False) -> P:
    """Tokens/labels/img arrays: [(K,) B, ...] -> client on pod, batch on
    ("pod","data") (single-client multi-pod = pure data parallel over pods)
    or just "data" when clients occupy the pod axis."""
    data = axis_size(mesh, "data")
    ndim = len(shape)
    spec: list = [None] * ndim
    b = 0
    if client_stacked:
        if "pod" in mesh.axis_names:
            spec[0] = "pod"
        b = 1
        axes_for = lambda n: ("data" if n % data == 0 and n >= data else None)
    else:
        axes_for = lambda n: _batch_axes_for(mesh, n)
    if ndim > b and axes_for(shape[b]) is not None:
        spec[b] = axes_for(shape[b])
    elif ndim > b + 1 and axes_for(shape[b + 1]) is not None:
        spec[b + 1] = axes_for(shape[b + 1])  # batch=1 long-context: shard sequence
    return P(*spec)


def batch_pspecs(batch, mesh: Mesh, *, client_stacked=False):
    return jax.tree_util.tree_map(
        lambda l: batch_pspec(jnp.shape(l), mesh, client_stacked=client_stacked), batch)


def named(tree_of_pspecs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_of_pspecs,
        is_leaf=lambda x: isinstance(x, P))
