"""Batching for DP-SGD.

The RDP accountant for the SAMPLED Gaussian mechanism formally assumes
POISSON subsampling: each example enters the batch independently with
probability q = B/N (paper §4.1, citing Yu et al. 2019: "mini-batches are
sampled from the training set independently with replacement by including
each training example with a fixed probability").

Two loaders are provided:

* :func:`sample_batch` — fixed-size uniform sampling with replacement, the
  standard practical surrogate (Abadi et al. 2016 §5); keeps jit shapes
  static.
* :func:`poisson_batch` — exact Poisson subsampling. The variable batch
  size is padded/truncated to a static ``max_batch`` with a weight mask so
  the jitted step keeps one shape: selected examples get weight 1, padding
  gets 0, and the DP-SGD mean divides by the EXPECTED batch size qN (the
  estimator the accountant's sensitivity analysis assumes).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def sample_batch(key, x: jnp.ndarray, y: jnp.ndarray, batch: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    idx = jax.random.randint(key, (batch,), 0, x.shape[0])
    return x[idx], y[idx]


def poisson_batch(key, x: jnp.ndarray, y: jnp.ndarray, q: float,
                  max_batch: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Poisson-subsampled batch, padded to ``max_batch``.

    Returns (xb, yb, mask) where mask[i] in {0., 1.} marks real examples.
    Selected examples beyond ``max_batch`` are dropped (prob. negligible
    when max_batch ≳ qN + 4·sqrt(qN(1-q))); unselected slots repeat example
    0 with mask 0, so downstream per-example clipping sees zero gradients
    there (clip(0) = 0 contributes nothing to the sum).
    """
    n = x.shape[0]
    sel = jax.random.bernoulli(key, q, (n,))
    # stable order: selected indices first
    order = jnp.argsort(~sel)  # False<True; selected (True) first under ~
    take = order[:max_batch]
    mask = sel[take].astype(jnp.float32)
    return x[take], y[take], mask


def expected_batch(q: float, n: int) -> float:
    return q * n


def steps_per_epoch(n: int, batch: int) -> int:
    return max(1, -(-n // batch))
