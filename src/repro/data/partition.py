"""Federated partitioners reproducing the paper's non-IID structures:

* ``partition_major`` — §4.1: each client gets one randomly-assigned major
  class contributing fraction ``p_major`` of its data, rest IID.
  (p_major = 1/n_classes is the IID setting.)
* ``partition_dirichlet`` — §4.3/4.4: class proportions per client drawn
  from Dirichlet(alpha) (Yurochkin et al. 2019).
"""
from __future__ import annotations

from typing import List

import numpy as np


def partition_major(
    rng: np.random.Generator,
    y: np.ndarray,
    n_clients: int,
    per_client: int,
    p_major: float,
    n_classes: int,
) -> List[np.ndarray]:
    """Returns per-client index arrays into the source dataset (disjoint)."""
    pools = {c: list(rng.permutation(np.where(y == c)[0])) for c in range(n_classes)}
    majors = rng.integers(0, n_classes, size=n_clients)
    out = []
    n_major = int(round(p_major * per_client))
    for k in range(n_clients):
        idx = []
        mc = int(majors[k])
        take = min(n_major, len(pools[mc]))
        idx.extend(pools[mc][:take])
        pools[mc] = pools[mc][take:]
        # remaining drawn IID from the other classes
        others = [c for c in range(n_classes) if c != mc and pools[c]]
        while len(idx) < per_client and others:
            c = int(rng.choice(others))
            idx.append(pools[c].pop())
            others = [c for c in others if pools[c]]
        out.append(np.array(idx[:per_client], dtype=np.int64))
    return out


def partition_dirichlet(
    rng: np.random.Generator,
    y: np.ndarray,
    n_clients: int,
    alpha: float,
) -> List[np.ndarray]:
    n_classes = int(y.max()) + 1
    idx_by_class = [rng.permutation(np.where(y == c)[0]) for c in range(n_classes)]
    client_idx: List[list] = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx_by_class[c])).astype(int)[:-1]
        for k, part in enumerate(np.split(idx_by_class[c], cuts)):
            client_idx[k].extend(part.tolist())
    return [np.array(sorted(ix), dtype=np.int64) for ix in client_idx]
