"""Synthetic datasets.

The paper's datasets (MNIST/FaMNIST/CIFAR-10/Kvasir/Camelyon-17) are not
available offline, so benchmarks use class-conditional synthetic images with
the *same federated structure* (sizes, class counts, non-IID partitions).
Difficulty is controlled by the class-mean separation vs noise scale, chosen
so that (a) local-only training generalizes poorly on skewed clients and
(b) collaborative methods can close most of the gap — the regime the paper's
figures probe.

``make_lm_data`` generates token streams from per-domain random bigram
Markov chains (domain structure keyed by ``domain``, not the sampling key) for the LLM-scale ProxyFL examples: clients draw from
different domain mixtures (non-IID), and cross-entropy on held-out mixed
streams plays the role of the joint test set.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def make_classification_data(
    key,
    n: int,
    image_shape: Tuple[int, int, int],
    n_classes: int,
    *,
    sep: float = 1.0,
    noise: float = 1.0,
    task_seed: int = 0,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Class-conditional Gaussian images: x = sep * mu_y + noise * eps.

    The class means are derived from ``task_seed`` (NOT from ``key``) so that
    train/test splits drawn with different sampling keys share the same task.
    """
    km = jax.random.PRNGKey(task_seed)
    ky, kx = jax.random.split(key, 2)
    d = int(jnp.prod(jnp.array(image_shape)))
    # smooth-ish class means: low-dim random basis mixed per class
    basis = jax.random.normal(km, (16, d)) / jnp.sqrt(d)
    coef = jax.random.normal(jax.random.fold_in(km, 1), (n_classes, 16))
    mu = coef @ basis  # [C, d]
    y = jax.random.randint(ky, (n,), 0, n_classes)
    x = sep * mu[y] + noise * jax.random.normal(kx, (n, d)) / jnp.sqrt(d) * 4.0
    return x.reshape((n,) + tuple(image_shape)), y


def make_lm_data(
    key,
    n_tokens: int,
    vocab: int,
    *,
    domain: int = 0,
    order_sharpness: float = 4.0,
) -> jnp.ndarray:
    """Token stream from a random bigram chain specific to ``domain``."""
    kt = jax.random.PRNGKey(7_000_000 + domain)  # chain fixed by domain id
    ks = jax.random.fold_in(key, domain)
    logits = order_sharpness * jax.random.normal(kt, (vocab, vocab))

    def step(tok, k):
        nxt = jax.random.categorical(k, logits[tok])
        return nxt, nxt

    keys = jax.random.split(ks, n_tokens + 1)
    first = jax.random.randint(keys[0], (), 0, vocab)
    _, toks = jax.lax.scan(step, first, keys[1:])
    return toks.astype(jnp.int32)


def lm_examples(stream: jnp.ndarray, seq_len: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chop a stream into (inputs, next-token labels) examples."""
    n = (stream.shape[0] - 1) // seq_len
    x = stream[: n * seq_len].reshape(n, seq_len)
    y = stream[1 : n * seq_len + 1].reshape(n, seq_len)
    return x, y
