"""Padded stacking for ragged (size-skewed) federated cohorts.

The paper's non-IID settings (§4.3/4.4 Dirichlet partitions for Kvasir and
the Camelyon histology task) give every client a *different* number of
local examples, but the engine's compiled vmap/shard_map round wants one
rectangular ``[K, N, ...]`` stack. This module bridges the two:

* :func:`pad_compatible` — can a cohort's per-client data pytrees be
  stacked at all?  True iff every client has the same tree structure and
  every leaf agrees on dtype and trailing dims; ONLY the leading (example
  count) dim may differ. Anything else — different architectures' feature
  shapes, extra keys — is genuinely incompatible and belongs on the loop
  backend.
* :func:`client_lengths` — per-client example counts (the leading dim all
  of a client's leaves must share).
* :func:`pad_stack` — pad every leaf along axis 0 to the cohort max and
  stack into ``[K, N_max, ...]``, returning ``(stacked, n_valid)`` with
  ``n_valid: int32[K]`` the true per-client lengths.

Padding semantics
-----------------
Rows ``n_valid[k]:`` of client ``k``'s slice are padding (``fill`` value,
0 by default). Padding is *inert by construction*, not by value: samplers
draw batch indices via ``randint(0, n_valid[k])`` so a padded row is never
selected, and per-client step counts are derived from ``n_valid`` — so the
fill value never reaches a gradient. The engine's state (params, optimizer
moments, PushSum weights) contains no padding; checkpoints of a federation
running on padded data are byte-identical in layout to the rectangular
case and round-trip bit-exactly.
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def client_lengths(data: Sequence[Any]) -> np.ndarray:
    """int64[K] per-client example counts.

    Every leaf of one client's pytree must share the leading dim (that is
    what "the client holds n_k examples" means); raises otherwise.
    """
    out = []
    for k, d in enumerate(data):
        leaves = jax.tree_util.tree_leaves(d)
        if not leaves:
            raise ValueError(f"client {k} has an empty data pytree")
        ns = {x.shape[0] if getattr(x, "ndim", 0) else None for x in leaves}
        if len(ns) != 1 or None in ns:
            raise ValueError(
                f"client {k}'s leaves disagree on the leading (example) "
                f"dim: {sorted(x.shape for x in leaves)}")
        out.append(leaves[0].shape[0])
    return np.asarray(out, np.int64)


def pad_compatible(data: Sequence[Any]) -> bool:
    """True iff the cohort can run on the stacked (vmap/shard_map) path:
    one shared tree structure, and each leaf position agrees on dtype and
    trailing dims across clients (leading dims are free to differ)."""
    if len(data) == 0:
        return False
    try:
        structs = {jax.tree_util.tree_structure(d) for d in data}
        if len(structs) != 1:
            return False
        client_lengths(data)  # consistent leading dim within each client
        sigs = {
            tuple((x.dtype, x.shape[1:])
                  for x in jax.tree_util.tree_leaves(d))
            for d in data}
        return len(sigs) == 1
    except (ValueError, AttributeError):
        return False


def pad_stack(data: Sequence[Any], fill: float = 0
              ) -> Tuple[Any, jnp.ndarray]:
    """Stack a (possibly ragged) cohort into one ``[K, N_max, ...]`` pytree.

    Returns ``(stacked, n_valid)``; ``n_valid`` is ``int32[K]``. For an
    already-rectangular cohort this is exactly ``tree_map(stack)`` (no
    padding rows, ``n_valid`` constant). ``fill`` sets the padding value —
    it must never be read (see module docstring), so tests pad with NaN to
    prove the sampler masks correctly.
    """
    n_valid = client_lengths(data)
    if (n_valid <= 0).any():
        raise ValueError(
            "clients with zero examples cannot be sampled: "
            f"per-client sizes {n_valid.tolist()}")
    n_max = int(n_valid.max())

    def pad(x):
        short = n_max - x.shape[0]
        if short == 0:
            return x
        return jnp.pad(x, [(0, short)] + [(0, 0)] * (x.ndim - 1),
                       constant_values=fill)

    padded = [jax.tree_util.tree_map(pad, d) for d in data]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *padded)
    return stacked, jnp.asarray(n_valid, jnp.int32)
