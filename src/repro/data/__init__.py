from .loader import sample_batch, steps_per_epoch
from .partition import partition_dirichlet, partition_major
from .ragged import client_lengths, pad_compatible, pad_stack
from .synthetic import lm_examples, make_classification_data, make_lm_data

__all__ = [
    "sample_batch",
    "steps_per_epoch",
    "partition_dirichlet",
    "partition_major",
    "client_lengths",
    "pad_compatible",
    "pad_stack",
    "lm_examples",
    "make_classification_data",
    "make_lm_data",
]
