from .loader import sample_batch, steps_per_epoch
from .partition import partition_dirichlet, partition_major
from .synthetic import lm_examples, make_classification_data, make_lm_data

__all__ = [
    "sample_batch",
    "steps_per_epoch",
    "partition_dirichlet",
    "partition_major",
    "lm_examples",
    "make_classification_data",
    "make_lm_data",
]
