from .optimizers import Adam, AdamState, SGD

__all__ = ["Adam", "AdamState", "SGD"]
