"""Functional optimizers (pytree in, pytree out). Adam matches torch.optim.Adam
(the paper's optimizer: lr 1e-3, weight decay 1e-4 — additive L2, not AdamW)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any


class AdamState(NamedTuple):
    m: Params
    v: Params
    t: jnp.ndarray
    # fp32 master copy of sub-fp32 params (None when params are fp32).
    # Without it, bf16 weights near 1.0 cannot absorb lr≈1e-3 updates at all
    # (bf16 resolution at 1.0 is ~8e-3) — the canonical mixed-precision trap.
    p32: Params = None


@dataclass(frozen=True)
class Adam:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    # Moment dtype. fp32 is the default; "bfloat16" halves optimizer HBM
    # (the dominant state term for the ≥398B archs at 256 chips) at a small
    # update-precision cost — a documented hardware-adaptation lever.
    moment_dtype: str = "float32"
    master_weights: bool = True

    def _needs_master(self, params) -> bool:
        return self.master_weights and any(
            x.dtype != jnp.float32
            for x in jax.tree_util.tree_leaves(params))

    def init(self, params: Params) -> AdamState:
        md = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, md), p)
        p32 = None
        if self._needs_master(params):
            p32 = jax.tree_util.tree_map(
                lambda x: x.astype(jnp.float32), params)
        return AdamState(zeros(params), zeros(params),
                         jnp.zeros((), jnp.int32), p32)

    def update(self, grads: Params, state: AdamState, params: Params
               ) -> Tuple[Params, AdamState]:
        t = state.t + 1
        b1, b2 = self.b1, self.b2
        md = jnp.dtype(self.moment_dtype)
        base = state.p32 if state.p32 is not None else params
        if self.weight_decay:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + self.weight_decay * p.astype(g.dtype),
                grads, base)
        gf = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        m = jax.tree_util.tree_map(
            lambda m, g: (b1 * m.astype(jnp.float32) + (1 - b1) * g).astype(md),
            state.m, gf)
        v = jax.tree_util.tree_map(
            lambda v, g: (b2 * v.astype(jnp.float32) + (1 - b2) * g * g).astype(md),
            state.v, gf)
        tf = t.astype(jnp.float32)
        c1 = 1 - b1 ** tf
        c2 = 1 - b2 ** tf

        def upd32(p, m, v):
            step = self.lr * (m.astype(jnp.float32) / c1) / (
                jnp.sqrt(v.astype(jnp.float32) / c2) + self.eps)
            return p.astype(jnp.float32) - step

        new32 = jax.tree_util.tree_map(upd32, base, m, v)
        new_params = jax.tree_util.tree_map(
            lambda n, p: n.astype(p.dtype), new32, params)
        p32 = new32 if state.p32 is not None else None
        return new_params, AdamState(m, v, t, p32)


@dataclass(frozen=True)
class SGD:
    lr: float = 0.1
    weight_decay: float = 0.0

    def init(self, params: Params):
        return ()

    def update(self, grads, state, params):
        if self.weight_decay:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + self.weight_decay * p.astype(g.dtype), grads, params)
        new = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32) - self.lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new, state
