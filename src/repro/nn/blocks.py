"""Decoder layer = pre-norm token mixer (attn/MLA/mamba) + pre-norm FFN
(dense / MoE / none), with residuals. Uniform (x, cache, aux) interface so
the model stack can `lax.scan` over stacked per-layer parameters."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import LayerSpec, ModelConfig
from .attention import apply_attention, init_attention, init_kv_cache
from .mamba import apply_mamba, init_mamba, init_mamba_cache
from .mla import apply_mla, init_mla, init_mla_cache
from .modules import Params, init_mlp, init_rmsnorm, mlp, rmsnorm
from .moe import apply_moe, init_moe


def init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    p: Params = {"norm1": init_rmsnorm(cfg.d_model, dtype)}
    if spec.kind == "attn":
        if cfg.attn_impl == "mla":
            p["mixer"] = init_mla(k1, cfg, dtype)
        else:
            p["mixer"] = init_attention(k1, cfg, dtype)
    elif spec.kind == "mamba":
        p["mixer"] = init_mamba(k1, cfg, dtype)
    else:
        raise ValueError(spec.kind)
    if spec.ffn == "dense":
        p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
        p["ffn"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    elif spec.ffn == "moe":
        p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
        p["ffn"] = init_moe(k2, cfg, dtype)
    elif spec.ffn != "none":
        raise ValueError(spec.ffn)
    return p


def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int,
                     dtype=jnp.float32) -> Params:
    if spec.kind == "mamba":
        return init_mamba_cache(cfg, batch, dtype)
    if cfg.attn_impl == "mla":
        return init_mla_cache(cfg, batch, max_len, dtype)
    return init_kv_cache(cfg, batch, max_len, dtype, window=spec.window)


def apply_layer(
    p: Params,
    cfg: ModelConfig,
    spec: LayerSpec,
    x: jnp.ndarray,
    *,
    pos_offset=0,
    cache: Optional[Params] = None,
    kv_chunk: int = 1024,
    mamba_chunk: int = 256,
    moe_expert_axis=None,
    batch_axis=None,
) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    h = rmsnorm(p["norm1"], x)
    if spec.kind == "mamba":
        y, new_cache = apply_mamba(p["mixer"], cfg, h, cache=cache,
                                   chunk=mamba_chunk, batch_axis=batch_axis)
    elif cfg.attn_impl == "mla":
        y, new_cache = apply_mla(p["mixer"], cfg, spec, h, pos_offset=pos_offset,
                                 cache=cache, kv_chunk=kv_chunk)
    else:
        y, new_cache = apply_attention(p["mixer"], cfg, spec, h, pos_offset=pos_offset,
                                       cache=cache, kv_chunk=kv_chunk)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in p:
        h = rmsnorm(p["norm2"], x)
        if spec.ffn == "moe":
            y, aux = apply_moe(p["ffn"], cfg, h, expert_axis=moe_expert_axis)
        else:
            y = mlp(p["ffn"], h)
        x = x + y
    return x, new_cache, aux
