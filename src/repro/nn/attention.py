"""Attention: GQA/MHA with causal, sliding-window and KV-cache decode paths.

The core primitive is :func:`attend` — an online-softmax attention that
scans over KV chunks so the S×S score matrix is never materialized (the
pure-JAX analogue of the Pallas flash kernel in ``repro.kernels``; XLA maps
the per-chunk einsums onto the MXU). It supports

* grouped queries (``Hq = G * Hkv``) without repeating KV heads,
* different QK and V head dims (needed by MLA's absorbed decode),
* causal + sliding-window masking via explicit position vectors,
* arbitrary query offset (decode with a prefix cache).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, LayerSpec
from .modules import Params, apply_rope, init_linear, linear

NEG_INF = float("-inf")


def _mask(q_pos, kv_pos, window: Optional[int]):
    """[Sq, Sk] boolean validity mask (True == attend)."""
    ok = kv_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= (q_pos[:, None] - kv_pos[None, :]) < window
    return ok


def _attend_dense(q, k, v, q_pos, kv_pos, window, scale):
    """Single-block attention (small Skv). q:[B,Sq,Hkv,G,Dqk]."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    ok = _mask(q_pos, kv_pos, window)
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)  # fully-masked rows
    p = jnp.where(ok[None, None, None], jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]


def attend(
    q: jnp.ndarray,  # [B, Sq, Hq, Dqk]
    k: jnp.ndarray,  # [B, Sk, Hkv, Dqk]
    v: jnp.ndarray,  # [B, Sk, Hkv, Dv]
    *,
    q_pos: jnp.ndarray,  # [Sq] int32 absolute positions
    kv_pos: jnp.ndarray,  # [Sk] int32 absolute positions
    window: Optional[int] = None,
    kv_chunk: int = 1024,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Online-softmax causal attention; returns [B, Sq, Hq, Dv] (q dtype)."""
    B, Sq, Hq, Dqk = q.shape
    _, Sk, Hkv, _ = k.shape
    Dv = v.shape[-1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = scale if scale is not None else Dqk ** -0.5
    qr = q.reshape(B, Sq, Hkv, G, Dqk)

    if Sk <= kv_chunk:
        out = _attend_dense(qr, k, v, q_pos, kv_pos, window, scale)
        return out.reshape(B, Sq, Hq, Dv).astype(q.dtype)

    # pad Sk to a multiple of the chunk; padded slots get kv_pos = INT32_MAX
    n_chunks = -(-Sk // kv_chunk)
    pad = n_chunks * kv_chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
    kc = k.reshape(B, n_chunks, kv_chunk, Hkv, Dqk).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, Hkv, Dv).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(n_chunks, kv_chunk)

    qf = qr.astype(jnp.float32)

    # Flash-attention semantics under AD: without checkpointing, lax.scan
    # saves every chunk's probability block as a backward residual — the
    # full S×S score matrix in fp32. Rematerializing the body keeps only
    # the O(S) carry per chunk and recomputes p in the backward pass.
    @jax.checkpoint
    def body(carry, xs):
        m, l, acc = carry
        kb, vb, pb = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kb.astype(jnp.float32)) * scale
        ok = _mask(q_pos, pb, window)
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(ok[None, None, None], jnp.exp(s - m_safe[..., None]), 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, Dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer


def init_attention(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_linear(kq, cfg.d_model, cfg.n_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": init_linear(kk, cfg.d_model, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": init_linear(kv, cfg.d_model, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": init_linear(ko, cfg.n_heads * hd, cfg.d_model, dtype=dtype),
    }


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.float32, window: Optional[int] = None) -> Params:
    """KV cache. Sliding-window layers allocate a RING BUFFER of ``window``
    slots instead of ``max_len`` — at long_500k this shrinks a local layer's
    cache by seq/window (512× for gemma3's 1024-token local layers)."""
    hd = cfg.resolved_head_dim
    slots = min(max_len, window) if window else max_len
    return {
        "k": jnp.zeros((batch, slots, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, slots, cfg.n_kv_heads, hd), dtype),
    }


def apply_attention(
    p: Params,
    cfg: ModelConfig,
    spec: LayerSpec,
    x: jnp.ndarray,  # [B, S, d]
    *,
    pos_offset: jnp.ndarray | int = 0,
    cache: Optional[Params] = None,
    kv_chunk: int = 1024,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Self-attention. With ``cache`` the new K/V are written at
    ``pos_offset`` and attention runs over the whole cache (prefill when
    S>1, decode when S==1); without it, attention is over ``x`` only."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    theta = spec.rope_theta or cfg.rope_theta
    q = linear(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = linear(p["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
    v = linear(p["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)
    q_pos = jnp.asarray(pos_offset, jnp.int32) + jnp.arange(S, dtype=jnp.int32)
    q = apply_rope(q, q_pos, theta)
    k = apply_rope(k, q_pos, theta)

    if cache is None:
        out = attend(q, k, v, q_pos=q_pos, kv_pos=q_pos, window=spec.window, kv_chunk=kv_chunk)
        new_cache = None
    else:
        off = jnp.asarray(pos_offset, jnp.int32)
        Smax = cache["k"].shape[1]
        if spec.window is not None and Smax == spec.window:
            # ring buffer: slot(p) = p % w. Attention runs over the PREVIOUS
            # ring contents (context positions off-w..off-1; unwritten slots
            # mask out) plus the fresh block, THEN the last min(S, w) new
            # tokens are written into their (unique) slots.
            w = spec.window
            s_idx = jnp.arange(w, dtype=jnp.int32)
            last_old = off - 1
            pos_old = last_old - jnp.mod(last_old - s_idx, w)
            pos_old = jnp.where(pos_old < 0, jnp.iinfo(jnp.int32).max, pos_old)
            k_ctx = jnp.concatenate([cache["k"].astype(k.dtype), k], axis=1)
            v_ctx = jnp.concatenate([cache["v"].astype(v.dtype), v], axis=1)
            kv_pos = jnp.concatenate([pos_old, q_pos])
            out = attend(q, k_ctx, v_ctx, q_pos=q_pos, kv_pos=kv_pos, window=w,
                         kv_chunk=kv_chunk)
            kw = k if S <= w else k[:, S - w:]
            vw = v if S <= w else v[:, S - w:]
            n = kw.shape[1]
            slots = jnp.mod(off + S - n + jnp.arange(n, dtype=jnp.int32), w)
            ck = cache["k"].at[:, slots].set(kw.astype(cache["k"].dtype))
            cv = cache["v"].at[:, slots].set(vw.astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, off, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, off, 0, 0))
            new_cache = {"k": ck, "v": cv}
            if spec.window is not None and S == 1 and Smax > spec.window:
                # decode with sliding window over a full-length cache
                w = spec.window
                start = jnp.clip(off - w + 1, 0, Smax - w)
                ks = jax.lax.dynamic_slice_in_dim(ck, start, w, axis=1)
                vs = jax.lax.dynamic_slice_in_dim(cv, start, w, axis=1)
                kv_pos = start + jnp.arange(w, dtype=jnp.int32)
                out = attend(q, ks, vs, q_pos=q_pos, kv_pos=kv_pos, window=w, kv_chunk=kv_chunk)
            else:
                kv_pos = jnp.arange(Smax, dtype=jnp.int32)
                out = attend(q, ck, cv, q_pos=q_pos, kv_pos=kv_pos, window=spec.window, kv_chunk=kv_chunk)

    y = linear(p["wo"], out.reshape(B, S, cfg.n_heads * hd))
    return y, new_cache
