from .modules import (
    Params,
    tree_bytes,
    tree_flatten_vector,
    tree_global_norm,
    tree_size,
    tree_unflatten_vector,
)
from .model import decode_step, forward, init_cache, init_model
from .losses import accuracy, cross_entropy, dml_loss, kl_divergence, macro_accuracy
from . import vision

__all__ = [
    "Params",
    "tree_bytes",
    "tree_flatten_vector",
    "tree_global_norm",
    "tree_size",
    "tree_unflatten_vector",
    "decode_step",
    "forward",
    "init_cache",
    "init_model",
    "accuracy",
    "cross_entropy",
    "dml_loss",
    "kl_divergence",
    "macro_accuracy",
    "vision",
]
