"""Mamba-1 selective-SSM block (falcon-mamba / jamba).

Training/prefill uses a chunked selective scan: an outer ``lax.scan`` over
sequence chunks carries the [B, d_inner, d_state] recurrent state, and the
in-chunk recurrence is a work-efficient ``associative_scan``. Only one
chunk's [B, C, d_inner, d_state] tensor is live at a time, which is the
TPU adaptation of the paper-standard CUDA selective-scan kernel (the Pallas
version of the same chunking lives in ``repro.kernels.mamba_scan``).
Decode is the O(1) single-step recurrence with a rolling conv state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import MambaConfig, ModelConfig
from .modules import Params, init_linear, linear, normal_init


def init_mamba(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    m = cfg.mamba or MambaConfig()
    d, di, ds, dtr = cfg.d_model, cfg.d_inner, m.d_state, cfg.resolved_dt_rank
    k = jax.random.split(key, 5)
    # S4D-real initialization for A; dt bias so softplus(dt) starts ~1e-3..1e-1
    A = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    return {
        "in_proj": init_linear(k[0], d, 2 * di, dtype=dtype),
        "conv_w": normal_init(k[1], (m.d_conv, di), 0.2, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": init_linear(k[2], di, dtr + 2 * ds, dtype=dtype),
        "dt_proj": init_linear(k[3], dtr, di, bias=True, scale=dtr**-0.5, dtype=dtype),
        "A_log": jnp.log(A).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": init_linear(k[4], di, d, dtype=dtype),
    }


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    m = cfg.mamba or MambaConfig()
    return {
        "conv": jnp.zeros((batch, m.d_conv - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, m.d_state), jnp.float32),
    }


def _causal_conv(p: Params, x: jnp.ndarray, prev: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Depthwise causal conv along seq. x:[B,S,di]; prev:[B,d_conv-1,di]."""
    K = p["conv_w"].shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    y = sum(xp[:, i : i + S, :] * p["conv_w"][i] for i in range(K))
    return y + p["conv_b"]


def _ssm_inputs(p: Params, cfg: ModelConfig, xc: jnp.ndarray):
    """From conv output xc:[B,S,di] compute (dt, B, C, A) in float32."""
    m = cfg.mamba or MambaConfig()
    dtr = cfg.resolved_dt_rank
    dbc = linear(p["x_proj"], xc)
    dt_r = dbc[..., :dtr]
    Bm = dbc[..., dtr : dtr + m.d_state].astype(jnp.float32)
    Cm = dbc[..., dtr + m.d_state :].astype(jnp.float32)
    dt = jax.nn.softplus(linear(p["dt_proj"], dt_r).astype(jnp.float32) - 4.0)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    return dt, Bm, Cm, A


def _chunk_scan(h0, dt, A, Bm, Cm, xc):
    """One chunk of the selective scan.

    h0:[B,di,ds] f32; dt,xc:[B,C,di]; Bm,Cm:[B,C,ds]; A:[di,ds].
    Returns (y [B,C,di] f32, h_last [B,di,ds]).
    """
    a = jnp.exp(dt[..., None] * A)  # [B,C,di,ds]
    b = (dt * xc.astype(jnp.float32))[..., None] * Bm[:, :, None, :]

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    a_cum, b_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = a_cum * h0[:, None] + b_cum  # [B,C,di,ds]
    y = jnp.einsum("bcds,bcs->bcd", h, Cm)
    return y, h[:, -1]


def apply_mamba(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,  # [B, S, d]
    *,
    cache: Optional[Params] = None,
    chunk: int = 256,
    batch_axis=None,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """``batch_axis`` re-pins the batch dim of the chunked-scan tensors to
    that mesh axis: the pad/reshape/swapaxes chain below otherwise loses the
    batch sharding in GSPMD propagation, replicating multi-GiB [n, B, C,
    di, ds] scan buffers on every device (observed on jamba × train_4k)."""
    m = cfg.mamba or MambaConfig()
    B, S, _ = x.shape
    di = cfg.d_inner
    xz = linear(p["in_proj"], x)
    x1, z = xz[..., :di], xz[..., di:]

    def pin(t, b_dim, di_dim=None):
        # NOTE: with_sharding_constraint specs are TOTAL — a None dim means
        # "replicated", so the d_inner dim must keep its tensor-parallel
        # axis explicitly (framework convention: the TP axis is "model").
        if batch_axis is None:
            return t
        from jax.sharding import PartitionSpec as P
        spec = [None] * t.ndim
        spec[b_dim] = batch_axis
        if di_dim is not None and t.shape[di_dim] == di:
            spec[di_dim] = "model"
        return jax.lax.with_sharding_constraint(t, P(*spec))

    prev_conv = cache["conv"] if cache is not None else None
    xc = jax.nn.silu(_causal_conv(p, x1, prev_conv))
    dt, Bm, Cm, A = _ssm_inputs(p, cfg, xc)
    h0 = cache["ssm"] if cache is not None else jnp.zeros((B, di, m.d_state), jnp.float32)

    if S == 1:
        # decode: single recurrence step
        a = jnp.exp(dt[:, 0, :, None] * A)
        b = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0, None, :]
        h = a * h0 + b
        y = jnp.einsum("bds,bs->bd", h, Cm[:, 0])[:, None, :]
        h_last = h
    elif S <= chunk:
        y, h_last = _chunk_scan(h0, dt, A, Bm, Cm, xc)
    else:
        n = -(-S // chunk)
        pad = n * chunk - S
        if pad:
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0 -> identity step
            Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
            Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
            xcp = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        else:
            xcp = xc
        resh = lambda t: pin(t.reshape(B, n, chunk, *t.shape[2:]).swapaxes(0, 1),
                             1, di_dim=3)

        def body(h, xs):
            dtc, bc, cc, xcc = xs
            y, h_next = _chunk_scan(pin(h, 0, di_dim=1), dtc, A, bc, cc, xcc)
            return pin(h_next, 0, di_dim=1), pin(y, 0, di_dim=2)

        h_last, ys = jax.lax.scan(body, pin(h0, 0, di_dim=1),
                                  (resh(dt), resh(Bm), resh(Cm), resh(xcp)))
        y = ys.swapaxes(0, 1).reshape(B, n * chunk, di)[:, :S]

    y = y + p["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    out = linear(p["out_proj"], (y.astype(x.dtype) * jax.nn.silu(z)))

    new_cache = None
    if cache is not None:
        K = m.d_conv
        if S >= K - 1:
            conv_state = x1[:, S - (K - 1) :, :]
        else:
            conv_state = jnp.concatenate([cache["conv"][:, S:, :], x1], axis=1)
        new_cache = {"conv": conv_state.astype(cache["conv"].dtype), "ssm": h_last}
    return out, new_cache
