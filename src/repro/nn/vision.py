"""The paper's own image-classification models (Appendix A / §4.3-4.4).

MLP, LeNet5, CNN1, CNN2 (Shen et al. 2020 architectures), a small VGG
(Kvasir, Yang et al. 2021) and a GroupNorm ResNet-ish CNN standing in for
the ResNet18-GN used on Camelyon-17 (GroupNorm instead of BatchNorm exactly
because per-example gradients must be well-defined for DP-SGD — paper §4.4).

All are functional pytree-param models: ``init_<name>(key, image_shape,
n_classes) -> params`` and ``apply(params, images) -> logits``. A model is
the pair ``VisionModel(init, apply, name)`` so the FL protocol can mix
heterogeneous private architectures (paper Fig. 5b).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from .modules import Params, init_linear, linear


@dataclass(frozen=True)
class VisionModel:
    name: str
    init: Callable
    apply: Callable


# ---------------------------------------------------------------------------
# helpers


def _conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    scale = (kh * kw * cin) ** -0.5
    return {"w": scale * jax.random.normal(key, (kh, kw, cin, cout), dtype),
            "b": jnp.zeros((cout,), dtype)}


def _conv(p, x, stride=1, padding="SAME"):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _maxpool(x, k=2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID")


def _avgpool_global(x):
    return jnp.mean(x, axis=(1, 2))


def _groupnorm_init(c, dtype=jnp.float32):
    return {"g": jnp.ones((c,), dtype), "b": jnp.zeros((c,), dtype)}


def _groupnorm(p, x, groups=8, eps=1e-5):
    B, H, W, C = x.shape
    g = min(groups, C)
    while C % g:
        g -= 1
    xr = x.reshape(B, H, W, g, C // g)
    mu = xr.mean(axis=(1, 2, 4), keepdims=True)
    var = xr.var(axis=(1, 2, 4), keepdims=True)
    xr = (xr - mu) * jax.lax.rsqrt(var + eps)
    return xr.reshape(B, H, W, C) * p["g"] + p["b"]


# ---------------------------------------------------------------------------
# MLP: two hidden layers of 200 units (paper App. A)


def init_mlp_vision(key, image_shape, n_classes, dtype=jnp.float32) -> Params:
    d_in = int(jnp.prod(jnp.array(image_shape)))
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "fc1": init_linear(k1, d_in, 200, bias=True, scale=d_in**-0.5, dtype=dtype),
        "fc2": init_linear(k2, 200, 200, bias=True, scale=200**-0.5, dtype=dtype),
        "fc3": init_linear(k3, 200, n_classes, bias=True, scale=200**-0.5, dtype=dtype),
    }


def apply_mlp_vision(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(linear(p["fc1"], x))
    x = jax.nn.relu(linear(p["fc2"], x))
    return linear(p["fc3"], x)


# ---------------------------------------------------------------------------
# LeNet5


def init_lenet5(key, image_shape, n_classes, dtype=jnp.float32) -> Params:
    H, W, C = image_shape
    k = jax.random.split(key, 5)
    h, w = H // 4, W // 4  # two 2x2 pools
    return {
        "c1": _conv_init(k[0], 5, 5, C, 6, dtype),
        "c2": _conv_init(k[1], 5, 5, 6, 16, dtype),
        "fc1": init_linear(k[2], h * w * 16, 120, bias=True, scale=0.05, dtype=dtype),
        "fc2": init_linear(k[3], 120, 84, bias=True, scale=0.1, dtype=dtype),
        "fc3": init_linear(k[4], 84, n_classes, bias=True, scale=0.1, dtype=dtype),
    }


def apply_lenet5(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    x = _maxpool(jax.nn.relu(_conv(p["c1"], x)))
    x = _maxpool(jax.nn.relu(_conv(p["c2"], x)))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(linear(p["fc1"], x))
    x = jax.nn.relu(linear(p["fc2"], x))
    return linear(p["fc3"], x)


# ---------------------------------------------------------------------------
# CNN1 / CNN2 (Shen et al. 2020)


def init_cnn1(key, image_shape, n_classes, dtype=jnp.float32) -> Params:
    H, W, C = image_shape
    k = jax.random.split(key, 4)
    h, w = H // 4, W // 4
    return {
        "c1": _conv_init(k[0], 3, 3, C, 6, dtype),
        "c2": _conv_init(k[1], 3, 3, 6, 16, dtype),
        "fc1": init_linear(k[2], h * w * 16, 64, bias=True, scale=0.05, dtype=dtype),
        "fc2": init_linear(k[3], 64, n_classes, bias=True, scale=0.1, dtype=dtype),
    }


def apply_cnn1(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    x = _maxpool(jax.nn.relu(_conv(p["c1"], x)))
    x = _maxpool(jax.nn.relu(_conv(p["c2"], x)))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(linear(p["fc1"], x))
    return linear(p["fc2"], x)


def init_cnn2(key, image_shape, n_classes, dtype=jnp.float32) -> Params:
    H, W, C = image_shape
    k = jax.random.split(key, 3)
    h, w = H // 4, W // 4
    return {
        "c1": _conv_init(k[0], 3, 3, C, 128, dtype),
        "c2": _conv_init(k[1], 3, 3, 128, 128, dtype),
        "fc": init_linear(k[2], h * w * 128, n_classes, bias=True, scale=0.02, dtype=dtype),
    }


def apply_cnn2(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    x = _maxpool(jax.nn.relu(_conv(p["c1"], x)))
    x = _maxpool(jax.nn.relu(_conv(p["c2"], x)))
    return linear(p["fc"], x.reshape(x.shape[0], -1))


# ---------------------------------------------------------------------------
# Small VGG (Kvasir) and GroupNorm residual CNN (Camelyon stand-in)


def init_vgg_small(key, image_shape, n_classes, dtype=jnp.float32) -> Params:
    H, W, C = image_shape
    k = jax.random.split(key, 5)
    return {
        "c1": _conv_init(k[0], 3, 3, C, 32, dtype),
        "c2": _conv_init(k[1], 3, 3, 32, 64, dtype),
        "c3": _conv_init(k[2], 3, 3, 64, 128, dtype),
        "fc1": init_linear(k[3], 128, 128, bias=True, scale=0.05, dtype=dtype),
        "fc2": init_linear(k[4], 128, n_classes, bias=True, scale=0.1, dtype=dtype),
    }


def apply_vgg_small(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    x = _maxpool(jax.nn.relu(_conv(p["c1"], x)))
    x = _maxpool(jax.nn.relu(_conv(p["c2"], x)))
    x = _maxpool(jax.nn.relu(_conv(p["c3"], x)))
    x = _avgpool_global(x)
    x = jax.nn.relu(linear(p["fc1"], x))
    return linear(p["fc2"], x)


def init_resnet_gn(key, image_shape, n_classes, dtype=jnp.float32) -> Params:
    """Small residual CNN with GroupNorm (the DP-compatible norm, §4.4)."""
    H, W, C = image_shape
    k = jax.random.split(key, 8)
    widths = (32, 64, 128)
    p: Params = {"stem": _conv_init(k[0], 3, 3, C, widths[0], dtype)}
    cin = widths[0]
    for i, cout in enumerate(widths):
        p[f"b{i}_c1"] = _conv_init(k[2 * i + 1], 3, 3, cin, cout, dtype)
        p[f"b{i}_n1"] = _groupnorm_init(cout, dtype)
        p[f"b{i}_c2"] = _conv_init(k[2 * i + 2], 3, 3, cout, cout, dtype)
        p[f"b{i}_n2"] = _groupnorm_init(cout, dtype)
        if cin != cout:
            p[f"b{i}_skip"] = _conv_init(jax.random.fold_in(k[7], i), 1, 1, cin, cout, dtype)
        cin = cout
    p["fc"] = init_linear(k[7], cin, n_classes, bias=True, scale=0.1, dtype=dtype)
    return p


def apply_resnet_gn(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    x = jax.nn.relu(_conv(p["stem"], x))
    for i in range(3):
        h = jax.nn.relu(_groupnorm(p[f"b{i}_n1"], _conv(p[f"b{i}_c1"], x, stride=2)))
        h = _groupnorm(p[f"b{i}_n2"], _conv(p[f"b{i}_c2"], h))
        skip = p.get(f"b{i}_skip")
        xs = _conv(skip, x, stride=2) if skip is not None else x[:, ::2, ::2, :]
        x = jax.nn.relu(h + xs)
    return linear(p["fc"], _avgpool_global(x))


MODELS = {
    "mlp": VisionModel("mlp", init_mlp_vision, apply_mlp_vision),
    "lenet5": VisionModel("lenet5", init_lenet5, apply_lenet5),
    "cnn1": VisionModel("cnn1", init_cnn1, apply_cnn1),
    "cnn2": VisionModel("cnn2", init_cnn2, apply_cnn2),
    "vgg": VisionModel("vgg", init_vgg_small, apply_vgg_small),
    "resnet_gn": VisionModel("resnet_gn", init_resnet_gn, apply_resnet_gn),
}


def get_vision_model(name: str) -> VisionModel:
    return MODELS[name]
