"""Basic functional building blocks.

Parameters are plain pytrees (nested dicts of jnp arrays); every module is
an ``init_*(key, ...) -> params`` plus an ``apply``-style function. This
keeps everything transparent to ``jax.jit`` / ``shard_map`` / ``vmap`` and
lets the ProxyFL protocol treat whole models as flat vectors when gossiping.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Params = dict

# ---------------------------------------------------------------------------
# Initializers


def normal_init(key, shape, scale=0.02, dtype=jnp.float32):
    return scale * jax.random.normal(key, shape, dtype)


def zeros_init(_key, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


# ---------------------------------------------------------------------------
# Linear


def init_linear(key, d_in, d_out, bias=False, scale=0.02, dtype=jnp.float32):
    kw, _ = jax.random.split(key)
    p = {"w": normal_init(kw, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# RMSNorm


def init_rmsnorm(d, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * p["g"]


# ---------------------------------------------------------------------------
# Embedding


def init_embedding(key, vocab, d, scale=0.02, dtype=jnp.float32):
    return {"e": normal_init(key, (vocab, d), scale, dtype)}


def embed(p: Params, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["e"], ids, axis=0)


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["e"].T


# ---------------------------------------------------------------------------
# RoPE


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, D]; positions: [..., S] (broadcastable)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [D/2]
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # [..., S, 1, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    out = jnp.stack([out1, out2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU) — the dense FFN used by every assigned arch


def init_mlp(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d_model, d_ff, dtype=dtype),
        "up": init_linear(k2, d_model, d_ff, dtype=dtype),
        "down": init_linear(k3, d_ff, d_model, dtype=dtype),
    }


def mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))


# ---------------------------------------------------------------------------
# Parameter-tree utilities (used heavily by the gossip / DP layers)


def tree_size(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(x.size * x.dtype.itemsize) for x in jax.tree_util.tree_leaves(tree))


def tree_flatten_vector(tree) -> jnp.ndarray:
    """Concatenate every leaf into a single 1-D vector (proxy wire format)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([x.reshape(-1) for x in leaves]) if leaves else jnp.zeros((0,))


def tree_unflatten_vector(vec: jnp.ndarray, like) -> Params:
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out, off = [], 0
    for leaf in leaves:
        n = leaf.size
        out.append(vec[off : off + n].reshape(leaf.shape).astype(leaf.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))
