"""Decoder-only model stack for every assigned architecture family.

The layer layout is ``prefix`` (unrolled) + ``pattern`` × R (stacked params,
executed with ``lax.scan`` so HLO size is O(len(pattern)), not O(n_layers) —
essential for tractable ``.lower().compile()`` at 512 devices) + ``tail``
(unrolled remainder). KV/SSM caches mirror the same structure so the decode
path scans too.

Modality frontends are stubs per the task carve-out: VLM forward consumes
precomputed patch embeddings [B, n_img, frontend_dim]; audio forward
consumes EnCodec token ids [B, S, n_codebooks].
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .blocks import apply_layer, init_layer, init_layer_cache
from .modules import Params, init_linear, init_rmsnorm, linear, normal_init, rmsnorm

Cache = Dict[str, Any]


def _plan(cfg: ModelConfig):
    P = len(cfg.prefix)
    L = len(cfg.pattern)
    R, rem = cfg.pattern_plan()
    return P, L, R, rem


# ---------------------------------------------------------------------------
# init


def init_model(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    P, L, R, rem = _plan(cfg)
    keys = jax.random.split(key, 8)
    params: Params = {}

    if cfg.modality == "audio":
        params["embed"] = {"e": normal_init(keys[0], (cfg.n_codebooks, cfg.vocab_size, cfg.d_model), 0.02, dtype)}
    else:
        params["embed"] = {"e": normal_init(keys[0], (cfg.vocab_size, cfg.d_model), 0.02, dtype)}
    if cfg.modality == "vlm":
        params["img_proj"] = init_linear(keys[1], cfg.frontend_dim, cfg.d_model, bias=True, dtype=dtype)

    lk = jax.random.split(keys[2], max(P, 1))
    params["prefix"] = tuple(init_layer(lk[i], cfg, cfg.prefix[i], dtype) for i in range(P))

    if R > 0:
        stack = []
        pk = jax.random.split(keys[3], L)
        for pos in range(L):
            rk = jax.random.split(pk[pos], R)
            stack.append(jax.vmap(lambda k: init_layer(k, cfg, cfg.pattern[pos], dtype))(rk))
        params["stack"] = tuple(stack)
    else:
        params["stack"] = ()

    tk = jax.random.split(keys[4], max(rem, 1))
    params["tail"] = tuple(init_layer(tk[i], cfg, cfg.pattern[i], dtype) for i in range(rem))

    params["norm_f"] = init_rmsnorm(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        if cfg.modality == "audio":
            params["head"] = {"w": normal_init(keys[5], (cfg.n_codebooks, cfg.d_model, cfg.vocab_size), 0.02, dtype)}
        else:
            params["head"] = init_linear(keys[5], cfg.d_model, cfg.vocab_size, dtype=dtype)
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Cache:
    dtype = dtype or jnp.dtype(cfg.dtype)
    P, L, R, rem = _plan(cfg)
    cache: Cache = {
        "prefix": tuple(init_layer_cache(cfg, cfg.prefix[i], batch, max_len, dtype) for i in range(P)),
        "tail": tuple(init_layer_cache(cfg, cfg.pattern[i], batch, max_len, dtype) for i in range(rem)),
    }
    if R > 0:
        stack = []
        for pos in range(L):
            one = init_layer_cache(cfg, cfg.pattern[pos], batch, max_len, dtype)
            stack.append(jax.tree_util.tree_map(lambda x: jnp.broadcast_to(x, (R,) + x.shape), one))
        cache["stack"] = tuple(stack)
    else:
        cache["stack"] = ()
    return cache


# ---------------------------------------------------------------------------
# forward


def _embed_inputs(params, cfg: ModelConfig, tokens, img):
    if cfg.modality == "audio":
        # tokens: [B, S, K]; sum codebook embeddings
        e = params["embed"]["e"]  # [K, V, d]
        x = sum(jnp.take(e[k], tokens[..., k], axis=0) for k in range(cfg.n_codebooks))
        return x
    e = params["embed"]["e"]
    x = jnp.take(e, tokens, axis=0)
    if cfg.modality == "vlm" and img is not None:
        xi = linear(params["img_proj"], img.astype(x.dtype))
        x = jnp.concatenate([xi, x], axis=1)
    return x


def _logits(params, cfg: ModelConfig, x):
    if cfg.modality == "audio":
        if cfg.tie_embeddings:
            return jnp.einsum("bsd,kvd->bskv", x, params["embed"]["e"])
        return jnp.einsum("bsd,kdv->bskv", x, params["head"]["w"])
    if cfg.tie_embeddings:
        return x @ params["embed"]["e"].T
    return linear(params["head"], x)


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    img: Optional[jnp.ndarray] = None,
    *,
    cache: Optional[Cache] = None,
    pos_offset=0,
    remat: bool = False,
    kv_chunk: int = 1024,
    mamba_chunk: int = 256,
    act_spec: Optional[Tuple] = None,
    moe_expert_axis=None,
    batch_axis=None,
) -> Tuple[jnp.ndarray, Optional[Cache], jnp.ndarray]:
    """Returns (logits, new_cache, aux_loss). ``cache=None`` → pure forward
    (training); with cache → prefill (S>1) or decode (S==1) at
    ``pos_offset``. ``act_spec`` (a PartitionSpec tuple for [B, S, d]
    activations) pins the residual stream between layers — the 2D
    weight-stationary serving path shards d over "data" so every matmul
    contracts two similarly-sharded operands (partial-sum + small psum)
    instead of gathering weights."""
    P, L, R, rem = _plan(cfg)
    x = _embed_inputs(params, cfg, tokens, img)

    def pin(h):
        if act_spec is None:
            return h
        from jax.sharding import PartitionSpec as PS
        return jax.lax.with_sharding_constraint(h, PS(*act_spec))

    x = pin(x)
    aux = jnp.zeros((), jnp.float32)
    use_cache = cache is not None
    new_cache: Cache = {"prefix": [], "tail": [], "stack": ()}
    layer_kw = dict(pos_offset=pos_offset, kv_chunk=kv_chunk,
                    mamba_chunk=mamba_chunk, moe_expert_axis=moe_expert_axis,
                    batch_axis=batch_axis)

    for i in range(P):
        x, nc, a = apply_layer(params["prefix"][i], cfg, cfg.prefix[i], x,
                               cache=cache["prefix"][i] if use_cache else None, **layer_kw)
        x = pin(x)
        aux += a
        new_cache["prefix"].append(nc)

    if R > 0:
        if use_cache:
            def body(carry, xs):
                x, aux = carry
                pp, cc = xs
                ncs = []
                for pos in range(L):
                    x, nc, a = apply_layer(pp[pos], cfg, cfg.pattern[pos], x,
                                           cache=cc[pos], **layer_kw)
                    x = pin(x)
                    aux += a
                    ncs.append(nc)
                return (x, aux), tuple(ncs)

            xs = (params["stack"], cache["stack"])
        else:
            def body(carry, pp):
                x, aux = carry
                for pos in range(L):
                    x, _, a = apply_layer(pp[pos], cfg, cfg.pattern[pos], x,
                                          cache=None, **layer_kw)
                    x = pin(x)
                    aux += a
                return (x, aux), None

            xs = params["stack"]
        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux), stack_cache = jax.lax.scan(body, (x, aux), xs)
        new_cache["stack"] = stack_cache if use_cache else ()

    for i in range(rem):
        x, nc, a = apply_layer(params["tail"][i], cfg, cfg.pattern[i], x,
                               cache=cache["tail"][i] if use_cache else None, **layer_kw)
        aux += a
        new_cache["tail"].append(nc)

    x = rmsnorm(params["norm_f"], x)
    logits = _logits(params, cfg, x)
    if use_cache:
        out_cache = {"prefix": tuple(new_cache["prefix"]),
                     "stack": new_cache["stack"],
                     "tail": tuple(new_cache["tail"])}
    else:
        out_cache = None
    return logits, out_cache, aux


def decode_step(params, cfg: ModelConfig, tokens_last, cache, pos):
    """One-token decode. tokens_last: [B,1] (or [B,1,K] audio)."""
    logits, new_cache, _ = forward(params, cfg, tokens_last, cache=cache, pos_offset=pos)
    return logits, new_cache
