"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

K/V are compressed to a per-token latent ``c_kv`` (rank ``kv_lora_rank``)
plus one shared RoPE key head; only ``(c_kv, k_rope)`` is cached. Prefill
expands per-head K/V from the latent and runs regular flash attention.
Decode uses the *absorbed* formulation: queries are pushed through W_uk into
latent space, so attention runs against the compressed cache directly —
per-step KV traffic is ``kv_lora + rope_dim`` per token instead of
``2 * H * head_dim`` (the MLA decode advantage, TPU-friendly because it is
a plain [B,1,H,r]×[B,S,r] contraction on the MXU).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, LayerSpec, MLAConfig
from .attention import attend
from .modules import Params, apply_rope, init_linear, init_rmsnorm, linear, rmsnorm


def init_mla(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    m = cfg.mla or MLAConfig()
    d, H = cfg.d_model, cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    k = jax.random.split(key, 6)
    return {
        "wdq": init_linear(k[0], d, m.q_lora_rank, dtype=dtype),
        "q_norm": init_rmsnorm(m.q_lora_rank, dtype),
        "wuq": init_linear(k[1], m.q_lora_rank, H * qk_head, dtype=dtype),
        # joint down-proj: [c_kv | k_rope]
        "wdkv": init_linear(k[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype=dtype),
        "kv_norm": init_rmsnorm(m.kv_lora_rank, dtype),
        "wuk": init_linear(k[3], m.kv_lora_rank, H * m.qk_nope_head_dim, dtype=dtype),
        "wuv": init_linear(k[4], m.kv_lora_rank, H * m.v_head_dim, dtype=dtype),
        "wo": init_linear(k[5], H * m.v_head_dim, d, dtype=dtype),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32) -> Params:
    m = cfg.mla or MLAConfig()
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
    }


def _project_q(p, cfg: ModelConfig, m: MLAConfig, x, q_pos, theta):
    B, S, _ = x.shape
    H = cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = linear(p["wuq"], rmsnorm(p["q_norm"], linear(p["wdq"], x)))
    q = q.reshape(B, S, H, qk_head)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_rope = apply_rope(q_rope, q_pos, theta)
    return q_nope, q_rope


def _project_kv_latent(p, m: MLAConfig, x, kv_pos, theta):
    B, S, _ = x.shape
    dkv = linear(p["wdkv"], x)
    ckv = rmsnorm(p["kv_norm"], dkv[..., : m.kv_lora_rank])
    kr = dkv[..., m.kv_lora_rank :]
    kr = apply_rope(kr[:, :, None, :], kv_pos, theta)[:, :, 0, :]  # shared head
    return ckv, kr


def _expanded_attend(p, cfg, m, q_nope, q_rope, ckv, kr, q_pos, kv_pos, kv_chunk):
    """Prefill path: expand per-head K/V from the latent, flash-attend."""
    B, Sk = ckv.shape[0], ckv.shape[1]
    H = cfg.n_heads
    k_nope = linear(p["wuk"], ckv).reshape(B, Sk, H, m.qk_nope_head_dim)
    vfull = linear(p["wuv"], ckv).reshape(B, Sk, H, m.v_head_dim)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kr[:, :, None, :], (B, Sk, H, m.qk_rope_head_dim))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    return attend(q, k, vfull, q_pos=q_pos, kv_pos=kv_pos, kv_chunk=kv_chunk, scale=scale)


def _absorbed_attend(p, cfg, m, q_nope, q_rope, ckv, kr, q_pos, kv_pos, kv_chunk):
    """Decode path: attention in latent space against the compressed cache."""
    B, Sq = q_nope.shape[0], q_nope.shape[1]
    H = cfg.n_heads
    wuk = p["wuk"]["w"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, wuk)  # absorb W_uk into q
    q_cat = jnp.concatenate([q_lat, q_rope], -1)  # [B,Sq,H,r+rope]
    k_cat = jnp.concatenate([ckv, kr], -1)[:, :, None, :]  # Hkv=1
    v_lat = ckv[:, :, None, :]
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    o_lat = attend(q_cat, k_cat, v_lat, q_pos=q_pos, kv_pos=kv_pos, kv_chunk=kv_chunk, scale=scale)
    wuv = p["wuv"]["w"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    return jnp.einsum("bshr,rhd->bshd", o_lat, wuv)


def apply_mla(
    p: Params,
    cfg: ModelConfig,
    spec: LayerSpec,
    x: jnp.ndarray,
    *,
    pos_offset: jnp.ndarray | int = 0,
    cache: Optional[Params] = None,
    kv_chunk: int = 1024,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    m = cfg.mla or MLAConfig()
    B, S, _ = x.shape
    H = cfg.n_heads
    theta = spec.rope_theta or cfg.rope_theta
    q_pos = jnp.asarray(pos_offset, jnp.int32) + jnp.arange(S, dtype=jnp.int32)
    q_nope, q_rope = _project_q(p, cfg, m, x, q_pos, theta)
    ckv, kr = _project_kv_latent(p, m, x, q_pos, theta)

    if cache is None:
        out = _expanded_attend(p, cfg, m, q_nope, q_rope, ckv, kr, q_pos, q_pos, kv_chunk)
        new_cache = None
    else:
        off = jnp.asarray(pos_offset, jnp.int32)
        cc = jax.lax.dynamic_update_slice(cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, off, 0))
        cr = jax.lax.dynamic_update_slice(cache["kr"], kr.astype(cache["kr"].dtype), (0, off, 0))
        new_cache = {"ckv": cc, "kr": cr}
        kv_pos = jnp.arange(cc.shape[1], dtype=jnp.int32)
        if S == 1:
            out = _absorbed_attend(p, cfg, m, q_nope, q_rope, cc, cr, q_pos, kv_pos, kv_chunk)
        else:
            out = _expanded_attend(p, cfg, m, q_nope, q_rope, cc, cr, q_pos, kv_pos, kv_chunk)

    y = linear(p["wo"], out.reshape(B, S, H * m.v_head_dim))
    return y, new_cache
