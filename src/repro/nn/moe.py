"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Dispatch is gather/scatter based (argsort tokens by expert, place into
[E, C] capacity slots) rather than one-hot-einsum based, so the compiled
FLOPs stay ≈ the active-expert FFN FLOPs — important for an honest
roofline. The expert dimension E is shardable over the "model" mesh axis
(expert parallelism); the token scatter/gather then lowers to all-to-all
style collectives under GSPMD.

Supports DeepSeek-style shared (always-on) experts and Arctic-style
parallel dense-residual MLPs.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, MoEConfig
from .modules import Params, init_mlp, mlp, normal_init


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    mo = cfg.moe or MoEConfig()
    d, E, f = cfg.d_model, mo.n_experts, mo.d_ff_expert
    k = jax.random.split(key, 6)
    p = {
        "router": normal_init(k[0], (d, E), 0.02, jnp.float32),
        "gate": normal_init(k[1], (E, d, f), 0.02, dtype),
        "up": normal_init(k[2], (E, d, f), 0.02, dtype),
        "down": normal_init(k[3], (E, f, d), 0.02, dtype),
    }
    if mo.n_shared_experts:
        p["shared"] = init_mlp(k[4], d, mo.n_shared_experts * f, dtype)
    if mo.dense_residual_d_ff:
        p["residual"] = init_mlp(k[5], d, mo.dense_residual_d_ff, dtype)
    return p


def capacity(n_tokens: int, mo: MoEConfig) -> int:
    return max(1, int(-(-n_tokens * mo.top_k * mo.capacity_factor // mo.n_experts)))


def apply_moe(p: Params, cfg: ModelConfig, x: jnp.ndarray,
              expert_axis=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar).

    ``expert_axis`` (a mesh axis name) pins the dispatch/combine buffers'
    expert dim to that axis — expert parallelism. Without it GSPMD sees
    only a flat [E*C, d] scatter target and replicates the dispatch buffer
    on every device (observed: 20 GiB/layer on deepseek-v2 at train_4k)."""
    mo = cfg.moe or MoEConfig()
    B, S, d = x.shape
    T, E, K = B * S, mo.n_experts, mo.top_k
    C = capacity(T, mo)
    xf = x.reshape(T, d)

    def pin(t, spec):
        if expert_axis is None:
            return t
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(t, P(*spec))

    logits = (xf.astype(jnp.float32)) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)  # [T, E]
    topw, topi = jax.lax.top_k(probs, K)  # [T, K]
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(topi, E, dtype=jnp.float32).sum(1), axis=0
    ) / K
    frac_probs = jnp.mean(probs, axis=0)
    aux = mo.router_aux_loss_coef * E * jnp.sum(frac_tokens * frac_probs)

    # --- sort-based dispatch into [E, C] capacity slots
    flat_e = topi.reshape(-1)  # [T*K]
    flat_t = jnp.arange(T * K, dtype=jnp.int32) // K
    flat_w = topw.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * K, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = pos < C

    # 2D scatter into [E, C, d] (NOT a flat [E*C, d] buffer: GSPMD cannot
    # shard the expert dim of a flattened scatter target, so the dispatch
    # buffer would replicate on every device)
    slot_c = jnp.where(keep, pos, 0)
    src = xf[st] * keep[:, None].astype(x.dtype)
    be = jnp.zeros((E, C, d), x.dtype).at[se, slot_c].add(src)
    be = pin(be, (expert_axis, None, None))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", be, p["gate"])) * jnp.einsum(
        "ecd,edf->ecf", be, p["up"]
    )
    h = pin(h, (expert_axis, None, None))
    ye = pin(jnp.einsum("ecf,efd->ecd", h, p["down"]),
             (expert_axis, None, None))

    w = (sw * keep).astype(x.dtype)
    yf = jnp.zeros((T, d), x.dtype).at[st].add(ye[se, slot_c] * w[:, None])

    y = yf.reshape(B, S, d)
    if "shared" in p:
        y = y + mlp(p["shared"], x)
    if "residual" in p:
        y = y + mlp(p["residual"], x)
    return y, aux
