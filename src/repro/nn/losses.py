"""Losses: cross-entropy and the DML KL term (paper Eqs. 2-5)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean CE. logits [..., V]; labels [...] int; mask broadcastable to
    labels (1 = count). Audio models pass [..., K, V] / [..., K].

    Written as vocab-local reductions (max / sum-exp / masked-pick via an
    iota compare) rather than ``take_along_axis`` so that on a tensor-
    parallel mesh with vocab-sharded logits every term stays local and only
    [..,] -shaped partials cross the "model" axis — a gather of the full
    logits tensor otherwise dominates collective traffic."""
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    picked = jnp.sum(jnp.where(iota == labels[..., None].astype(jnp.int32), lf, 0.0),
                     axis=-1)
    nll = lse - picked
    if mask is None:
        return jnp.mean(nll)
    mask = jnp.broadcast_to(mask, nll.shape).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def kl_divergence(p_logits: jnp.ndarray, q_logits: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean KL[p || q] over positions (paper Eq. 3). Differentiable wrt both;
    callers stop-gradient the frozen side per the DML alternation."""
    lp = jax.nn.log_softmax(p_logits.astype(jnp.float32), axis=-1)
    lq = jax.nn.log_softmax(q_logits.astype(jnp.float32), axis=-1)
    kl = jnp.sum(jnp.exp(lp) * (lp - lq), axis=-1)
    if mask is None:
        return jnp.mean(kl)
    mask = jnp.broadcast_to(mask, kl.shape).astype(jnp.float32)
    return jnp.sum(kl * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def dml_loss(own_logits, peer_logits, labels, alpha: float,
             mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """(1-alpha)·CE(own, y) + alpha·KL(own ‖ stop_grad(peer)) — Eq. 4/5."""
    peer = jax.lax.stop_gradient(peer_logits)
    return ((1.0 - alpha) * cross_entropy(own_logits, labels, mask)
            + alpha * kl_divergence(own_logits, peer, mask))


def accuracy(logits, labels, mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    pred = jnp.argmax(logits, axis=-1)
    ok = (pred == labels).astype(jnp.float32)
    if mask is None:
        return jnp.mean(ok)
    mask = jnp.broadcast_to(mask, ok.shape).astype(jnp.float32)
    return jnp.sum(ok * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def macro_accuracy(logits, labels, n_classes: int) -> jnp.ndarray:
    """Per-class accuracy averaged over classes (paper's macro-accuracy)."""
    pred = jnp.argmax(logits, axis=-1).reshape(-1)
    labels = labels.reshape(-1)
    accs = []
    for c in range(n_classes):
        m = (labels == c).astype(jnp.float32)
        accs.append(jnp.sum((pred == c) * m) / jnp.maximum(jnp.sum(m), 1.0))
    return jnp.mean(jnp.stack(accs))
