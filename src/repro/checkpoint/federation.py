"""FederationCheckpointer — per-round snapshots of COMPLETE federation state.

A federation run is resumable iff five things survive the kill: every
client's state pytree (private model, proxy, optimizer moments), the
PushSum de-bias weights ``w``, the round counter, the base RNG key the
round keys derive from, and each client's DP accountant step count. This
module snapshots all five through :meth:`FederationEngine.save_state`
(which exports a backend-portable, per-client canonical payload — stacked
vmap/shard_map state is gathered off the mesh, loop state is saved as-is)
and restores them bit-exactly, so a run killed after round t and resumed
from its checkpoint produces the SAME final parameters and epsilon as an
uninterrupted run.

On-disk layout (one directory per federation)::

    <dir>/round_000002.npz        # all leaves, '/'-joined key paths
    <dir>/round_000002.json       # shape/dtype manifest (inspectable)
    <dir>/round_000002.meta.json  # rounds_done, config fingerprint, ...
    <dir>/LATEST                  # tag of the newest complete snapshot

``LATEST`` is written (atomically) only after the snapshot is fully on
disk, so a kill mid-write can never be resumed from. A config fingerprint
(:func:`config_fingerprint`) is stamped into each snapshot and verified on
restore — resuming under a different protocol configuration raises instead
of silently diverging. ``rounds`` and ``backend`` are excluded from the
fingerprint by default: extending a finished run and switching between the
loop/vmap execution backends are both legitimate resume scenarios.

Commitment chain (verifiable federation)
----------------------------------------
Every snapshot is additionally committed to by the hash chain of
:mod:`repro.core.commit`: ``h_t = H(h_{t-1} || round metadata ||
chunked-leaf digests of each client's released proxy)``, computed from the
canonical arrays the ``.npz`` stores (backend-invariant by construction).
``.meta.json`` records ``commitment``/``prev_commitment`` and the
append-only ``audit.jsonl`` in the federation directory records one entry
per snapshot — per-client commitments AND per-leaf digests, so the trail
outlives snapshot rotation. Restore replays the whole chain and recomputes
the restored round's leaf digests from the npz; any divergence raises
:class:`repro.core.commit.CommitmentError` (distinct from the fingerprint
``ValueError``) naming the first divergent round and leaf path. Under
``verify=True`` (``cfg.verify_commitments``) a snapshot with NO commitment
records is refused too; otherwise legacy snapshots only warn.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
import warnings
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .ckpt import manifest_path

_TAG = "round_{:06d}"
_LATEST = "LATEST"
_AUDIT = "audit.jsonl"


def _commit_mod():
    """Lazy import of :mod:`repro.core.commit`: importing it at module
    level would cycle (repro.core.__init__ -> baselines -> this module)."""
    from ..core import commit
    return commit

# Config knobs a resume is allowed to change. fedlint FED004 requires a
# justifying comment on every entry: an exclusion is a CLAIM that run
# identity survives changing the field.
DEFAULT_FINGERPRINT_EXCLUDE = (
    "rounds",   # horizon only: rounds=50 resumed to 100 replays rounds
                # 0..49 bit-identically (round_key is absolute in t)
    "backend",  # loop/vmap/shard_map/async are conformance-tested to
                # produce identical trajectories (tests/test_conformance.py)
    "verify_commitments",  # verification knob only: the verified run's
                # trajectory is bit-identical to the unverified one (the
                # hashes observe state, never change it — tests/test_commit)
)


def config_fingerprint(cfg, exclude=DEFAULT_FINGERPRINT_EXCLUDE,
                       **extra) -> str:
    """Stable short hash of a ProxyFLConfig (+ caller context such as the
    method name or architecture names). Two runs share a fingerprint iff
    their checkpoints are interchangeable."""
    blob = dataclasses.asdict(cfg) if dataclasses.is_dataclass(cfg) else dict(cfg)
    for k in exclude:
        blob.pop(k, None)
    payload = json.dumps({"cfg": blob, **extra}, sort_keys=True, default=str)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


class FederationCheckpointer:
    """Directory-of-rounds checkpoint manager for a FederationEngine run.

    Parameters
    ----------
    directory : str
        One federation per directory (callers namespace by method/seed).
    every : int
        Snapshot cadence in rounds; ``should_save(t)`` is true after
        rounds ``every, 2*every, ...``. ``0`` disables periodic saves
        (explicit :meth:`save` still works).
    keep : int
        Retain only the newest ``keep`` snapshots (0 = keep all).
    fingerprint : str, optional
        Expected :func:`config_fingerprint`; verified against each
        snapshot's recorded fingerprint on save collision / restore. When
        omitted, a fingerprint is DERIVED from the engine's config at save
        and restore time — constructing the checkpointer without one no
        longer makes the check silently vacuous.
    verify : bool
        Strict commitment mode (``cfg.verify_commitments``): a restore is
        refused (instead of warned about) when the snapshot carries no
        commitment records or no recorded fingerprint. Chain/digest
        MISMATCHES are refused regardless of this flag.
    """

    def __init__(self, directory: str, every: int = 1, keep: int = 0,
                 fingerprint: Optional[str] = None, verify: bool = False):
        self.directory = directory
        self.every = int(every)
        self.keep = int(keep)
        self.fingerprint = fingerprint
        self.verify = bool(verify)

    # -- paths ---------------------------------------------------------------

    def _base(self, rounds_done: int) -> str:
        return os.path.join(self.directory, _TAG.format(rounds_done))

    def _meta_path(self, rounds_done: int) -> str:
        return self._base(rounds_done) + ".meta.json"

    @property
    def audit_path(self) -> str:
        return os.path.join(self.directory, _AUDIT)

    def _complete(self, rounds_done: int) -> bool:
        """ONE completeness criterion for every discovery path: a snapshot
        is resumable iff npz + manifest + meta are all on disk (they are
        written in that order, so any prefix means a kill mid-write). The
        LATEST pointer used to trust npz-only while the scan required
        meta.json — the two paths could disagree about the same file set."""
        base = self._base(rounds_done)
        return all(os.path.exists(p) for p in
                   (base + ".npz", manifest_path(base),
                    self._meta_path(rounds_done)))

    def _expected_fingerprint(self, engine=None) -> Optional[str]:
        """The fingerprint snapshots must carry: the explicit one when the
        checkpointer was constructed with it, else one derived from the
        engine's own config — so save() never stamps null and restore
        never skips the comparison just because the caller forgot to pass
        a fingerprint."""
        if self.fingerprint:
            return self.fingerprint
        if engine is not None and getattr(engine, "cfg", None) is not None:
            return config_fingerprint(engine.cfg, n_clients=engine.K,
                                      mix=engine.mix)
        return None

    # -- save ----------------------------------------------------------------

    def should_save(self, t: int) -> bool:
        """True when round t (0-based, just completed) is on the cadence."""
        return self.every > 0 and (t + 1) % self.every == 0

    def _audit_entries(self) -> List[dict]:
        """Parsed ``audit.jsonl`` entries, in file order. Reading stops at
        the first malformed line (a kill mid-append tears at most the last
        line — everything before it stays verifiable; whether the torn
        round is resumable is decided by the chain check, which refuses
        when the RESTORED round has no intact entry)."""
        if not os.path.exists(self.audit_path):
            return []
        out: List[dict] = []
        with open(self.audit_path) as f:
            for line in f:
                if not line.strip():
                    continue
                try:
                    out.append(json.loads(line))
                except json.JSONDecodeError:
                    break
        return out

    def _append_audit(self, entry: dict) -> None:
        with open(self.audit_path, "a") as f:
            f.write(json.dumps(entry, sort_keys=True) + "\n")

    def _commit_snapshot(self, engine, rounds_done: int) -> Tuple[str, str]:
        """Compute this snapshot's commitment from the canonical arrays the
        npz ACTUALLY stores (what restore will recompute from), chain it to
        the previous audit entry, and append the audit record. Returns
        ``(commitment, prev_commitment)`` for the meta stamp. Re-saving a
        round already in the trail verifies bit-identity and skips the
        append; a different payload for an audited round is refused."""
        commit = _commit_mod()
        with np.load(self._base(rounds_done) + ".npz") as npz:
            digests, leaves = commit.snapshot_client_digests(npz, engine.K)
        entries = self._audit_entries()
        prev = commit.GENESIS
        for e in entries:
            if e.get("rounds_done") == rounds_done:
                # already audited: a bit-identical replay (a resume's
                # re-save, or a killed run deterministically re-run into
                # its own directory) is a no-op; a DIFFERENT payload is a
                # history rewrite and refused
                if e.get("commitment") != commit.chain_step(
                        e.get("prev_commitment", commit.GENESIS),
                        rounds_done, engine.K, digests):
                    raise commit.CommitmentError(
                        f"round {rounds_done} is already committed in "
                        f"{self.audit_path!r} with a DIFFERENT payload; "
                        "refusing to overwrite an audited snapshot — use a "
                        "fresh checkpoint directory", round=rounds_done)
                return e["commitment"], e.get("prev_commitment",
                                              commit.GENESIS)
            prev = e.get("commitment", prev)
        later = [e["rounds_done"] for e in entries
                 if e.get("rounds_done", 0) > rounds_done]
        if later:
            raise commit.CommitmentError(
                f"audit trail {self.audit_path!r} already records rounds "
                f"{later} after round {rounds_done}, which it never "
                "committed; appending it now would fork the chain — point "
                "the run at a fresh checkpoint directory", round=rounds_done)
        h = commit.chain_step(prev, rounds_done, engine.K, digests)
        self._append_audit({"rounds_done": rounds_done,
                            "n_clients": engine.K,
                            "prev_commitment": prev,
                            "commitment": h,
                            "clients": digests,
                            "leaves": leaves})
        return h, prev

    def save(self, engine, state, t: int, base_key=None) -> str:
        """Snapshot ``state`` after completed round ``t``; returns the base
        path of the written snapshot. Write order is load-bearing: npz +
        manifest, then the audit entry, then meta, then the LATEST pointer
        — a complete meta implies a complete audit entry, and only a
        complete snapshot is ever published."""
        rounds_done = t + 1
        base = self._base(rounds_done)
        engine.save_state(base, state, t, base_key=base_key)
        commitment, prev = self._commit_snapshot(engine, rounds_done)
        meta = {
            "rounds_done": rounds_done,
            "fingerprint": self._expected_fingerprint(engine),
            "n_clients": engine.K,
            "backend": engine.backend,
            "mix": engine.mix,
            "commitment": commitment,
            "prev_commitment": prev,
            "saved_unix_time": time.time(),
        }
        with open(self._meta_path(rounds_done), "w") as f:
            json.dump(meta, f, indent=1, sort_keys=True)
        # publish atomically only once the snapshot is complete on disk
        tmp = os.path.join(self.directory, _LATEST + ".tmp")
        with open(tmp, "w") as f:
            f.write(_TAG.format(rounds_done))
        os.replace(tmp, os.path.join(self.directory, _LATEST))
        self._rotate()
        return base

    def maybe_save(self, engine, state, t: int, base_key=None
                   ) -> Optional[str]:
        if not self.should_save(t):
            return None
        return self.save(engine, state, t, base_key=base_key)

    def _rotate(self) -> None:
        if self.keep <= 0:
            return
        for r in self.saved_rounds()[:-self.keep]:
            base = self._base(r)
            for p in (base + ".npz", manifest_path(base), self._meta_path(r)):
                if os.path.exists(p):
                    os.remove(p)

    # -- discovery / restore -------------------------------------------------

    def saved_rounds(self) -> list:
        """Ascending list of rounds_done with a snapshot on disk."""
        if not os.path.isdir(self.directory):
            return []
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("round_") and name.endswith(".npz"):
                try:
                    out.append(int(name[len("round_"):-len(".npz")]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_round(self) -> Optional[int]:
        """rounds_done of the newest COMPLETE snapshot (LATEST pointer,
        falling back to a directory scan), or None when the directory holds
        no resumable state. Both paths trust the SAME completeness
        criterion (:meth:`_complete`: npz + manifest + meta on disk), and a
        corrupt/garbage LATEST file falls back to the scan instead of
        crashing the resume."""
        latest = os.path.join(self.directory, _LATEST)
        if os.path.exists(latest):
            with open(latest) as f:
                tag = f.read().strip()
            if tag.startswith("round_"):
                try:
                    r = int(tag[len("round_"):])
                except ValueError:
                    r = None  # garbage pointer: fall back to the scan
                if r is not None and self._complete(r):
                    return r
        complete = [r for r in self.saved_rounds() if self._complete(r)]
        return complete[-1] if complete else None

    def _check_meta(self, rounds_done: int, engine=None) -> dict:
        mp = self._meta_path(rounds_done)
        meta = {}
        if os.path.exists(mp):
            try:
                with open(mp) as f:
                    meta = json.load(f)
            except json.JSONDecodeError:
                meta = {}  # truncated by a kill mid-write; npz is complete
        theirs = meta.get("fingerprint")
        expected = self._expected_fingerprint(engine)
        if not theirs:
            # pre-derivation snapshots stamped null — the comparison used
            # to pass vacuously; now it is at least loud, and refused in
            # strict mode
            msg = (f"checkpoint {self._base(rounds_done)!r} records no "
                   "config fingerprint — cannot verify it belongs to this "
                   "run's configuration")
            if self.verify:
                raise _commit_mod().CommitmentError(
                    msg + " (verify_commitments is on; refusing)",
                    round=rounds_done)
            warnings.warn(msg, stacklevel=3)
        elif expected and theirs != expected:
            raise ValueError(
                f"checkpoint {self._base(rounds_done)!r} was written under a "
                f"different federation configuration (fingerprint {theirs} != "
                f"expected {expected}); refusing to resume — point "
                "--checkpoint-dir at a fresh directory or rerun with the "
                "original configuration")
        return meta

    def verify_chain(self, rounds_done: int, meta: Optional[dict] = None
                     ) -> Optional[str]:
        """Replay the commitment chain from GENESIS and recompute the
        restored round's leaf digests from its npz; raise
        :class:`~repro.core.commit.CommitmentError` naming the first
        divergent round (and leaf path, for leaf-level tampering) on any
        mismatch. Returns the verified commitment, or None when the
        directory predates the audit trail (warned, refused under
        ``verify=True``)."""
        commit = _commit_mod()
        meta = self._check_meta(rounds_done) if meta is None else meta
        entries = self._audit_entries()
        if not entries and "commitment" not in meta:
            msg = (f"checkpoint directory {self.directory!r} carries no "
                   "commitment records (pre-audit-trail snapshot) — the "
                   "proxy payload cannot be verified")
            if self.verify:
                raise commit.CommitmentError(
                    msg + " (verify_commitments is on; refusing)",
                    round=rounds_done)
            warnings.warn(msg, stacklevel=3)
            return None
        prev, last_r, target = commit.GENESIS, 0, None
        for e in entries:
            r = e.get("rounds_done")
            if not isinstance(r, int) or r <= last_r:
                raise commit.CommitmentError(
                    f"audit trail {self.audit_path!r} is out of order at "
                    f"entry for round {r!r} (after round {last_r}) — the "
                    "trail has been edited or reordered", round=r)
            if e.get("prev_commitment") != prev:
                raise commit.CommitmentError(
                    f"commitment chain broken at round {r}: entry links to "
                    f"{e.get('prev_commitment')!r} but round {last_r}'s "
                    f"commitment is {prev!r} — an earlier snapshot was "
                    "rewritten or the trail was truncated", round=r)
            digests = e.get("clients", {})
            expect = {c: hashlib.sha256(json.dumps(
                lv, sort_keys=True).encode()).hexdigest()
                for c, lv in e.get("leaves", {}).items()}
            if expect != digests:
                bad = sorted(c for c in set(digests) | set(expect)
                             if digests.get(c) != expect.get(c))
                raise commit.CommitmentError(
                    f"audit entry for round {r} is internally inconsistent "
                    f"(client commitment != hash of recorded leaf digests "
                    f"for {bad}) — the trail has been edited", round=r)
            h = commit.chain_step(prev, r, e.get("n_clients", 0), digests)
            if e.get("commitment") != h:
                raise commit.CommitmentError(
                    f"commitment chain diverges at round {r}: recorded "
                    f"{e.get('commitment')!r}, recomputed {h!r}", round=r)
            if r == rounds_done:
                target = e
            prev, last_r = h, r
        if target is None:
            raise commit.CommitmentError(
                f"audit trail {self.audit_path!r} has no entry for round "
                f"{rounds_done} (last recorded round: {last_r}) — the trail "
                "was truncated or the snapshot bypassed it; refusing to "
                "restore an uncommitted round", round=rounds_done)
        if meta.get("commitment") != target["commitment"]:
            raise commit.CommitmentError(
                f"meta.json of round {rounds_done} records commitment "
                f"{meta.get('commitment')!r} but the audit trail says "
                f"{target['commitment']!r} — meta files were swapped, "
                "reordered or rewritten", round=rounds_done)
        # leaf-level recheck of the round actually being restored: the
        # chain above proves the TRAIL is intact; this proves the npz still
        # holds the bytes the trail committed to
        with np.load(self._base(rounds_done) + ".npz") as npz:
            n = int(target.get("n_clients", 0))
            _, leaves = commit.snapshot_client_digests(npz, n)
        for ckey in sorted(target.get("leaves", {})):
            recorded = target["leaves"][ckey]
            actual = leaves.get(ckey, {})
            for path in sorted(set(recorded) | set(actual)):
                if recorded.get(path) != actual.get(path):
                    raise commit.CommitmentError(
                        f"checkpoint leaf {ckey}/{commit.PROXY_PREFIX}"
                        f"{path} of round {rounds_done} does not match its "
                        f"committed digest (recorded "
                        f"{recorded.get(path)!r}, recomputed "
                        f"{actual.get(path)!r}) — the snapshot was "
                        "tampered with after it was committed",
                        round=rounds_done, leaf=f"{commit.PROXY_PREFIX}{path}",
                        client=int(ckey[1:]))
        return target["commitment"]

    def restore(self, engine, rounds_done: Optional[int] = None, *,
                like=None, base_key=None) -> Tuple[Any, int]:
        """Load a snapshot into ``engine``'s state layout; returns
        ``(state, rounds_done)`` — the caller continues the round loop at
        ``t = rounds_done``. Also restores attached accountant counters.
        The commitment chain is verified BEFORE any state is materialized
        (tampered snapshots refuse with the divergent round/leaf named)."""
        if rounds_done is None:
            rounds_done = self.latest_round()
            if rounds_done is None:
                raise FileNotFoundError(
                    f"no federation checkpoint found under {self.directory!r}")
        meta = self._check_meta(rounds_done, engine)
        self.verify_chain(rounds_done, meta)
        state, done = engine.restore_state(self._base(rounds_done), like=like,
                                           base_key=base_key)
        if done != rounds_done:
            raise ValueError(
                f"checkpoint {self._base(rounds_done)!r} records "
                f"rounds_done={done}, expected {rounds_done}")
        return state, done

    def restore_latest(self, engine, *, like=None, base_key=None
                       ) -> Optional[Tuple[Any, int]]:
        """Like :meth:`restore`, but returns None when there is nothing to
        resume from (fresh start) instead of raising."""
        if self.latest_round() is None:
            return None
        return self.restore(engine, like=like, base_key=base_key)
