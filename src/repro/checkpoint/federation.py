"""FederationCheckpointer — per-round snapshots of COMPLETE federation state.

A federation run is resumable iff five things survive the kill: every
client's state pytree (private model, proxy, optimizer moments), the
PushSum de-bias weights ``w``, the round counter, the base RNG key the
round keys derive from, and each client's DP accountant step count. This
module snapshots all five through :meth:`FederationEngine.save_state`
(which exports a backend-portable, per-client canonical payload — stacked
vmap/shard_map state is gathered off the mesh, loop state is saved as-is)
and restores them bit-exactly, so a run killed after round t and resumed
from its checkpoint produces the SAME final parameters and epsilon as an
uninterrupted run.

On-disk layout (one directory per federation)::

    <dir>/round_000002.npz        # all leaves, '/'-joined key paths
    <dir>/round_000002.json       # shape/dtype manifest (inspectable)
    <dir>/round_000002.meta.json  # rounds_done, config fingerprint, ...
    <dir>/LATEST                  # tag of the newest complete snapshot

``LATEST`` is written (atomically) only after the snapshot is fully on
disk, so a kill mid-write can never be resumed from. A config fingerprint
(:func:`config_fingerprint`) is stamped into each snapshot and verified on
restore — resuming under a different protocol configuration raises instead
of silently diverging. ``rounds`` and ``backend`` are excluded from the
fingerprint by default: extending a finished run and switching between the
loop/vmap execution backends are both legitimate resume scenarios.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Any, Optional, Tuple

from .ckpt import manifest_path

_TAG = "round_{:06d}"
_LATEST = "LATEST"

# Config knobs a resume is allowed to change. fedlint FED004 requires a
# justifying comment on every entry: an exclusion is a CLAIM that run
# identity survives changing the field.
DEFAULT_FINGERPRINT_EXCLUDE = (
    "rounds",   # horizon only: rounds=50 resumed to 100 replays rounds
                # 0..49 bit-identically (round_key is absolute in t)
    "backend",  # loop/vmap/shard_map/async are conformance-tested to
                # produce identical trajectories (tests/test_conformance.py)
)


def config_fingerprint(cfg, exclude=DEFAULT_FINGERPRINT_EXCLUDE,
                       **extra) -> str:
    """Stable short hash of a ProxyFLConfig (+ caller context such as the
    method name or architecture names). Two runs share a fingerprint iff
    their checkpoints are interchangeable."""
    blob = dataclasses.asdict(cfg) if dataclasses.is_dataclass(cfg) else dict(cfg)
    for k in exclude:
        blob.pop(k, None)
    payload = json.dumps({"cfg": blob, **extra}, sort_keys=True, default=str)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


class FederationCheckpointer:
    """Directory-of-rounds checkpoint manager for a FederationEngine run.

    Parameters
    ----------
    directory : str
        One federation per directory (callers namespace by method/seed).
    every : int
        Snapshot cadence in rounds; ``should_save(t)`` is true after
        rounds ``every, 2*every, ...``. ``0`` disables periodic saves
        (explicit :meth:`save` still works).
    keep : int
        Retain only the newest ``keep`` snapshots (0 = keep all).
    fingerprint : str, optional
        Expected :func:`config_fingerprint`; verified against each
        snapshot's recorded fingerprint on save collision / restore.
    """

    def __init__(self, directory: str, every: int = 1, keep: int = 0,
                 fingerprint: Optional[str] = None):
        self.directory = directory
        self.every = int(every)
        self.keep = int(keep)
        self.fingerprint = fingerprint

    # -- paths ---------------------------------------------------------------

    def _base(self, rounds_done: int) -> str:
        return os.path.join(self.directory, _TAG.format(rounds_done))

    def _meta_path(self, rounds_done: int) -> str:
        return self._base(rounds_done) + ".meta.json"

    # -- save ----------------------------------------------------------------

    def should_save(self, t: int) -> bool:
        """True when round t (0-based, just completed) is on the cadence."""
        return self.every > 0 and (t + 1) % self.every == 0

    def save(self, engine, state, t: int, base_key=None) -> str:
        """Snapshot ``state`` after completed round ``t``; returns the base
        path of the written snapshot."""
        rounds_done = t + 1
        base = self._base(rounds_done)
        engine.save_state(base, state, t, base_key=base_key)
        meta = {
            "rounds_done": rounds_done,
            "fingerprint": self.fingerprint,
            "n_clients": engine.K,
            "backend": engine.backend,
            "mix": engine.mix,
            "saved_unix_time": time.time(),
        }
        with open(self._meta_path(rounds_done), "w") as f:
            json.dump(meta, f, indent=1, sort_keys=True)
        # publish atomically only once the snapshot is complete on disk
        tmp = os.path.join(self.directory, _LATEST + ".tmp")
        with open(tmp, "w") as f:
            f.write(_TAG.format(rounds_done))
        os.replace(tmp, os.path.join(self.directory, _LATEST))
        self._rotate()
        return base

    def maybe_save(self, engine, state, t: int, base_key=None
                   ) -> Optional[str]:
        if not self.should_save(t):
            return None
        return self.save(engine, state, t, base_key=base_key)

    def _rotate(self) -> None:
        if self.keep <= 0:
            return
        for r in self.saved_rounds()[:-self.keep]:
            base = self._base(r)
            for p in (base + ".npz", manifest_path(base), self._meta_path(r)):
                if os.path.exists(p):
                    os.remove(p)

    # -- discovery / restore -------------------------------------------------

    def saved_rounds(self) -> list:
        """Ascending list of rounds_done with a snapshot on disk."""
        if not os.path.isdir(self.directory):
            return []
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("round_") and name.endswith(".npz"):
                try:
                    out.append(int(name[len("round_"):-len(".npz")]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_round(self) -> Optional[int]:
        """rounds_done of the newest COMPLETE snapshot (LATEST pointer,
        falling back to a directory scan), or None when the directory holds
        no resumable state. The scan only trusts snapshots whose meta.json
        exists — it is written strictly after the .npz, so a kill mid-write
        leaves a partial .npz that is ignored here, never resumed from."""
        latest = os.path.join(self.directory, _LATEST)
        if os.path.exists(latest):
            with open(latest) as f:
                tag = f.read().strip()
            if tag.startswith("round_"):
                r = int(tag[len("round_"):])
                if os.path.exists(self._base(r) + ".npz"):
                    return r
        complete = [r for r in self.saved_rounds()
                    if os.path.exists(self._meta_path(r))]
        return complete[-1] if complete else None

    def _check_meta(self, rounds_done: int) -> dict:
        mp = self._meta_path(rounds_done)
        meta = {}
        if os.path.exists(mp):
            try:
                with open(mp) as f:
                    meta = json.load(f)
            except json.JSONDecodeError:
                meta = {}  # truncated by a kill mid-write; npz is complete
        theirs = meta.get("fingerprint")
        if self.fingerprint and theirs and theirs != self.fingerprint:
            raise ValueError(
                f"checkpoint {self._base(rounds_done)!r} was written under a "
                f"different federation configuration (fingerprint {theirs} != "
                f"expected {self.fingerprint}); refusing to resume — point "
                "--checkpoint-dir at a fresh directory or rerun with the "
                "original configuration")
        return meta

    def restore(self, engine, rounds_done: Optional[int] = None, *,
                like=None, base_key=None) -> Tuple[Any, int]:
        """Load a snapshot into ``engine``'s state layout; returns
        ``(state, rounds_done)`` — the caller continues the round loop at
        ``t = rounds_done``. Also restores attached accountant counters."""
        if rounds_done is None:
            rounds_done = self.latest_round()
            if rounds_done is None:
                raise FileNotFoundError(
                    f"no federation checkpoint found under {self.directory!r}")
        self._check_meta(rounds_done)
        state, done = engine.restore_state(self._base(rounds_done), like=like,
                                           base_key=base_key)
        if done != rounds_done:
            raise ValueError(
                f"checkpoint {self._base(rounds_done)!r} records "
                f"rounds_done={done}, expected {rounds_done}")
        return state, done

    def restore_latest(self, engine, *, like=None, base_key=None
                       ) -> Optional[Tuple[Any, int]]:
        """Like :meth:`restore`, but returns None when there is nothing to
        resume from (fresh start) instead of raising."""
        if self.latest_round() is None:
            return None
        return self.restore(engine, like=like, base_key=base_key)
