"""Checkpointing: params/optimizer pytrees <-> .npz + path manifest.

Leaves are stored under '/'-joined key paths so checkpoints are inspectable
with plain numpy and stable across JAX versions. Round-level federation
state (client models, de-bias weights, accountant counters) serializes the
same way.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {}
    for k, v in flat.items():
        a = np.asarray(v)
        if a.dtype.kind not in "fiub" or str(a.dtype) == "bfloat16":
            # npz has no bf16/fp8 codecs; store widened (lossless into f32)
            a = a.astype(np.float32)
        arrays[k] = a
    np.savez(path if path.endswith(".npz") else path + ".npz", **arrays)
    manifest = {k: {"shape": list(np.shape(v)), "dtype": str(np.asarray(v).dtype)}
                for k, v in flat.items()}
    with open((path[:-4] if path.endswith(".npz") else path) + ".json", "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)


def load_checkpoint(path: str, like) -> Any:
    """Restore into the structure of ``like`` (leaf order by key paths)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    flat_like = _flatten_with_paths(like)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    keys = list(flat_like.keys())
    assert len(keys) == len(leaves)
    restored = []
    for key, leaf in zip(keys, leaves):
        arr = npz[key]
        assert arr.shape == tuple(np.shape(leaf)), (key, arr.shape, np.shape(leaf))
        dt = leaf.dtype if hasattr(leaf, "dtype") else None
        restored.append(jnp.asarray(arr).astype(dt) if dt is not None
                        else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, restored)
