"""Checkpointing: params/optimizer pytrees <-> .npz + path manifest.

Leaves are stored under '/'-joined key paths so checkpoints are inspectable
with plain numpy and stable across JAX versions. Restoration matches leaves
BY KEY PATH (never by flatten order): a checkpoint whose key set disagrees
with the template raises a descriptive error listing the missing and
unexpected keys instead of silently loading values into the wrong slots.
Round-level federation state (client models, de-bias weights, accountant
counters) serializes the same way — see :mod:`repro.checkpoint.federation`.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _flatten_with_paths(tree) -> Dict[str, Any]:
    """Leaf dict keyed by '/'-joined path; rejects ambiguous (colliding)
    key paths up front — a collision would otherwise drop a leaf and
    corrupt whichever restore consumed the checkpoint."""
    flat: Dict[str, Any] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        if key in flat:
            raise ValueError(
                f"pytree produces duplicate checkpoint key path {key!r}; "
                "rename the colliding nodes before checkpointing")
        flat[key] = leaf
    return flat


# public alias: the commitment layer (repro.core.commit) flattens proxy
# trees with THE SAME path convention the npz uses, so a commitment
# computed from live state and one recomputed from the checkpoint agree
# by construction
flatten_with_paths = _flatten_with_paths


def _npz_path(path: str) -> str:
    return path if path.endswith(".npz") else path + ".npz"


def manifest_path(path: str) -> str:
    return (path[:-4] if path.endswith(".npz") else path) + ".json"


def save_checkpoint(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {}
    for k, v in flat.items():
        a = np.asarray(v)
        if a.dtype.kind not in "fiub" or str(a.dtype) == "bfloat16":
            # npz has no bf16/fp8 codecs; store widened (lossless into f32)
            a = a.astype(np.float32)
        arrays[k] = a
    np.savez(_npz_path(path), **arrays)
    manifest = {k: {"shape": list(np.shape(v)), "dtype": str(np.asarray(v).dtype)}
                for k, v in flat.items()}
    with open(manifest_path(path), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)


def load_checkpoint(path: str, like) -> Any:
    """Restore into the structure of ``like``, matching leaves by key path.

    Raises ``KeyError`` when the checkpoint's key set and the template's
    disagree (listing the missing / unexpected paths) and ``ValueError``
    on a per-leaf shape mismatch — both conditions previously restored
    garbage silently when flatten order happened to differ.
    """
    pairs, treedef = jax.tree_util.tree_flatten_with_path(like)
    keyed = {}
    for p, leaf in pairs:
        key = "/".join(_path_str(s) for s in p)
        if key in keyed:
            raise ValueError(
                f"restore template produces duplicate key path {key!r}")
        keyed[key] = leaf
    with np.load(_npz_path(path)) as npz:
        have = set(npz.files)
        missing = sorted(set(keyed) - have)
        unexpected = sorted(have - set(keyed))
        if missing or unexpected:
            raise KeyError(
                f"checkpoint {_npz_path(path)!r} does not match the restore "
                f"template: missing keys {missing or 'none'}, "
                f"unexpected keys {unexpected or 'none'}")
        restored = []
        for p, leaf in pairs:
            key = "/".join(_path_str(s) for s in p)
            arr = npz[key]
            if arr.shape != tuple(np.shape(leaf)):
                raise ValueError(
                    f"checkpoint leaf {key!r} has shape {arr.shape}, "
                    f"template expects {tuple(np.shape(leaf))}")
            dt = leaf.dtype if hasattr(leaf, "dtype") else None
            restored.append(jnp.asarray(arr).astype(dt) if dt is not None
                            else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, restored)
