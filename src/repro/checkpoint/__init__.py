"""repro.checkpoint — pytree checkpoints + per-round federation snapshots.

Two layers:

* :mod:`repro.checkpoint.ckpt` — generic pytree <-> ``.npz`` serialization.
  Leaves live under '/'-joined key paths and are restored BY KEY PATH with
  descriptive missing/unexpected-key errors (never by flatten order).
* :mod:`repro.checkpoint.federation` — :class:`FederationCheckpointer`,
  which snapshots COMPLETE federation state every N rounds (per-client
  engine states incl. optimizer moments, PushSum de-bias weights ``w``, the
  round counter, the base RNG key, DP accountant step counts, and a config
  fingerprint) and restores it bit-exactly on any engine backend.

Checkpoint usage
----------------
Periodic snapshots + resume around a :class:`FederationEngine` round loop::

    from repro.checkpoint import FederationCheckpointer, config_fingerprint

    ckpt = FederationCheckpointer("ckpts/run0", every=5,
                                  fingerprint=config_fingerprint(cfg))
    state = engine.init_states(key)
    start = 0
    restored = ckpt.restore_latest(engine, like=state, base_key=key)
    if restored is not None:                 # fresh start when None
        state, start = restored              # continue at t = rounds_done
    for t in range(start, cfg.rounds):
        state, _ = engine.run_round(state, data, t,
                                    jax.random.fold_in(key, 10_000 + t))
        ckpt.maybe_save(engine, state, t, base_key=key)

Or let the drivers do it for you — every entry point threads the same three
knobs:

* ``repro.core.baselines.run_federated(..., checkpoint_dir=..,
  checkpoint_every=.., resume=True)``
* ``python -m repro.launch.train --checkpoint-dir d --checkpoint-every 5
  --resume``
* ``benchmarks.common.bench_methods(..., checkpoint_dir=..)`` (env:
  ``REPRO_BENCH_CKPT_DIR`` / ``REPRO_BENCH_CKPT_EVERY`` /
  ``REPRO_BENCH_RESUME``)

Resume correctness contract: a run killed after round t and resumed from
its checkpoint produces bit-identical final proxy parameters and accountant
epsilon versus the uninterrupted run (CI enforces this via
``scripts/ci.sh --smoke`` on both the loop and vmap backends). Checkpoints
are backend-portable: state is stored per client, so a snapshot written by
the heterogeneous ``loop`` backend restores into a ``vmap``/``shard_map``
engine (stacking on load) and vice versa (gathering from the mesh on save).
"""
from .ckpt import load_checkpoint, manifest_path, save_checkpoint
from .federation import FederationCheckpointer, config_fingerprint

__all__ = ["FederationCheckpointer", "config_fingerprint",
           "load_checkpoint", "manifest_path", "save_checkpoint"]
