"""Communication-claim gate (run by the ``comm-claim`` CI job).

The paper's headline systems claim (§4, Fig. 4) is that ProxyFL's
communication is O(1) in federation size: every client sends exactly one
proxy per round, no matter how many clients join. The compressed exchange
(repro.core.compress) must shrink that constant, never disturb it. This
script loads the JSON written by ``benchmarks/fig4_comm.py`` (and, when
present, ``benchmarks/fig_compress.py``) and FAILS the build if:

1. ProxyFL's bottleneck bytes/round varies with K — for ANY compression
   mode, at every scale in the file (the O(1) claim itself);
2. a centralized baseline (FedAvg/FML) does NOT grow with K — that would
   mean the figure stopped measuring the contrast the paper draws;
3. top-k at ratio 0.25 reduces ProxyFL's bytes/round by < 4x, or int8
   by < 3.5x, versus an f32 full-precision baseline (the compression
   claim); scales whose baseline already ships bf16 (the LLM-scale rows,
   ``dtype_bytes == 2``) use the correspondingly halved structural
   floors — 3x top-k, 1.9x int8;
4. (fig_compress.json, full 20-round grids only) ProxyFL's top-k proxy
   accuracy falls more than 2 points below the uncompressed run at the
   claim cohorts (K <= 8 — the paper's experiments run 8 clients). The
   K=16 row is the scaling stress point and is reported, not gated:
   6.4x fewer bits at the slowest-mixing cohort buys a measured ~4-round
   consensus delay (the gap closes fully by 24 rounds), which is the
   honest Pareto trade the figure exists to show. Tiny CI slices
   (REPRO_BENCH_COMPRESS_TINY) skip the accuracy check entirely: 2
   rounds of a 5%% cohort is noise, and the point of the tiny slice is
   exercising the codecs, not the learning curve.

    PYTHONPATH=src python scripts/check_comm_claim.py \
        [fig4_comm.json] [fig_compress.json]
"""
import json
import sys


def _fail(msg: str):
    print(f"COMM CLAIM VIOLATED: {msg}", file=sys.stderr)
    sys.exit(1)


def _by(rows, **kv):
    return [r for r in rows if all(r.get(k) == v for k, v in kv.items())]


def check_fig4(rows):
    scales = sorted({r["scale"] for r in rows})
    modes = sorted({r["compress"] for r in rows})
    for scale in scales:
        # 1. O(1): proxyfl bytes/round must be ONE value across K
        for mode in modes:
            got = {r["clients"]: r["bytes_per_round"]
                   for r in _by(rows, scale=scale, method="proxyfl",
                                compress=mode)}
            if len(set(got.values())) != 1:
                _fail(f"proxyfl bytes/round varies with K at {scale} "
                      f"compress={mode}: {got}")
        # 2. contrast: the centralized baselines must grow with K
        for method in ("fedavg", "fml"):
            sel = sorted((r["clients"], r["bytes_per_round"])
                         for r in _by(rows, scale=scale, method=method,
                                      compress="none"))
            if any(b2 <= b1 for (_, b1), (_, b2) in zip(sel, sel[1:])):
                _fail(f"{method} bytes/round is not increasing in K at "
                      f"{scale}: {sel}")
        # 3. compression factors on what proxyfl ships — floors depend on
        # the baseline element width (f32 rows: 6.4x/4x structural bests;
        # bf16 rows: 3.2x/2x)
        base = _by(rows, scale=scale, method="proxyfl", compress="none")[0]
        f32 = base.get("dtype_bytes", 4) == 4
        for mode, floor in (("topk", 4.0 if f32 else 3.0),
                            ("int8", 3.5 if f32 else 1.9)):
            b = _by(rows, scale=scale, method="proxyfl", compress=mode)[0]
            red = base["bytes_per_round"] / b["bytes_per_round"]
            if red < floor:
                _fail(f"{mode} reduction {red:.2f}x < {floor}x at {scale}")
            print(f"ok {scale}: {mode} {red:.2f}x, proxyfl O(1) in K")


def check_fig_compress(rows):
    full_grid = all(r["rounds"] >= 20 for r in rows)
    for K in sorted({r["clients"] for r in rows}):
        none = _by(rows, clients=K, method="proxyfl", compress="none")[0]
        topk = _by(rows, clients=K, method="proxyfl", compress="topk")[0]
        red = none["client_bytes_per_round"] / topk["client_bytes_per_round"]
        if red < 4.0:
            _fail(f"fig_compress K={K}: topk reduction {red:.2f}x < 4x")
        if not full_grid:
            print(f"ok K={K}: topk {red:.2f}x (tiny slice — accuracy "
                  "gap not asserted)")
            continue
        gap = none["proxy_acc_mean"] - topk["proxy_acc_mean"]
        if K <= 8 and gap > 0.02:
            _fail(f"fig_compress K={K}: topk proxy accuracy "
                  f"{topk['proxy_acc_mean']:.4f} is {gap * 100:.1f} points "
                  f"below uncompressed {none['proxy_acc_mean']:.4f} (> 2)")
        note = "" if K <= 8 else " (stress row — reported, not gated)"
        print(f"ok K={K}: topk {red:.2f}x, proxy acc gap "
              f"{gap * 100:+.1f} points{note}")


def main(argv):
    fig4 = argv[1] if len(argv) > 1 else "fig4_comm.json"
    figc = argv[2] if len(argv) > 2 else "fig_compress.json"
    check_fig4(json.load(open(fig4)))
    try:
        rows = json.load(open(figc))
    except FileNotFoundError:
        print(f"note: {figc} absent — accuracy-vs-bytes checks skipped")
        rows = None
    if rows:
        check_fig_compress(rows)
    print("COMM CLAIM OK")


if __name__ == "__main__":
    main(sys.argv)
