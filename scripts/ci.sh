#!/usr/bin/env bash
# Single CI entry point: tier-1 test suite + headless quickstart example.
#
#   scripts/ci.sh             # full tier-1 run (ROADMAP verify command)
#   scripts/ci.sh --lint      # static analysis, reproduces the CI lint job:
#                             # fedlint (tools/fedlint — the five engine
#                             # correctness contracts from docs/INVARIANTS.md:
#                             # rng-discipline, trace-hygiene, carry-coverage,
#                             # fingerprint-coverage, kernel-dtype) over
#                             # src/ + benchmarks/, then the curated ruff
#                             # baseline (ruff.toml) over the whole tree.
#                             # ruff is skipped with a banner when not
#                             # installed (minimal containers); fedlint is
#                             # stdlib-only and always runs. FEDLINT_FORMAT=
#                             # github switches to workflow annotations.
#   scripts/ci.sh --fast      # only tests marked @pytest.mark.fast; includes
#                             # the fast slice of the cross-backend
#                             # conformance matrix (tests/test_conformance.py:
#                             # loop==vmap, ragged-on-vmap, blocked==per-round
#                             # bitwise, the async-τ0==vmap equivalence smoke,
#                             # async-τ2 block/resume bit-identity, the
#                             # Pallas fused-vs-plain hot-path parity, and
#                             # the compressed-exchange parity slice:
#                             # compress=none bitwise-identical to the
#                             # uncompressed protocol on every backend,
#                             # plus topk/int8 loop-vs-vmap columns with
#                             # the privacy epsilon compared EXACTLY —
#                             # compression must never touch the
#                             # accountant) plus
#                             # the interpret-mode kernel smoke slice
#                             # (tests/test_kernels.py: fused PushSum mix,
#                             # stale mix, noise→SGD/Adam step vs the ref
#                             # oracles) so every PR exercises every compiled
#                             # path including the fused kernels
#   scripts/ci.sh --bench     # NON-GATING perf baseline: the fast-tier
#                             # benchmark figures (selected from the
#                             # benchmarks.run registry's tier field — no
#                             # module names hard-coded here) write the
#                             # schema-stable BENCH_9.json artifact at the
#                             # repo root for CI to archive; a failure
#                             # prints a banner but NEVER fails the job
#                             # (shared runners make wall-clock gates
#                             # flaky by construction)
#   scripts/ci.sh --smoke     # resume-correctness smoke: 4-client federation
#                             # killed after round 2 of 3 and resumed (per-
#                             # round, rounds_per_block=2 kill-after-block,
#                             # the async-τ2 stale-buffer scenario AND the
#                             # hier-τ2 cross-shard-buffer scenario) must
#                             # be bit-identical to uninterrupted runs
#   scripts/ci.sh --shard I/N # deterministic 1-based slice of the test FILES
#                             # (sorted, round-robin) — the GitHub workflow
#                             # matrixes the full suite across shards; the
#                             # quickstart example runs on shard 1 only and
#                             # the heterogeneous-archs example on shard 2
#                             # (shards 1 and 2 always exist: CI's smallest
#                             # matrix is 3-way), so every example executes
#                             # exactly once per matrixed run
#
# The full suite exceeds 10 minutes serial, so pytest runs with `-n auto`
# whenever pytest-xdist is importable and falls back to serial when it is
# not (minimal containers stay supported).
#
# Extra arguments after the mode flag are forwarded to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# plain strings (not arrays): empty arrays break under `set -u` on bash < 4.4
MARK=""
SHARD=""
if [[ "${1:-}" == "--lint" ]]; then
  shift
  echo "== lint: fedlint (engine correctness contracts) =="
  python -m tools.fedlint src benchmarks --format="${FEDLINT_FORMAT:-text}"
  if python -c "import ruff" >/dev/null 2>&1 || command -v ruff >/dev/null 2>&1; then
    echo "== lint: ruff (curated baseline, ruff.toml) =="
    if [[ "${FEDLINT_FORMAT:-}" == "github" ]]; then
      ruff check --output-format=github .
    else
      ruff check .
    fi
  else
    echo "== lint: ruff NOT installed — SKIPPED (CI runs it; install ruff"
    echo "   locally to reproduce the full lint job) =="
  fi
  echo "CI OK"
  exit 0
elif [[ "${1:-}" == "--fast" ]]; then
  MARK="-m fast"
  shift
elif [[ "${1:-}" == "--bench" ]]; then
  shift
  echo "== bench baseline (non-gating): fast-tier figures -> BENCH_9.json =="
  if python scripts/bench_baseline.py "$@"; then
    echo "== bench baseline artifact written: BENCH_9.json =="
  else
    echo "== bench baseline FAILED — non-gating, job continues ==" >&2
  fi
  echo "CI OK"
  exit 0
elif [[ "${1:-}" == "--smoke" ]]; then
  shift
  echo "== smoke: checkpoint/resume bit-identity (round-blocks + async-τ2 + hier-τ2) + commitment verify-after-resume / refuse-after-bitflip =="
  python scripts/resume_smoke.py
  echo "CI OK"
  exit 0
elif [[ "${1:-}" == "--shard" ]]; then
  SHARD="${2:?--shard needs I/N (e.g. 1/2)}"
  shift 2
fi

XDIST=""
if python -c "import xdist" >/dev/null 2>&1; then
  XDIST="-n auto"
fi

# Property tests (hypothesis) skip cleanly when the library is absent
# (tests/_hypothesis_compat); -rs below makes pytest print the counted
# skip-reason summary so the logs record exactly what did not run.
if python -c "import hypothesis" >/dev/null 2>&1; then
  echo "== property tests: hypothesis available =="
else
  echo "== property tests: hypothesis NOT installed — property-based tests"
  echo "   will be SKIPPED (pinned deterministic twins still run; see the"
  echo "   'property test skipped' count in the pytest skip summary) =="
fi

if [[ -n "$SHARD" ]]; then
  I="${SHARD%%/*}"
  N="${SHARD##*/}"
  FILES=""
  i=0
  for f in tests/test_*.py; do  # glob order is sorted and stable
    if (( i % N == I - 1 )); then FILES="$FILES $f"; fi
    i=$((i + 1))
  done
  if [[ -z "$FILES" ]]; then
    # an empty slice (I > N or I > file count) must fail loudly — bare
    # pytest would silently collect the WHOLE tree instead
    echo "error: shard $SHARD selects no test files" >&2
    exit 1
  fi
  echo "== tier-1 shard $SHARD: pytest$FILES =="
  # shellcheck disable=SC2086  # FILES/XDIST intentionally word-split
  python -m pytest -x -q -rs $XDIST $FILES "$@"
  if [[ "$I" == "1" ]]; then
    echo "== example: quickstart (headless) =="
    python examples/quickstart.py
  elif [[ "$I" == "2" ]]; then
    # quickstart runs on shard 1; without this branch no shard ever
    # executed the heterogeneous-archs example and a regression there
    # would only surface in local full runs
    echo "== example: heterogeneous archs (headless) =="
    python examples/heterogeneous_archs.py
  fi
  echo "CI OK"
  exit 0
fi

echo "== tier-1: pytest =="
# shellcheck disable=SC2086  # MARK/XDIST intentionally word-split
python -m pytest -x -q -rs $MARK $XDIST "$@"

echo "== example: quickstart (headless) =="
python examples/quickstart.py

echo "CI OK"
