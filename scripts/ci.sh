#!/usr/bin/env bash
# Single CI entry point: tier-1 test suite + headless quickstart example.
#
#   scripts/ci.sh           # full tier-1 run (ROADMAP verify command)
#   scripts/ci.sh --fast    # only tests marked @pytest.mark.fast; includes
#                           # the ragged-cohort smoke (tests/test_ragged.py:
#                           # Dirichlet size-skewed clients on the vmap
#                           # backend — padded stacking, masked sampling,
#                           # loop==vmap equivalence) so every PR exercises
#                           # the compiled ragged path
#   scripts/ci.sh --smoke   # resume-correctness smoke: 4-client federation,
#                           # 3 rounds with --checkpoint-every 1, killed
#                           # after round 2 and resumed; fails unless the
#                           # final proxy params are bit-identical to an
#                           # uninterrupted run (loop AND vmap backends)
#
# Extra arguments after the mode flag are forwarded to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# plain string (not an array): empty arrays break under `set -u` on bash < 4.4
MARK=""
if [[ "${1:-}" == "--fast" ]]; then
  MARK="-m fast"
  shift
elif [[ "${1:-}" == "--smoke" ]]; then
  shift
  echo "== smoke: checkpoint/resume bit-identity =="
  python scripts/resume_smoke.py
  echo "CI OK"
  exit 0
fi

echo "== tier-1: pytest =="
# shellcheck disable=SC2086  # MARK intentionally word-splits into -m fast
python -m pytest -x -q $MARK "$@"

echo "== example: quickstart (headless) =="
python examples/quickstart.py

echo "CI OK"
