#!/usr/bin/env python
"""Non-gating CI performance baseline.

Runs the FAST-tier benchmark figures — selected from the
``benchmarks.run.MODULES`` registry's tier field, never hard-coded — at
their default CPU-budget settings and writes one schema-stable JSON
artifact, ``BENCH_9.json`` at the repo root, so CI can archive a
throughput baseline per commit without gating merges on wall-clock
numbers (shared runners make timing assertions flaky by construction).

Schema (stable across figures; every row carries every key)::

    {"schema": 1, "tier": "fast", "figures": [...], "rows": [
        {"figure": str, "K": int, "backend": str,
         "rounds_per_sec": float | null, "bytes_per_round": float | null},
    ]}

``bytes_per_round`` is each figure's own bytes column: the exchange
bytes-moved model for fig_kernels, the per-client cross-shard wire bytes
for fig_hier (the O(1)-in-K claim), absent (null) for fig_blocks.

Usage::

    PYTHONPATH=src python scripts/bench_baseline.py           # all fast tier
    PYTHONPATH=src python scripts/bench_baseline.py fig_hier  # subset
"""
from __future__ import annotations

import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path[:0] = [os.path.join(REPO, "src"), REPO]

OUT = os.path.join(REPO, "BENCH_9.json")


def _normalize(figure: str, row: dict) -> dict:
    """One row of any fast-tier figure → the stable schema."""
    backend = row.get("backend", "vmap")
    if figure == "fig_kernels":
        # fig_kernels times the vmap backend's plain vs Pallas-fused path
        backend = f"vmap-{row.get('path', 'plain')}"
    elif figure == "fig_hier" and backend == "hier":
        backend = f"hier-s{row.get('n_shards')}-t{row.get('staleness')}"
    bytes_per_round = None
    for k in ("bytes_per_round", "exchange_bytes_per_round",
              "bytes_cross_per_client"):
        if row.get(k) is not None:
            bytes_per_round = float(row[k])
            break
    return {
        "figure": figure,
        "K": int(row.get("K", row.get("clients", 0))),
        "backend": backend,
        "rounds_per_sec": (float(row["rounds_per_sec"])
                           if row.get("rounds_per_sec") is not None else None),
        "bytes_per_round": bytes_per_round,
    }


def main(argv=None) -> int:
    from benchmarks.run import MODULES, names_for_tier

    only = list(argv if argv is not None else sys.argv[1:])
    names = names_for_tier("fast")
    if only:
        unknown = set(only) - set(names)
        if unknown:
            raise SystemExit(f"not fast-tier figures: {sorted(unknown)} "
                             f"(fast tier: {names})")
        names = [n for n in names if n in only]

    # keep the figures' own per-run JSON artifacts out of the repo root
    res_dir = os.path.join(REPO, "results")
    os.makedirs(res_dir, exist_ok=True)
    os.environ.setdefault("REPRO_BENCH_BLOCKS_JSON",
                          os.path.join(res_dir, "fig_blocks.json"))
    os.environ.setdefault("REPRO_BENCH_KERNELS_JSON",
                          os.path.join(res_dir, "fig_kernels.json"))

    rows = []
    for name in names:
        mod = MODULES[name][0]
        print(f"[bench_baseline] running {name} ...", flush=True)
        for r in mod.run(False):
            rows.append(_normalize(name, r))
    artifact = {"schema": 1, "tier": "fast", "figures": names, "rows": rows}
    with open(OUT, "w") as f:
        json.dump(artifact, f, indent=1)
    print(f"[bench_baseline] {len(rows)} rows from {len(names)} figures "
          f"-> {OUT}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
