"""Resume-correctness smoke (run by ``scripts/ci.sh --smoke``).

The checkpointing contract this repo guarantees — and CI enforces — is:
a federation run killed after round t and resumed from its checkpoint
produces BIT-IDENTICAL final proxy parameters and accountant epsilon
versus the uninterrupted run.

Scenario (per backend, loop and vmap):
  1. reference: uninterrupted 4-client ProxyFL federation, 3 rounds.
  2. "killed" run: same federation with ``--checkpoint-every 1``,
     terminated after round 2 (cfg.rounds=2 stands in for the kill).
  3. resumed run: rounds=3 + ``resume=True`` restarts from the round-2
     snapshot and executes only the final round — WITH
     ``verify_commitments=True``, so the restore replays the whole
     commitment chain in strict mode (and the loop backend additionally
     verifies every received proxy digest in flight) before continuing.
  4. refuse-after-bitflip: one mantissa bit of one committed proxy leaf
     in the newest snapshot is flipped; the next resume must REFUSE with
     a ``CommitmentError`` naming the divergent round and leaf path.
Fails unless resumed == reference exactly (np.array_equal on every proxy
AND private leaf, exact epsilon match — verification observes state, it
never changes it), and unless the loop- and vmap-backend resumed runs
agree within numerical tolerance.

The same contract is then enforced for FUSED round-blocks (vmap): the
federation runs with ``rounds_per_block=2`` and ``checkpoint_every=2`` —
whole blocks compiled as one XLA program, snapshots on block edges — is
killed after the first block, resumed, and must still land bit-identically
on the per-round reference trajectory.

Finally the ASYNC (stale gossip) backend: a staleness-2 federation whose
uninterrupted reference fuses the whole horizon into ONE block is killed
at a checkpoint edge in the middle of that block structure and resumed.
With τ=2 every post-resume round consumes proxy mass recorded BEFORE the
kill, so bit-identity here proves the τ-deep in-flight buffer round-trips
through the checkpoint exactly.

The HIER (two-level) backend gets the same mid-block treatment at
staleness τ=2 and n_shards=2: post-resume rounds consume CROSS-SHARD
deliveries recorded before the kill, so bit-identity proves the
``hier_buffer``/``hier_w`` carry pair round-trips through the checkpoint
exactly (the FED003 carry-coverage contract, exercised end to end).

    PYTHONPATH=src python scripts/resume_smoke.py
"""
import dataclasses
import os
import sys
import tempfile

import jax
import numpy as np

from repro.configs.base import DPConfig, ProxyFLConfig
from repro.core.baselines import run_federated
from repro.core.protocol import ModelSpec
from repro.data.synthetic import make_classification_data
from repro.nn.modules import tree_flatten_vector
from repro.nn.vision import get_vision_model

K, N_CLASSES, SHAPE = 4, 10, (14, 14, 1)
ROUNDS, KILL_AFTER = 3, 2


def build_federation():
    key = jax.random.PRNGKey(0)
    x, y = make_classification_data(key, 1200, SHAPE, N_CLASSES, sep=2.0)
    data = [(x[i * 300:(i + 1) * 300], y[i * 300:(i + 1) * 300])
            for i in range(K)]
    vm = get_vision_model("mlp")
    spec = ModelSpec("mlp", lambda k: vm.init(k, SHAPE, N_CLASSES), vm.apply)
    cfg = ProxyFLConfig(
        n_clients=K, rounds=ROUNDS, batch_size=50, local_steps=2,
        dropout_rate=0.25,  # §3.4 active-mask schedule must also replay
        dp=DPConfig(enabled=True, noise_multiplier=1.0, clip_norm=1.0))
    return spec, data, data[0], cfg


def flat(res, role):
    return np.stack([np.asarray(tree_flatten_vector(getattr(c, role)))
                     for c in res["clients"]])


def bitflip_refusal(backend: str, run, cfg, ckpt) -> None:
    """Flip one mantissa bit of one committed proxy leaf in the newest
    snapshot; the next resume must refuse, naming round and leaf."""
    from repro.core.commit import CommitmentError

    sub = os.path.join(ckpt["checkpoint_dir"], "proxyfl_s0")
    latest = max(int(n[len("round_"):-len(".npz")]) for n in os.listdir(sub)
                 if n.startswith("round_") and n.endswith(".npz"))
    npz_path = os.path.join(sub, f"round_{latest:06d}.npz")
    with np.load(npz_path) as f:
        arrays = {k: f[k] for k in f.files}
    leaf = next(k for k in sorted(arrays) if "/proxy/params/" in k)
    arrays[leaf].reshape(-1).view(np.uint32)[0] ^= 1
    np.savez(npz_path, **arrays)
    try:
        run(cfg, resume=True, **ckpt)
    except CommitmentError as e:
        if e.round != latest or not e.leaf or e.leaf not in str(e):
            raise SystemExit(
                f"[resume-smoke:{backend}] FAIL: refusal did not name the "
                f"divergent round/leaf (round={e.round}, leaf={e.leaf})")
        print(f"[resume-smoke:{backend}] OK — bit-flipped snapshot leaf "
              f"refused (round {e.round}, c{e.client:04d}/{e.leaf})")
    else:
        raise SystemExit(f"[resume-smoke:{backend}] FAIL: tampered snapshot "
                         "was restored instead of refused")


def run_backend(backend: str) -> np.ndarray:
    spec, data, test, cfg = build_federation()
    run = lambda c, **kw: run_federated("proxyfl", [spec] * K, spec, data,
                                        test, c, seed=0, eval_every=ROUNDS,
                                        backend=backend, **kw)
    reference = run(cfg)
    with tempfile.TemporaryDirectory() as d:
        ckpt = dict(checkpoint_dir=d, checkpoint_every=1)
        run(dataclasses.replace(cfg, rounds=KILL_AFTER), **ckpt)  # "killed"
        # strict commitment mode: the restore replays the hash chain and
        # recomputes the snapshot's leaf digests before any state is used
        resumed = run(cfg, resume=True, verify_commitments=True, **ckpt)
        bitflip_refusal(backend, run, cfg, ckpt)

    failures = []
    for role in ("proxy_params", "private_params"):
        if not np.array_equal(flat(reference, role), flat(resumed, role)):
            failures.append(f"{role} differ after resume")
    if reference["epsilon"] != resumed["epsilon"]:
        failures.append(f"epsilon differs: {reference['epsilon']} != "
                        f"{resumed['epsilon']}")
    if len(resumed["history"]) != 1 or resumed["history"][0]["round"] != ROUNDS:
        failures.append("resumed run did not restart at the kill point")
    if failures:
        raise SystemExit(f"[resume-smoke:{backend}] FAIL: "
                         + "; ".join(failures))
    print(f"[resume-smoke:{backend}] OK — killed@{KILL_AFTER}/{ROUNDS} "
          f"verified resume is bit-identical "
          f"(eps={resumed['epsilon'][0]:.3f})")
    return flat(resumed, "proxy_params")


def run_blocked() -> None:
    """Kill-after-BLOCK/resume: rounds_per_block=2 fuses rounds {0,1} into
    one compiled block (checkpoint_every=2 puts the snapshot on the block
    edge); the run is killed there, resumed for the final round, and must
    reproduce the plain per-round reference bit-for-bit."""
    spec, data, test, cfg = build_federation()
    run = lambda c, **kw: run_federated("proxyfl", [spec] * K, spec, data,
                                        test, c, seed=0, eval_every=ROUNDS,
                                        backend="vmap", **kw)
    reference = run(cfg)  # per-round (rounds_per_block defaults to 1)
    with tempfile.TemporaryDirectory() as d:
        blk = dict(checkpoint_dir=d, checkpoint_every=KILL_AFTER,
                   rounds_per_block=KILL_AFTER)
        run(dataclasses.replace(cfg, rounds=KILL_AFTER), **blk)  # "killed"
        resumed = run(cfg, resume=True, verify_commitments=True, **blk)

    failures = []
    for role in ("proxy_params", "private_params"):
        if not np.array_equal(flat(reference, role), flat(resumed, role)):
            failures.append(f"{role} differ after blocked resume")
    if reference["epsilon"] != resumed["epsilon"]:
        failures.append(f"epsilon differs: {reference['epsilon']} != "
                        f"{resumed['epsilon']}")
    if failures:
        raise SystemExit("[resume-smoke:blocked] FAIL: " + "; ".join(failures))
    print(f"[resume-smoke:blocked] OK — rounds_per_block={KILL_AFTER} "
          f"kill-after-block resume is bit-identical to the per-round run")


def run_async_stale() -> None:
    """Kill-mid-block at staleness τ=2: the uninterrupted reference runs
    the WHOLE 6-round horizon as one fused async block; the killed run
    checkpoints every 2 rounds (block edges cut to the cadence) and dies
    at round 4 — mid the reference's block structure. The resume replays
    rounds 5-6, whose stale mix consumes sends recorded at rounds 3-4,
    i.e. delivery mass that only exists if ``stale_theta``/``stale_w``
    were restored from the snapshot. Must match the reference bit-for-bit
    (params AND epsilon)."""
    spec, data, test, cfg = build_federation()
    cfg = dataclasses.replace(cfg, rounds=6, staleness=2)
    run = lambda c, B, **kw: run_federated(
        "proxyfl", [spec] * K, spec, data, test, c, seed=0,
        eval_every=c.rounds, backend="async", rounds_per_block=B, **kw)
    reference = run(cfg, cfg.rounds)  # whole horizon: ONE compiled block
    with tempfile.TemporaryDirectory() as d:
        ckpt = dict(checkpoint_dir=d, checkpoint_every=2)
        run(dataclasses.replace(cfg, rounds=4), cfg.rounds, **ckpt)  # killed
        resumed = run(cfg, cfg.rounds, resume=True, verify_commitments=True,
                      **ckpt)

    failures = []
    for role in ("proxy_params", "private_params"):
        if not np.array_equal(flat(reference, role), flat(resumed, role)):
            failures.append(f"{role} differ after async-stale resume")
    if reference["epsilon"] != resumed["epsilon"]:
        failures.append(f"epsilon differs: {reference['epsilon']} != "
                        f"{resumed['epsilon']}")
    if failures:
        raise SystemExit("[resume-smoke:async-t2] FAIL: "
                         + "; ".join(failures))
    print("[resume-smoke:async-t2] OK — staleness-2 kill-mid-block resume "
          "is bit-identical (in-flight buffer restored from the snapshot)")


def run_hier_stale() -> None:
    """The hier twin of :func:`run_async_stale`: a staleness-2, n_shards=2
    two-level federation fused into ONE 6-round block is killed at round 4
    (a checkpoint edge cutting the block structure) and resumed. Rounds
    5-6 mix cross-shard sends recorded at rounds 3-4 — delivery mass that
    only exists if the hier in-flight pair (``hier_buffer``/``hier_w``)
    was restored from the snapshot. Must match the uninterrupted
    reference bit-for-bit (params AND epsilon)."""
    spec, data, test, cfg = build_federation()
    cfg = dataclasses.replace(cfg, rounds=6, staleness=2, n_shards=2)
    run = lambda c, B, **kw: run_federated(
        "proxyfl", [spec] * K, spec, data, test, c, seed=0,
        eval_every=c.rounds, backend="hier", rounds_per_block=B, **kw)
    reference = run(cfg, cfg.rounds)  # whole horizon: ONE compiled block
    with tempfile.TemporaryDirectory() as d:
        ckpt = dict(checkpoint_dir=d, checkpoint_every=2)
        run(dataclasses.replace(cfg, rounds=4), cfg.rounds, **ckpt)  # killed
        resumed = run(cfg, cfg.rounds, resume=True, verify_commitments=True,
                      **ckpt)

    failures = []
    for role in ("proxy_params", "private_params"):
        if not np.array_equal(flat(reference, role), flat(resumed, role)):
            failures.append(f"{role} differ after hier-stale resume")
    if reference["epsilon"] != resumed["epsilon"]:
        failures.append(f"epsilon differs: {reference['epsilon']} != "
                        f"{resumed['epsilon']}")
    if failures:
        raise SystemExit("[resume-smoke:hier-t2] FAIL: "
                         + "; ".join(failures))
    print("[resume-smoke:hier-t2] OK — two-level staleness-2 kill-mid-block "
          "resume is bit-identical (cross-shard buffer restored from the "
          "snapshot)")


def main() -> int:
    finals = {b: run_backend(b) for b in ("vmap", "loop")}
    np.testing.assert_allclose(finals["vmap"], finals["loop"],
                               atol=1e-5, rtol=1e-4,
                               err_msg="loop/vmap resumed runs diverged")
    print("[resume-smoke] OK — loop and vmap resumed trajectories agree")
    run_blocked()
    run_async_stale()
    run_hier_stale()
    return 0


if __name__ == "__main__":
    sys.exit(main())
