"""Shared AST plumbing for the fedlint rules.

One :class:`ModuleInfo` per parsed file carries:

* the AST with parent links and per-node enclosing-function chains
  (``func_chain(node)`` -> ("FederationEngine", "_local_phase", "one")),
* an import-alias table so dotted call names resolve to canonical module
  paths (``full_call_name``: ``fold_in(...)`` imported via ``from
  jax.random import fold_in`` resolves to ``"jax.random.fold_in"``),
* the comment map (line -> comment text) the suppression protocol and the
  fingerprint rule's justification check read from.

Everything here is stdlib-only (ast + tokenize): fedlint must run in a
bare CI container before any project dependency is importable.
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from typing import Dict, List, Optional, Tuple

SUPPRESS_RE = re.compile(
    r"#\s*fedlint:\s*disable=(?P<rules>[A-Za-z0-9_,\s]+?)"
    r"(?:\s*--\s*(?P<reason>.*\S))?\s*$")


class ModuleInfo:
    """Parsed module + derived indexes (see module docstring)."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.comments = _comment_map(source)
        self.parents: Dict[ast.AST, ast.AST] = {}
        self._chains: Dict[ast.AST, Tuple[str, ...]] = {}
        self.aliases = _alias_table(self.tree)
        self._index(self.tree, (), None)

    def _index(self, node: ast.AST, chain: Tuple[str, ...],
               parent: Optional[ast.AST]) -> None:
        if parent is not None:
            self.parents[node] = parent
        self._chains[node] = chain
        child_chain = chain
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            child_chain = chain + (node.name,)
        elif isinstance(node, ast.Lambda):
            child_chain = chain + ("<lambda>",)
        for child in ast.iter_child_nodes(node):
            self._index(child, child_chain, node)

    # -- lookups -----------------------------------------------------------

    def func_chain(self, node: ast.AST) -> Tuple[str, ...]:
        """Names of the functions/classes enclosing ``node``, outermost
        first (``("FederationEngine", "init_states")``); () at module
        level."""
        return self._chains.get(node, ())

    def enclosing_defs(self, node: ast.AST) -> List[ast.AST]:
        """FunctionDef/AsyncFunctionDef/Lambda nodes enclosing ``node``,
        innermost LAST."""
        out: List[ast.AST] = []
        n = self.parents.get(node)
        while n is not None:
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
                out.append(n)
            n = self.parents.get(n)
        out.reverse()
        return out

    def full_call_name(self, func: ast.AST) -> str:
        """Canonical dotted name of a call target, with the leading import
        alias expanded (``jrandom.split`` -> ``jax.random.split``); ""
        when the target is not a plain Name/Attribute chain."""
        parts: List[str] = []
        n = func
        while isinstance(n, ast.Attribute):
            parts.append(n.attr)
            n = n.value
        if not isinstance(n, ast.Name):
            return ""
        parts.append(n.id)
        parts.reverse()
        head = self.aliases.get(parts[0], parts[0])
        return ".".join([head] + parts[1:])

    def suppressed(self, rule_id: str, line: int) -> Optional[str]:
        """The suppression reason when ``rule_id`` is disabled at ``line``
        (inline comment, or a standalone comment on the line above);
        "" when disabled WITHOUT a reason; None when not suppressed."""
        for ln in (line, line - 1):
            c = self.comments.get(ln)
            if c is None:
                continue
            if ln == line - 1 and not _comment_only_line(self.source, ln):
                continue  # an inline comment governs its OWN line only
            m = SUPPRESS_RE.search(c)
            if not m:
                continue
            rules = {r.strip() for r in m.group("rules").split(",")}
            if rule_id in rules or "all" in rules:
                return m.group("reason") or ""
        return None

    def bad_suppressions(self) -> List[Tuple[int, str]]:
        """(line, problem) for every malformed suppression comment:
        missing mandatory reason, or unknown rule id."""
        from . import RULES
        out = []
        for ln, c in sorted(self.comments.items()):
            m = SUPPRESS_RE.search(c)
            if not m:
                continue
            rules = [r.strip() for r in m.group("rules").split(",")]
            if not m.group("reason"):
                out.append((ln, "suppression is missing its mandatory "
                                "reason: write '# fedlint: disable=RULE "
                                "-- <why this site is exempt>'"))
            for r in rules:
                if r != "all" and r not in RULES:
                    out.append((ln, f"suppression names unknown rule "
                                    f"{r!r}"))
        return out


def _comment_only_line(source: str, line: int) -> bool:
    lines = source.splitlines()
    if not (1 <= line <= len(lines)):
        return False
    return lines[line - 1].lstrip().startswith("#")


def _comment_map(source: str) -> Dict[int, str]:
    out: Dict[int, str] = {}
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type == tokenize.COMMENT:
                out[tok.start[0]] = tok.string
    except tokenize.TokenError:  # unterminated something: best effort
        pass
    return out


def _alias_table(tree: ast.Module) -> Dict[str, str]:
    """local name -> canonical dotted prefix, from top-level imports
    (function-local imports are rare enough to ignore here)."""
    table: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                table[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                table[a.asname or a.name] = f"{node.module}.{a.name}"
    return table


def chain_matches(chain: Tuple[str, ...], glob: str) -> bool:
    """True when the dotted enclosing chain — or any of its prefixes — is
    matched by ``glob``, so an entry for ``Engine._local_phase*`` also
    covers the nested defs inside it. ``""`` matches module level only;
    ``"*"`` matches everything."""
    import fnmatch
    if glob == "*":
        return True
    if not chain:
        return glob == ""
    return any(fnmatch.fnmatchcase(".".join(chain[:i + 1]), glob)
               for i in range(len(chain)))


def const_str(node: ast.AST) -> Optional[str]:
    """The value of a string-constant node, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def keyword_arg(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None
