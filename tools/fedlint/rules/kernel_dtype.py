"""FED005 kernel-dtype — Pallas kernels accumulate in f32 and resolve
``interpret`` through one switch.

Two invariants from the kernel guide that the conformance tests can only
probe pointwise:

* every matmul-class op inside a kernel body must pin
  ``preferred_element_type=jnp.float32`` — on the MXU, a bf16 dot without
  it accumulates in bf16 and the PushSum mass-conservation error grows
  with n_clients; narrowing back to the output dtype happens once, at the
  ``o_ref[...] =`` store.
* ``pl.pallas_call(..., interpret=...)`` must flow through
  ``resolve_interpret`` — a hardcoded literal either silently runs the
  interpreter on TPU (orders of magnitude slower) or breaks CPU CI, and
  cannot be toggled by ``REPRO_PALLAS_INTERPRET``.

Kernel bodies are found structurally: the function passed (directly or
via ``functools.partial``) as the first argument to ``pallas_call``, plus
any def whose name ends in ``_kernel``.
"""
from __future__ import annotations

import ast
import fnmatch
from typing import List, Set

from .. import Finding, Rule, register
from ..astutil import ModuleInfo, keyword_arg
from ..config import KERNELS_GLOB

_DOT_OPS = {
    "jax.numpy.dot", "jax.numpy.matmul", "jax.numpy.einsum",
    "jax.lax.dot", "jax.lax.dot_general",
    "jax.experimental.pallas.dot",
}


@register
class KernelDtype(Rule):
    id = "FED005"
    name = "kernel-dtype"
    scope = "file"

    def check_module(self, mod: ModuleInfo) -> List[Finding]:
        if not fnmatch.fnmatchcase(mod.path, KERNELS_GLOB):
            return []
        out: List[Finding] = []
        kernel_defs = self._kernel_defs(mod)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and \
                    mod.full_call_name(node.func).split(".")[-1] == \
                    "pallas_call":
                out.extend(self._check_interpret(mod, node))
        for kd in kernel_defs:
            out.extend(self._check_accum(mod, kd))
        return out

    # -- interpret resolution ---------------------------------------------

    def _check_interpret(self, mod: ModuleInfo,
                         call: ast.Call) -> List[Finding]:
        val = keyword_arg(call, "interpret")
        if val is None:
            return [self.finding(
                mod.path, call.lineno,
                "pallas_call without interpret=resolve_interpret(...): "
                "the platform/env switch (REPRO_PALLAS_INTERPRET) must "
                "decide interpreter mode, not the call site")]
        if isinstance(val, ast.Constant):
            return [self.finding(
                mod.path, val.lineno,
                f"hardcoded interpret={val.value!r}: wrap it as "
                f"interpret=resolve_interpret(interpret) so CPU CI and "
                f"TPU runs share one switch")]
        if self._is_resolved(mod, val, call):
            return []
        return [self.finding(
            mod.path, val.lineno,
            "interpret= is not routed through resolve_interpret(); pass "
            "interpret=resolve_interpret(interpret)")]

    def _is_resolved(self, mod: ModuleInfo, val: ast.AST,
                     call: ast.Call) -> bool:
        if isinstance(val, ast.Call) and \
                mod.full_call_name(val.func).split(".")[-1] == \
                "resolve_interpret":
            return True
        if isinstance(val, ast.Name):
            # a local `interp = resolve_interpret(...)` upstream counts
            for d in mod.enclosing_defs(call):
                for n in ast.walk(d):
                    if isinstance(n, ast.Assign) and \
                            isinstance(n.value, ast.Call) and \
                            mod.full_call_name(
                                n.value.func).split(".")[-1] == \
                            "resolve_interpret" and \
                            any(isinstance(t, ast.Name) and t.id == val.id
                                for t in n.targets):
                        return True
        return False

    # -- f32 accumulation --------------------------------------------------

    def _kernel_defs(self, mod: ModuleInfo) -> List[ast.AST]:
        names: Set[str] = set()
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call) and
                    mod.full_call_name(node.func).split(".")[-1] ==
                    "pallas_call" and node.args):
                continue
            body = node.args[0]
            if isinstance(body, ast.Call) and body.args:
                # functools.partial(kernel, ...) indirection
                body = body.args[0] if not isinstance(
                    body.func, ast.Name) or body.func.id == "partial" \
                    else body.func
            if isinstance(body, ast.Name):
                names.add(body.id)
        out = []
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and (node.name in names or
                         node.name.endswith("_kernel")):
                out.append(node)
        return out

    def _check_accum(self, mod: ModuleInfo, kdef) -> List[Finding]:
        out = []
        for node in ast.walk(kdef):
            if not (isinstance(node, ast.Call) and
                    mod.full_call_name(node.func) in _DOT_OPS):
                continue
            pet = keyword_arg(node, "preferred_element_type")
            if pet is None:
                out.append(self.finding(
                    mod.path, node.lineno,
                    f"{mod.full_call_name(node.func)} inside kernel "
                    f"{kdef.name!r} without preferred_element_type="
                    f"jnp.float32 — bf16 inputs would accumulate in "
                    f"bf16 and break mass conservation"))
            elif not self._is_f32(pet):
                out.append(self.finding(
                    mod.path, pet.lineno,
                    f"kernel {kdef.name!r} accumulates in a non-f32 "
                    f"preferred_element_type; accumulate in f32 and "
                    f"narrow once at the o_ref store"))
        return out

    @staticmethod
    def _is_f32(node: ast.AST) -> bool:
        if isinstance(node, ast.Attribute):
            return node.attr == "float32"
        if isinstance(node, ast.Constant):
            return node.value == "float32"
        if isinstance(node, ast.Name):
            return node.id == "float32"
        return False
