"""FED004 fingerprint-coverage — no config field escapes the fingerprint
or the entry points.

``config_fingerprint`` is what stops a resume from silently continuing a
run under a DIFFERENT configuration (a changed ``lr``, a changed DP
budget). Three structural checks:

* **hash coverage** — ``config_fingerprint`` must hash the full dataclass
  (``dataclasses.asdict``; new fields are then covered automatically) or,
  if it ever enumerates fields by hand, name every ``ProxyFLConfig``
  field explicitly.
* **justified excludes** — every name in ``DEFAULT_FINGERPRINT_EXCLUDE``
  must (a) be a real field and (b) carry a comment on its own line
  saying WHY identity is preserved without it. An exclude is a claim
  ("resuming with more rounds is the same run"); claims get written down.
* **entry-point threading** — every field must be settable from both
  user-facing drivers (``launch/train.py`` and ``benchmarks/common.py``):
  it must appear as a keyword/attribute there, or be exempted in
  ``FLAG_EXEMPT_FIELDS`` with a why. This is what makes "added a config
  field, forgot the flag" a CI failure instead of a silent default.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from .. import Finding, Rule, register
from ..astutil import ModuleInfo, const_str
from ..config import (
    CONFIG_PATH,
    ENTRYPOINT_PATHS,
    FEDERATION_PATH,
    FLAG_EXEMPT_FIELDS,
)


@register
class FingerprintCoverage(Rule):
    id = "FED004"
    name = "fingerprint-coverage"
    scope = "repo"

    def check_repo(self, repo) -> List[Finding]:
        cfg_mod = repo.module(CONFIG_PATH)
        fed_mod = repo.module(FEDERATION_PATH)
        if cfg_mod is None or fed_mod is None:
            return []
        fields = self._config_fields(cfg_mod)
        if not fields:
            return [self.finding(CONFIG_PATH, 1,
                                 "could not find ProxyFLConfig fields")]
        out: List[Finding] = []
        out.extend(self._check_fingerprint(fed_mod, fields))
        for entry in ENTRYPOINT_PATHS:
            out.extend(self._check_entrypoint(repo, entry, fields))
        return out

    # -- field discovery ---------------------------------------------------

    @staticmethod
    def _config_fields(mod: ModuleInfo) -> Dict[str, int]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef) and \
                    node.name == "ProxyFLConfig":
                return {st.target.id: st.lineno for st in node.body
                        if isinstance(st, ast.AnnAssign)
                        and isinstance(st.target, ast.Name)}
        return {}

    # -- fingerprint + exclude list ---------------------------------------

    def _check_fingerprint(self, mod: ModuleInfo,
                           fields: Dict[str, int]) -> List[Finding]:
        out: List[Finding] = []
        fp = self._find_def(mod, "config_fingerprint")
        if fp is None:
            return [self.finding(
                FEDERATION_PATH, 1,
                "config_fingerprint() not found — the fingerprint "
                "contract has no anchor")]
        uses_asdict = any(
            isinstance(n, ast.Call) and
            mod.full_call_name(n.func).endswith("asdict")
            for n in ast.walk(fp))
        excluded = self._exclude_entries(mod, out, fields)
        if not uses_asdict:
            named = {s for n in ast.walk(fp)
                     if (s := const_str(n)) is not None}
            for f, line in sorted(fields.items()):
                if f not in named and f not in excluded:
                    out.append(self.finding(
                        FEDERATION_PATH, fp.lineno,
                        f"config_fingerprint neither asdict()s the "
                        f"config nor names field {f!r} — an unfingerprinted "
                        f"field lets a resume silently change the run"))
        return out

    def _exclude_entries(self, mod: ModuleInfo, out: List[Finding],
                         fields: Dict[str, int]) -> Set[str]:
        excluded: Set[str] = set()
        tup = self._find_assign(mod, "DEFAULT_FINGERPRINT_EXCLUDE")
        if tup is None:
            out.append(self.finding(
                FEDERATION_PATH, 1,
                "DEFAULT_FINGERPRINT_EXCLUDE not found"))
            return excluded
        if not isinstance(tup, (ast.Tuple, ast.List, ast.Set)):
            return excluded
        for el in tup.elts:
            name = const_str(el)
            if name is None:
                continue
            excluded.add(name)
            if name not in fields:
                out.append(self.finding(
                    FEDERATION_PATH, el.lineno,
                    f"DEFAULT_FINGERPRINT_EXCLUDE names {name!r}, which "
                    f"is not a ProxyFLConfig field — stale exclude?"))
            if el.lineno not in mod.comments:
                out.append(self.finding(
                    FEDERATION_PATH, el.lineno,
                    f"excluded field {name!r} has no justifying comment "
                    f"on its line — say why run identity survives "
                    f"changing it"))
        return excluded

    # -- entry-point threading --------------------------------------------

    def _check_entrypoint(self, repo, entry: str,
                          fields: Dict[str, int]) -> List[Finding]:
        mod = repo.module(entry)
        if mod is None:
            return []
        settable: Set[str] = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                settable.update(kw.arg for kw in node.keywords
                                if kw.arg is not None)
            elif isinstance(node, ast.keyword):
                pass
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                a = node.args
                settable.update(p.arg for p in
                                a.args + a.kwonlyargs + a.posonlyargs)
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Attribute):
                        settable.add(t.attr)
        out = []
        for f, line in sorted(fields.items()):
            if f in settable or f in FLAG_EXEMPT_FIELDS:
                continue
            out.append(self.finding(
                CONFIG_PATH, line,
                f"ProxyFLConfig.{f} is not threaded through {entry} — "
                f"users of that entry point can never set it; add the "
                f"flag/kwarg or exempt it in FLAG_EXEMPT_FIELDS with a "
                f"why"))
        return out

    # -- ast helpers -------------------------------------------------------

    @staticmethod
    def _find_def(mod: ModuleInfo, name: str):
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name == name:
                return node
        return None

    @staticmethod
    def _find_assign(mod: ModuleInfo, name: str) -> Optional[ast.AST]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name) and t.id == name:
                        return node.value
            elif isinstance(node, ast.AnnAssign) and \
                    isinstance(node.target, ast.Name) and \
                    node.target.id == name:
                return node.value
        return None
