"""FED002 trace-hygiene — no host syncs inside traced code.

A ``.item()``, ``np.asarray``, ``float()``/``int()`` coercion, or a
Python ``if`` on a tracer value inside a ``lax.scan`` body or a
jit-reachable function either breaks tracing outright or — worse —
silently baking a runtime value in as a compile-time constant and forcing
a device sync + retrace per call. The round hot path (PR 6's fused
kernels, the block-scan round bodies) must stay a single traced program.

Which functions count as traced:

* defs decorated with ``jax.jit`` / ``jax.vmap`` / ``jax.pmap`` (directly
  or via ``functools.partial(jax.jit, ...)``),
* defs whose NAME is passed to a transform in the same module
  (``jax.jit(step)``, ``lax.scan(body, ...)``, ``pl.pallas_call(kern)``),
* defs listed in ``TRACED_FUNCTION_SITES`` in ``tools/fedlint/config.py``
  — factory-returned closures the module-local inference can't see
  (the engine's round cores, gossip/compress/dp math). Nested defs
  inherit their enclosing def's traced-ness.

The Python-``if`` check is deliberately narrow to stay useful: it only
fires when the test expression calls into ``jax.numpy``/``jax.lax`` (an
``if jnp.any(mask):`` is a tracer boolification; an ``if cfg.dp:`` is
legitimate compile-time staging).
"""
from __future__ import annotations

import ast
from typing import List, Set

from .. import Finding, Rule, register
from ..astutil import ModuleInfo, chain_matches
from ..config import TRACED_FUNCTION_SITES

_TRANSFORMS = {
    "jax.jit", "jax.vmap", "jax.pmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat",
    "jax.lax.scan", "jax.lax.map", "jax.lax.while_loop", "jax.lax.cond",
    "jax.lax.fori_loop", "jax.lax.switch", "jax.lax.associative_scan",
    "jax.experimental.pallas.pallas_call",
    "jax.experimental.shard_map.shard_map",
}

# attribute chains that yield static (python-int) values even on tracers;
# coercing THOSE is fine and idiomatic
_STATIC_ATTRS = {"shape", "ndim", "size", "dtype", "itemsize"}

# numpy CONVERSION entry points — the ones that take an (possibly traced)
# array in. Constant constructors (np.zeros on a static shape, np.arange)
# are fine inside traced code: they bake in as constants.
_NP_CONVERTERS = {"numpy.asarray", "numpy.array", "numpy.copy",
                  "numpy.ascontiguousarray", "numpy.asanyarray"}


@register
class TraceHygiene(Rule):
    id = "FED002"
    name = "trace-hygiene"
    scope = "file"

    def check_module(self, mod: ModuleInfo) -> List[Finding]:
        traced = self._traced_defs(mod)

        def in_traced(node: ast.AST) -> bool:
            if any(d in traced for d in mod.enclosing_defs(node)):
                return True
            chain = mod.func_chain(node)
            return any(path == mod.path and chain_matches(chain, glob)
                       for path, glob in TRACED_FUNCTION_SITES)

        out: List[Finding] = []
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and in_traced(node):
                out.extend(self._check_call(mod, node))
            elif isinstance(node, (ast.If, ast.While)) and in_traced(node):
                out.extend(self._check_branch(mod, node))
        return out

    # -- traced-def inference ---------------------------------------------

    def _traced_defs(self, mod: ModuleInfo) -> Set[ast.AST]:
        traced_names: Set[str] = set()
        defs = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, []).append(node)
            if isinstance(node, ast.Call) and \
                    mod.full_call_name(node.func) in _TRANSFORMS:
                for arg in node.args:
                    if isinstance(arg, ast.Name):
                        traced_names.add(arg.id)

        traced: Set[ast.AST] = set()
        for name in traced_names:
            traced.update(defs.get(name, ()))
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and any(self._traced_decorator(mod, d)
                            for d in node.decorator_list):
                traced.add(node)
        return traced

    def _traced_decorator(self, mod: ModuleInfo, dec: ast.AST) -> bool:
        if mod.full_call_name(dec) in _TRANSFORMS:
            return True
        if isinstance(dec, ast.Call):
            if mod.full_call_name(dec.func) in _TRANSFORMS:
                return True
            if mod.full_call_name(dec.func) == "functools.partial" and \
                    dec.args and \
                    mod.full_call_name(dec.args[0]) in _TRANSFORMS:
                return True
        return False

    # -- violation checks --------------------------------------------------

    def _check_call(self, mod: ModuleInfo, node: ast.Call) -> List[Finding]:
        out = []
        func = node.func
        if isinstance(func, ast.Attribute) and func.attr == "item":
            out.append(self.finding(
                mod.path, node.lineno,
                ".item() in traced code forces a device sync (or a "
                "ConcretizationError); keep the value on device or move "
                "the readout outside the jitted region"))
        full = mod.full_call_name(func)
        if full in _NP_CONVERTERS:
            out.append(self.finding(
                mod.path, node.lineno,
                f"{full} in traced code round-trips through host numpy; "
                f"use jax.numpy (or run this on materialized outputs, "
                f"outside the traced function)"))
        if isinstance(func, ast.Name) and func.id in ("float", "int",
                                                      "bool") \
                and len(node.args) == 1 \
                and not self._static_arg(node.args[0]) \
                and not self._static_argname(mod, node):
            out.append(self.finding(
                mod.path, node.lineno,
                f"{func.id}() on a (potential) tracer concretizes it; "
                f"use .astype(...) for dtype casts or hoist the host "
                f"coercion out of the traced function"))
        return out

    def _check_branch(self, mod: ModuleInfo, node) -> List[Finding]:
        kind = "if" if isinstance(node, ast.If) else "while"
        for sub in ast.walk(node.test):
            if isinstance(sub, ast.Call):
                full = mod.full_call_name(sub.func)
                if full.startswith(("jax.numpy.", "jax.lax.")):
                    return [self.finding(
                        mod.path, node.lineno,
                        f"python `{kind}` on a {full} result boolifies a "
                        f"tracer; use jnp.where / lax.cond / lax.select "
                        f"instead")]
        return []

    def _static_argname(self, mod: ModuleInfo, node: ast.Call) -> bool:
        """float(b1) is fine when ``b1`` is one of the enclosing jitted
        def's ``static_argnames`` — a python value at trace time."""
        arg = node.args[0]
        names = {n.id for n in ast.walk(arg)
                 if isinstance(n, ast.Name)}
        if not names:
            return False
        for d in mod.enclosing_defs(node):
            if isinstance(d, ast.Lambda):
                continue
            for dec in d.decorator_list:
                if not (isinstance(dec, ast.Call) and
                        mod.full_call_name(dec.func) ==
                        "functools.partial" and dec.args and
                        mod.full_call_name(dec.args[0]) in _TRANSFORMS):
                    continue
                from ..astutil import const_str, keyword_arg
                sa = keyword_arg(dec, "static_argnames")
                if sa is None:
                    continue
                statics = set()
                if isinstance(sa, (ast.Tuple, ast.List)):
                    statics = {s for e in sa.elts
                               if (s := const_str(e)) is not None}
                elif (s := const_str(sa)) is not None:
                    statics = {s}
                # any static argname in the expression marks it as
                # config math (the other names are then shape-derived
                # locals in practice), not a tracer coercion
                if names & statics:
                    return True
        return False

    @staticmethod
    def _static_arg(arg: ast.AST) -> bool:
        """True for expressions that are static under tracing: literals,
        .shape/.ndim/... chains, len(...), and arithmetic thereof."""
        if isinstance(arg, ast.Constant):
            return True
        for sub in ast.walk(arg):
            if isinstance(sub, ast.Attribute) and sub.attr in _STATIC_ATTRS:
                return True
            if isinstance(sub, ast.Call) and \
                    isinstance(sub.func, ast.Name) and \
                    sub.func.id == "len":
                return True
        return False
