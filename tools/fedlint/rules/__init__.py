"""Importing this package registers every rule (see the ``@register``
decorator in each module)."""
from . import (  # noqa: F401
    carry_coverage,
    fingerprint_coverage,
    kernel_dtype,
    rng_discipline,
    trace_hygiene,
)
