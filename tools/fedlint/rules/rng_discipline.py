"""FED001 rng-discipline — one canonical, whitelisted RNG schedule.

Bit-identical kill/resume (and the DP accountant's claim that each noise
draw happens exactly once) require every random stream in the repo to be
derivable from the run's base key through the canonical helpers:
``round_key(base, t)``, ``compress_round_key(rk)`` and per-client
``fold_in(key, k)``. Two checks enforce that:

* **whitelist** — ``jax.random.PRNGKey/key/split/fold_in`` may only
  appear at the sites enumerated in ``tools/fedlint/config.py``
  (each with a mandatory why). A new call site is a finding until it is
  either rewritten against the canonical helpers or consciously added to
  the table in the same diff.
* **double-consume** — the same key variable must not feed two random
  primitives in one straight-line scope (``split(ks, n)`` followed by
  ``randint(ks, ...)`` silently correlates "independent" streams).
  ``fold_in`` is exempt as a consumer: deriving many streams from one
  parent key is exactly its job.
"""
from __future__ import annotations

import ast
import fnmatch
from typing import Dict, List

from .. import Finding, Rule, register
from ..astutil import ModuleInfo, chain_matches
from ..config import RNG_ALLOWED_SITES

# canonical full name -> short primitive name used by the Allow table
_GATED = {
    "jax.random.PRNGKey": "PRNGKey",
    "jax.random.key": "key",
    "jax.random.split": "split",
    "jax.random.fold_in": "fold_in",
}

# jax.random calls that CONSUME their key argument (everything except the
# derivation primitives — a key may be folded many times, never drawn
# from twice)
_NON_CONSUMERS = {"PRNGKey", "key", "fold_in", "wrap_key_data",
                  "key_data", "key_impl", "clone"}


@register
class RngDiscipline(Rule):
    id = "FED001"
    name = "rng-discipline"
    scope = "file"

    def check_module(self, mod: ModuleInfo) -> List[Finding]:
        out: List[Finding] = []
        out.extend(self._whitelist(mod))
        out.extend(self._double_consume(mod))
        return out

    # -- whitelist ---------------------------------------------------------

    def _whitelist(self, mod: ModuleInfo) -> List[Finding]:
        out = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            prim = _GATED.get(mod.full_call_name(node.func))
            if prim is None:
                continue
            chain = mod.func_chain(node)
            if any(fnmatch.fnmatchcase(mod.path, a.path)
                   and chain_matches(chain, a.func)
                   and prim in a.prims
                   for a in RNG_ALLOWED_SITES):
                continue
            where = ".".join(chain) or "<module>"
            out.append(self.finding(
                mod.path, node.lineno,
                f"jax.random.{prim} in non-canonical site {where!r}: "
                f"derive keys via round_key/compress_round_key/"
                f"fold_in(key, k), or add this site to RNG_ALLOWED_SITES "
                f"in tools/fedlint/config.py with a why"))
        return out

    # -- double-consume ----------------------------------------------------

    def _double_consume(self, mod: ModuleInfo) -> List[Finding]:
        out: List[Finding] = []
        scopes = [mod.tree] + [
            n for n in ast.walk(mod.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        for scope in scopes:
            self._scan_block(mod, list(scope.body), {}, out)
        return out

    def _scan_block(self, mod: ModuleInfo, stmts: List[ast.stmt],
                    consumed: Dict[str, int], out: List[Finding]) -> None:
        """Linear source-order scan with assignment kill and a
        conservative union merge across branches."""
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue  # nested scopes scanned on their own
            if isinstance(st, ast.If):
                self._scan_exprs(mod, st.test, consumed, out)
                merged: Dict[str, int] = {}
                for branch in (st.body, st.orelse):
                    state = dict(consumed)
                    self._scan_block(mod, branch, state, out)
                    merged.update(state)
                consumed.clear()
                consumed.update(merged)
                continue
            if isinstance(st, ast.Try):
                merged = {}
                branches = [st.body] + [h.body for h in st.handlers] + \
                    [st.orelse, st.finalbody]
                for branch in branches:
                    state = dict(consumed)
                    self._scan_block(mod, branch, state, out)
                    merged.update(state)
                consumed.clear()
                consumed.update(merged)
                continue
            if isinstance(st, (ast.For, ast.AsyncFor, ast.While)):
                # straight-line view of one iteration; cross-iteration
                # reuse is covered because an un-rebound key consumed in
                # the body stays marked for the statements after the loop
                if isinstance(st, ast.While):
                    self._scan_exprs(mod, st.test, consumed, out)
                else:
                    self._scan_exprs(mod, st.iter, consumed, out)
                    self._kill_target(st.target, consumed)
                self._scan_block(mod, st.body, consumed, out)
                self._scan_block(mod, st.orelse, consumed, out)
                continue
            if isinstance(st, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                if st.value is not None:
                    self._scan_exprs(mod, st.value, consumed, out)
                targets = st.targets if isinstance(st, ast.Assign) \
                    else [st.target]
                for t in targets:
                    self._kill_target(t, consumed)
                continue
            if isinstance(st, ast.With):
                for item in st.items:
                    self._scan_exprs(mod, item.context_expr, consumed, out)
                self._scan_block(mod, st.body, consumed, out)
                continue
            for child in ast.iter_child_nodes(st):
                if isinstance(child, ast.expr):
                    self._scan_exprs(mod, child, consumed, out)

    def _scan_exprs(self, mod: ModuleInfo, expr: ast.AST,
                    consumed: Dict[str, int], out: List[Finding]) -> None:
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call) or not node.args:
                continue
            full = mod.full_call_name(node.func)
            if not full.startswith("jax.random."):
                continue
            if full.rsplit(".", 1)[1] in _NON_CONSUMERS:
                continue
            arg = node.args[0]
            if not isinstance(arg, ast.Name):
                continue
            prev = consumed.get(arg.id)
            if prev is not None:
                out.append(self.finding(
                    mod.path, node.lineno,
                    f"key {arg.id!r} already consumed by a random "
                    f"primitive at line {prev}; split it first — reusing "
                    f"a key correlates streams that must be independent"))
            else:
                consumed[arg.id] = node.lineno

    @staticmethod
    def _kill_target(target: ast.AST, consumed: Dict[str, int]) -> None:
        for n in ast.walk(target):
            if isinstance(n, ast.Name):
                consumed.pop(n.id, None)
