"""FED003 carry-coverage — every scan-carry key survives kill/resume.

PRs 5 and 7 each grew the federation-level carried state (``stale_theta``
/``stale_w`` for the async backend, ``ef_state`` for compression error
feedback), and each time ``_ckpt_payload``/``restore_state`` had to be
extended BY HAND. Forgetting that step is silent: training runs fine,
checkpoints save fine, and a resumed run diverges because part of the
carry came back zero-initialized. This rule closes the loop structurally:

1. discover the carry keys from ``engine.py`` itself — every string key
   of a state-wrapper dict (any dict literal carrying ``"clients"``, plus
   ``state["k"] = ...`` extensions of a wrapper bound to a name),
2. require every discovered key to be mentioned inside BOTH
   ``_ckpt_payload`` and ``restore_state``.

A key that genuinely must not be checkpointed goes in
``CARRY_EXEMPT_KEYS`` (tools/fedlint/config.py) with a why.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from .. import Finding, Rule, register
from ..astutil import ModuleInfo, const_str
from ..config import CARRY_EXEMPT_KEYS, ENGINE_PATH


@register
class CarryCoverage(Rule):
    id = "FED003"
    name = "carry-coverage"
    scope = "repo"

    def check_repo(self, repo) -> List[Finding]:
        mod = repo.module(ENGINE_PATH)
        if mod is None:
            return []
        carry = self._carry_keys(mod)
        if not carry:
            return [self.finding(
                ENGINE_PATH, 1,
                "found no state-wrapper dicts (a dict literal with a "
                "'clients' key) — if the carry layout was refactored, "
                "teach tools/fedlint/rules/carry_coverage.py the new "
                "shape")]
        out: List[Finding] = []
        coverage = {}
        for fname in ("_ckpt_payload", "restore_state"):
            fn = self._find_def(mod, fname)
            if fn is None:
                out.append(self.finding(
                    ENGINE_PATH, 1,
                    f"engine.py has no {fname}() — the carry-coverage "
                    f"contract checks checkpoint round-trips through it"))
                continue
            coverage[fname] = {
                s for n in ast.walk(fn)
                if (s := const_str(n)) is not None}
        for key, line in sorted(carry.items(), key=lambda kv: kv[1]):
            if key in CARRY_EXEMPT_KEYS:
                continue
            for fname, strings in coverage.items():
                if key not in strings:
                    out.append(self.finding(
                        ENGINE_PATH, line,
                        f"scan-carry key {key!r} never appears in "
                        f"{fname}() — a killed run would resume with "
                        f"this state zero-initialized; checkpoint it (or "
                        f"exempt it in CARRY_EXEMPT_KEYS with a why)"))
        return out

    # -- discovery ---------------------------------------------------------

    @staticmethod
    def _carry_keys(mod: ModuleInfo) -> Dict[str, int]:
        """key -> first line it appears as carried state."""
        keys: Dict[str, int] = {}
        wrapper_names: Set[str] = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Dict):
                knames = [const_str(k) for k in node.keys
                          if k is not None]
                if "clients" not in knames:
                    continue
                for k in node.keys:
                    s = const_str(k) if k is not None else None
                    if s is not None:
                        keys.setdefault(s, k.lineno)
                parent = mod.parents.get(node)
                if isinstance(parent, (ast.Assign, ast.AnnAssign)):
                    targets = parent.targets if isinstance(
                        parent, ast.Assign) else [parent.target]
                    for t in targets:
                        if isinstance(t, ast.Name):
                            wrapper_names.add(t.id)
        # state["k"] = ... extensions of a wrapper dict
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Subscript) and \
                            isinstance(t.value, ast.Name) and \
                            t.value.id in wrapper_names:
                        s = const_str(t.slice)
                        if s is not None:
                            keys.setdefault(s, t.lineno)
        return keys

    @staticmethod
    def _find_def(mod: ModuleInfo, name: str):
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name == name:
                return node
        return None
