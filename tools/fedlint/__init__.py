"""fedlint — repo-specific static analysis for the federation engine.

The reproduction's headline guarantees — bit-identical kill/resume at any
block size or staleness, exact PushSum mass conservation, honest DP
accounting — rest on a handful of CODE CONVENTIONS: one canonical RNG
schedule (``round_key``), a hand-maintained checkpoint payload
(``_ckpt_payload``), a config fingerprint, and an f32-accumulating kernel
idiom. Conventions rot; this package turns them into machine-checked
contracts that run in CI (see ``docs/INVARIANTS.md`` for what each rule
protects and why).

Rules (each documented in ``tools/fedlint/rules/``):

========  ====================  ====================================================
id        name                  contract
========  ====================  ====================================================
FED001    rng-discipline        PRNGKey/split/fold_in only at whitelisted canonical
                                sites; no key consumed by two random draws in one
                                scope (kill/resume + DP replay depend on one
                                deterministic key schedule)
FED002    trace-hygiene         no host syncs (.item(), np.asarray, float()/int())
                                or Python ``if`` on tracer values inside lax.scan
                                bodies / jit-reachable functions
FED003    carry-coverage        every federation-level scan-carry key next to
                                "clients" in engine state wrappers must round-trip
                                through _ckpt_payload AND restore_state
FED004    fingerprint-coverage  every ProxyFLConfig field is fingerprinted (asdict)
                                or justified in DEFAULT_FINGERPRINT_EXCLUDE, and is
                                threaded through (or exempted from) BOTH entry
                                points: launch/train.py and benchmarks/common.py
FED005    kernel-dtype          Pallas kernel bodies accumulate in f32
                                (preferred_element_type) and resolve interpret via
                                resolve_interpret, never a hardcoded literal
========  ====================  ====================================================

Suppressions: ``# fedlint: disable=FED001 -- <reason>`` on the offending
line (or a standalone comment on the line above) silences that rule there.
The reason is MANDATORY — a bare disable is itself a finding (FED000), so
every escape hatch is self-documenting in the diff that used it.

Run: ``python -m tools.fedlint src/ --format=github`` (exit 1 on findings).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

__all__ = ["Finding", "Rule", "RULES", "register", "all_rules"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a file/line."""

    rule: str            # rule id, e.g. "FED001"
    path: str            # repo-relative path
    line: int            # 1-based
    message: str
    severity: str = "error"   # "error" | "warning"

    def format_text(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} [{self.severity}] " \
               f"{self.message}"

    def format_github(self) -> str:
        kind = "error" if self.severity == "error" else "warning"
        # '%0A' etc. not needed: messages are single-line by construction
        return (f"::{kind} file={self.path},line={self.line},"
                f"title=fedlint {self.rule}::{self.message}")


class Rule:
    """Base class. ``scope`` selects the driver:

    * ``"file"``  — :meth:`check_module` runs once per linted file,
    * ``"repo"``  — :meth:`check_repo` runs once per invocation against
      fixed repo paths (cross-file structural contracts).
    """

    id: str = "FED000"
    name: str = "base"
    scope: str = "file"
    severity: str = "error"

    def check_module(self, mod) -> List[Finding]:  # pragma: no cover
        return []

    def check_repo(self, repo) -> List[Finding]:  # pragma: no cover
        return []

    def finding(self, path: str, line: int, message: str) -> Finding:
        return Finding(self.id, path, line, message, self.severity)


RULES: Dict[str, Rule] = {}


def register(rule_cls):
    """Class decorator: instantiate and add to the global registry."""
    rule = rule_cls()
    assert rule.id not in RULES, f"duplicate rule id {rule.id}"
    RULES[rule.id] = rule
    return rule_cls


def all_rules(select: Optional[List[str]] = None) -> List[Rule]:
    from . import rules  # noqa: F401  (importing registers everything)
    out = [RULES[k] for k in sorted(RULES)]
    if select:
        wanted = {s.strip() for s in select}
        unknown = wanted - set(RULES)
        if unknown:
            raise SystemExit(f"unknown rule id(s): {sorted(unknown)}")
        out = [r for r in out if r.id in wanted]
    return out
