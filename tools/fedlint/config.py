"""fedlint site tables — the repo-specific knowledge the rules consult.

Every entry that EXEMPTS something carries a mandatory ``why`` string, so
the whitelist is self-documenting and reviewable the same way the
``# fedlint: disable=RULE -- reason`` suppressions are. Adding a new RNG
call site, config field, or carried-state key means either conforming to
the canonical pattern or extending these tables in the same diff — which
is exactly the review hook the rules exist to create.

Paths are repo-relative posix globs; ``func`` globs match the dotted
enclosing-function chain (``"FederationEngine._local_phase.one"`` style;
``""`` is module level, ``"*"`` matches any function including module
level).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

# fixed repo locations the repo-scope rules cross-check structurally
ENGINE_PATH = "src/repro/core/engine.py"
CONFIG_PATH = "src/repro/configs/base.py"
FEDERATION_PATH = "src/repro/checkpoint/federation.py"
# both user-facing drivers every ProxyFLConfig field must be threaded
# through (or be exempted below, with a why)
ENTRYPOINT_PATHS = ("src/repro/launch/train.py", "benchmarks/common.py")

KERNELS_GLOB = "src/repro/kernels/*.py"


@dataclasses.dataclass(frozen=True)
class Allow:
    """One whitelisted RNG site (see rules/rng_discipline.py)."""

    path: str           # repo-relative glob
    func: str           # dotted enclosing-function chain glob
    prims: Tuple[str, ...]  # of: "PRNGKey", "key", "split", "fold_in"
    why: str

    def __post_init__(self):
        assert self.why.strip(), "whitelist entries need a why"


# Canonical RNG sites. PRNGKey (root key creation), fold_in (stream
# derivation — the kill/resume schedule lives here) and split (chain
# advancement) may appear ONLY at these sites; anything new is a finding
# until it is consciously added here or rewritten against round_key /
# compress_round_key / fold_in(key, k).
RNG_ALLOWED_SITES: Tuple[Allow, ...] = (
    # --- THE canonical schedule sites the whole repo derives from -------
    Allow("src/repro/core/engine.py", "round_key", ("fold_in",),
          "THE per-round key schedule: fold_in(base, ROUND_KEY_OFFSET+t); "
          "every backend and every block size replays it bit-exactly"),
    Allow("src/repro/core/compress.py", "compress_round_key", ("fold_in",),
          "codec RNG domain: fold_in(round_key, COMPRESS_KEY_FOLD), "
          "disjoint from the per-client fold domain by construction"),
    Allow("src/repro/core/engine.py", "FederationEngine.init_states",
          ("fold_in",),
          "per-client init streams fold_in(key, k), k < ROUND_KEY_OFFSET — "
          "disjoint from the round-key domain (tests/test_rng_schedule.py)"),
    # --- engine round internals (one schedule, all backends) ------------
    Allow("src/repro/core/engine.py", "FederationEngine._round_loop",
          ("fold_in",),
          "loop backend's per-client round key fold_in(key, k) — must match "
          "the stacked backends' _local_phase fanout bit-for-bit"),
    Allow("src/repro/core/engine.py", "FederationEngine._local_phase*",
          ("fold_in", "split"),
          "stacked per-client key fanout + the in-step key,batch,noise "
          "split — the single local-trajectory definition all backends "
          "share"),
    Allow("src/repro/core/engine.py", "FederationEngine._one_step*",
          ("split",),
          "loop-backend one-step body: same key,batch,noise split as "
          "_local_phase so loop == vmap draws bit-identical batches"),
    Allow("src/repro/core/engine.py", "FederationEngine.restore_state",
          ("PRNGKey", "key"),
          "throwaway template init for the checkpoint tree structure; its "
          "values are fully overwritten by the loaded snapshot"),
    Allow("src/repro/core/engine.py", "_dml_state_init.init", ("split",),
          "per-client private/proxy init key pair"),
    # --- protocol / dp local steps --------------------------------------
    Allow("src/repro/core/protocol.py", "init_client", ("split",),
          "historical per-client private/proxy init key pair"),
    Allow("src/repro/core/protocol.py", "local_round", ("split",),
          "historical reference local round: key,batch,noise split"),
    Allow("src/repro/core/dp.py", "add_gaussian_noise", ("split",),
          "one noise key per leaf of the gradient tree"),
    Allow("src/repro/core/dp.py", "_flat_gaussian_like", ("split",),
          "bit-identical per-leaf normals to add_gaussian_noise, drawn "
          "for the fused flat kernel path"),
    # --- drivers (root keys + data derivation) --------------------------
    Allow("src/repro/core/baselines.py", "run_federated", ("PRNGKey", "key"),
          "the run's base key from the user seed; rounds derive via "
          "round_key"),
    Allow("src/repro/launch/train.py", "main",
          ("PRNGKey", "key", "fold_in"),
          "driver root key + per-client dataset streams fold_in(key, "
          "100+k)/fold_in(key, 999+k), outside the engine's fold domains"),
    Allow("src/repro/launch/serve.py", "main", ("PRNGKey", "key", "split"),
          "serving demo root key; decode loop advances by split"),
    Allow("src/repro/launch/steps.py", "init_train_state", ("split",),
          "LLM-scale per-client init key pair"),
    Allow("src/repro/launch/steps.py", "train_state_shapes",
          ("PRNGKey", "key"),
          "shape-only eval_shape probe; values never materialize"),
    Allow("src/repro/launch/steps.py", "serve_state_shapes",
          ("PRNGKey", "key"),
          "shape-only eval_shape probe; values never materialize"),
    Allow("src/repro/launch/steps.py", "make_round_block_step*",
          ("fold_in",),
          "dryrun round-block twin of the engine's in-scan round_key fold"),
    Allow("src/repro/launch/steps.py", "make_hier_round_block_step*",
          ("fold_in",),
          "two-level (hier) round-block twin: same fold_in(keys, t) "
          "per-round schedule as make_round_block_step, one shard per pod"),
    # --- module families with their own key ownership -------------------
    Allow("src/repro/nn/*.py", "*", ("split", "fold_in"),
          "parameter-init trees fan one init key out to sub-module inits; "
          "keys never escape the init call"),
    Allow("src/repro/data/*.py", "*", ("PRNGKey", "key", "split", "fold_in"),
          "dataset generation owns fixed task-seed domains (task identity "
          "must NOT depend on the sampling key; documented per function)"),
    Allow("benchmarks/*.py", "*", ("PRNGKey", "key", "split", "fold_in"),
          "figure drivers own their root seeds and synthetic-data "
          "streams; the engine rounds they invoke still derive keys via "
          "round_key"),
)


# Functions whose bodies are traced even though the module-local inference
# cannot see it (they are returned by factories and jitted by a caller, or
# called from inside another jitted program). Nested defs inherit.
TRACED_FUNCTION_SITES: Tuple[Tuple[str, str], ...] = (
    ("src/repro/core/engine.py", "FederationEngine._local_phase*"),
    ("src/repro/core/engine.py", "FederationEngine._round_core*"),
    ("src/repro/core/engine.py", "FederationEngine._stale_round_core*"),
    ("src/repro/core/engine.py", "FederationEngine._hier_round_core*"),
    ("src/repro/core/engine.py", "FederationEngine._build_block*"),
    ("src/repro/core/engine.py", "FederationEngine._one_step*"),
    ("src/repro/core/engine.py", "FederationEngine._mix_matmul_op*"),
    ("src/repro/core/engine.py", "FederationEngine._shard_mix_op*"),
    ("src/repro/core/engine.py", "classifier_sampler*"),
    ("src/repro/core/gossip.py", "pushsum_mix"),
    ("src/repro/core/gossip.py", "pushsum_mix_debiased"),
    ("src/repro/core/gossip.py", "stale_mix_apply"),
    ("src/repro/core/gossip.py", "_hier_intra"),
    ("src/repro/core/gossip.py", "hier_mix_debiased"),
    ("src/repro/core/gossip.py", "hier_stale_mix_apply"),
    ("src/repro/core/gossip.py", "debias"),
    ("src/repro/core/gossip.py", "pushsum_gossip_shard"),
    ("src/repro/core/compress.py", "_topk_encode_decode"),
    ("src/repro/core/compress.py", "_int8_encode_decode"),
    ("src/repro/core/compress.py", "encode_decode"),
    ("src/repro/core/compress.py", "_split_P"),
    ("src/repro/core/compress.py", "_ef_encode"),
    ("src/repro/core/compress.py", "compressed_pushsum_mix"),
    ("src/repro/core/compress.py", "compressed_stale_mix"),
    ("src/repro/core/protocol.py", "dml_step_fn*"),
    ("src/repro/core/protocol.py", "ce_step_fn*"),
    ("src/repro/core/protocol.py", "_eval_apply*"),
    ("src/repro/core/dp.py", "clip_by_global_norm"),
    ("src/repro/core/dp.py", "add_gaussian_noise"),
    ("src/repro/core/dp.py", "_flat_gaussian_like"),
    ("src/repro/core/dp.py", "dp_gradient*"),
    ("src/repro/core/dp.py", "dp_adam_update*"),
)


# ProxyFLConfig fields exempt from the entry-point threading check of
# FED004 (fingerprint-coverage). Empty today: every field IS threaded
# through launch/train.py and benchmarks/common.py. Add entries as
# {"field": "why"} — the why is mandatory and shows up in --list-rules.
FLAG_EXEMPT_FIELDS: dict = {}


# Federation-level scan-carry keys exempt from FED003 (carry-coverage).
# Empty today: stale_theta/stale_w/ef_state/hier_buffer/hier_w all ride
# _ckpt_payload. Note the verifiable-federation layer (PR 10) adds NO
# carried state — commitment records (audit.jsonl, the meta commitment
# stamps) are on-disk audit artifacts recomputed from the canonical
# payload, never scan-carries, so they are outside FED003's domain by
# construction (see docs/INVARIANTS.md, "Commitment chain").
CARRY_EXEMPT_KEYS: dict = {}
