"""fedlint driver: collect files, run rules, filter suppressions, report.

``python -m tools.fedlint src/`` from the repo root is the canonical
invocation; ``--format=github`` makes CI annotate findings in the PR diff.
Exit status: 0 clean, 1 findings, 2 usage/parse errors.

The driver (not the rules) owns the suppression protocol: after a rule
emits a finding, a ``# fedlint: disable=<RULE> -- <reason>`` comment on
the finding's line (or a standalone comment directly above it) drops it.
A disable comment with no reason, or naming an unknown rule, is itself
reported as FED000 — and FED000 cannot be suppressed.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from . import Finding, all_rules
from .astutil import ModuleInfo

# repo root = parent of tools/; overridable for fixture trees in tests
DEFAULT_ROOT = Path(__file__).resolve().parents[2]


class Repo:
    """Lazy parsed-module cache keyed by repo-relative posix path; the
    repo-scope rules read fixed paths through this so they can run
    against fixture trees as well as the real checkout."""

    def __init__(self, root: Path):
        self.root = Path(root)
        self._cache: Dict[str, Optional[ModuleInfo]] = {}

    def module(self, relpath: str) -> Optional[ModuleInfo]:
        if relpath not in self._cache:
            p = self.root / relpath
            if not p.is_file():
                self._cache[relpath] = None
            else:
                self._cache[relpath] = ModuleInfo(
                    relpath, p.read_text(encoding="utf-8"))
        return self._cache[relpath]


def _collect(root: Path, paths: Sequence[str]) -> List[str]:
    """Expand the CLI path operands into sorted repo-relative posix
    paths of .py files."""
    out = set()
    for raw in paths:
        p = Path(raw)
        if not p.is_absolute():
            p = root / p
        if p.is_dir():
            out.update(f for f in p.rglob("*.py"))
        elif p.is_file():
            out.add(p)
        else:
            raise SystemExit(f"fedlint: no such path: {raw}")
    rels = []
    for f in sorted(out):
        try:
            rels.append(f.resolve().relative_to(root.resolve()).as_posix())
        except ValueError:
            rels.append(f.as_posix())
    return rels


def run(paths: Sequence[str], root: Optional[Path] = None,
        select: Optional[List[str]] = None) -> Tuple[List[Finding], List[str]]:
    """Lint ``paths`` under ``root``; returns (findings, parse_errors)."""
    root = Path(root) if root is not None else DEFAULT_ROOT
    repo = Repo(root)
    rules = all_rules(select)
    files = _collect(root, paths)
    findings: List[Finding] = []
    errors: List[str] = []
    mods: Dict[str, ModuleInfo] = {}

    for rel in files:
        try:
            mod = ModuleInfo(rel, (root / rel).read_text(encoding="utf-8"))
        except SyntaxError as e:
            errors.append(f"{rel}: syntax error: {e}")
            continue
        mods[rel] = mod
        repo._cache[rel] = mod
        for ln, problem in mod.bad_suppressions():
            findings.append(Finding("FED000", rel, ln, problem))
        for rule in rules:
            if rule.scope == "file":
                findings.extend(rule.check_module(mod))

    for rule in rules:
        if rule.scope == "repo":
            findings.extend(rule.check_repo(repo))

    kept = []
    for f in findings:
        if f.rule != "FED000":
            mod = mods.get(f.path) or repo._cache.get(f.path)
            if mod is not None and \
                    mod.suppressed(f.rule, f.line) is not None:
                continue
        kept.append(f)
    kept.sort(key=lambda f: (f.path, f.line, f.rule))
    return kept, errors


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.fedlint",
        description="repo-specific static analysis for the federation "
                    "engine's correctness contracts")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/directories to lint (default: src)")
    ap.add_argument("--format", choices=("text", "github"), default="text",
                    help="github emits ::error workflow annotations")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--root", default=None,
                    help="repo root override (used by the fixture tests)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    ns = ap.parse_args(argv)

    if ns.list_rules:
        for rule in all_rules():
            print(f"{rule.id}  {rule.name:<22} [{rule.scope}/"
                  f"{rule.severity}]")
        return 0

    select = ns.select.split(",") if ns.select else None
    paths = ns.paths or ["src"]
    try:
        findings, errors = run(
            paths, Path(ns.root) if ns.root else None, select)
    except SystemExit as e:
        print(e, file=sys.stderr)
        return 2
    for err in errors:
        print(f"fedlint: {err}", file=sys.stderr)
    for f in findings:
        print(f.format_github() if ns.format == "github"
              else f.format_text())
    if findings:
        n_err = sum(1 for f in findings if f.severity == "error")
        print(f"fedlint: {len(findings)} finding(s) "
              f"({n_err} error(s))", file=sys.stderr)
    if errors:
        return 2
    return 1 if findings else 0
